"""The paper's full evaluation workload: all four SSB query dataflows under
the three engines (ordinary / Kettle-like / optimized), with Algorithm-1
partitioning printed and results cross-checked against oracles.

  PYTHONPATH=src python examples/etl_ssb.py [--rows 1000000]
"""
import argparse

import numpy as np

from repro.core import (OptimizedEngine, OptimizeOptions, OrdinaryEngine,
                        StreamingEngine, partition, resolve_backend)
from repro.etl import BUILDERS, KettleEngine
from repro.etl.ssb import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_000_000)
    ap.add_argument("--splits", type=int, default=8)
    ap.add_argument("--backend", default=None,
                    help="operator backend: numpy (default) or jax; "
                         "REPRO_BACKEND env var also works")
    args = ap.parse_args()
    # float32 device accumulation cannot hit the float64 oracles exactly
    rtol = resolve_backend(args.backend).oracle_rtol

    data = generate(lineorder_rows=args.rows)
    print(f"SSB data: {data.nbytes()/1e6:.0f} MB columnar, "
          f"{args.rows} lineorder rows")

    for qname, build in BUILDERS.items():
        qf = build(data)
        g = partition(qf.flow)
        trees = " | ".join(f"T{t.tree_id+1}:{t.root}" for t in g.trees)
        print(f"\n{qname}: {len(qf.flow)} components -> "
              f"{len(g.trees)} execution trees ({trees})")
        expect = qf.oracle(data)

        rows = []
        qf = build(data)
        r = OrdinaryEngine(qf.flow, backend=args.backend).run()
        _check(qf.sink.result(), expect, rtol)
        rows.append(("ordinary", r))
        qf = build(data)
        r = KettleEngine(qf.flow, backend=args.backend).run()
        _check(qf.sink.result(), expect, rtol)
        rows.append(("kettle-like", r))
        qf = build(data)
        r = OptimizedEngine(qf.flow, OptimizeOptions(
            num_splits=args.splits, backend=args.backend)).run()
        _check(qf.sink.result(), expect, rtol)
        rows.append(("optimized", r))
        qf = build(data)
        r = StreamingEngine(qf.flow, OptimizeOptions(
            num_splits=args.splits, backend=args.backend)).run()
        _check(qf.sink.result(), expect, rtol)
        rows.append(("streaming", r))
        for name, rr in rows:
            print(f"  {name:12s} wall {rr.wall_time:6.2f}s  "
                  f"copies {rr.copies:4d}  "
                  f"copied {rr.bytes_copied/1e6:8.1f} MB")
    print("\nall results match the independent oracles — OK")


def _check(got, expect, rtol):
    for k in expect:
        np.testing.assert_allclose(got[k], expect[k], rtol=rtol)


if __name__ == "__main__":
    main()
