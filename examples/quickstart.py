"""Quickstart — the paper's technique on its own workload in ~60 lines.

Builds the paper's Figure-11 dataflow (SSB Q4.1), partitions it with
Algorithm 1, runs it three ways (ordinary / shared-cache / pipelined), plans
the optimal pipeline degree with Theorem 1, and checks the results against
an independent oracle.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (OptimizedEngine, OptimizeOptions, OrdinaryEngine,
                        partition)
from repro.core.planner import build_plan, choose_degree
from repro.etl import build_q4
from repro.etl.ssb import generate

# 1. data + dataflow (the paper's Fig-11 Q4.1 flow)
data = generate(lineorder_rows=500_000)
qf = build_q4(data)
print(f"dataflow: {qf.flow}")

# 2. Algorithm 1 — partition into execution trees
g_tau = partition(qf.flow)
for t in g_tau.trees:
    print(f"  T{t.tree_id + 1}: root={t.root!r:18s} members={t.members}")

# 3. ordinary engine (separate caches, copy on every edge)
run_ord = OrdinaryEngine(qf.flow).run()
result_ord = qf.sink.result()
print(run_ord.summary())

# 4. optimized engine — shared caching, sequential (paper: ~10% gain)
qf = build_q4(data)
run_seq = OptimizedEngine(qf.flow, OptimizeOptions(
    num_splits=8, pipelined=False, concurrent_trees=False)).run()
print(run_seq.summary(), f"(copies {run_ord.copies} -> {run_seq.copies})")

# 5. Algorithm 3 + Theorem 1 — plan the pipeline degree from the sample run
costs = {n: run_seq.activity_times[n] for n in run_seq.trees[0]}
plan = build_plan(costs, misc_total=0.002 * len(costs),
                  sample_rows=500_000, full_rows=500_000, m_prime=8)
m = choose_degree(plan, cores=8)
print(f"Theorem 1: staggering={plan.staggering!r} m*={plan.m_star:.1f} "
      f"-> degree {m}")

# 6. optimized engine — shared caching + pipeline parallelization
qf = build_q4(data)
run_pipe = OptimizedEngine(qf.flow, OptimizeOptions(num_splits=m)).run()
result_pipe = qf.sink.result()
print(run_pipe.summary())

# 7. correctness: engine results == independent oracle
expect = qf.oracle(data)
for key in expect:
    np.testing.assert_allclose(result_ord[key], expect[key], rtol=1e-9)
    np.testing.assert_allclose(result_pipe[key], expect[key], rtol=1e-9)
print("results match the independent oracle — OK")
