"""End-to-end training driver: train a ~100M-parameter LM for a few hundred
steps on CPU, with every substrate layer engaged —

  ETL input pipeline (core engine: shared caches + Algorithm-2 prefetch)
  -> jit'd train_step (microbatch accumulation, donated buffers)
  -> async CheckpointManager + StragglerWatchdog
  -> mid-run checkpoint-restart (simulated failure) proving elastic resume.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--dim 512]
"""
import argparse
import shutil
import tempfile

import numpy as np

from repro.configs import get_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: 8L x d512 + 32k vocab (tok_embed+head = 2x 16.4M)
    cfg = get_config("stablelm-3b", smoke=True).replace(
        name="lm-100m", n_layers=args.layers, d_model=args.dim,
        n_heads=8, n_kv_heads=8, d_ff=4 * args.dim, vocab_size=32_000,
        grad_accum=2)
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    try:
        half = args.steps // 2
        print(f"— phase 1: steps 0..{half} (then simulated failure) —")
        r1 = train_loop(cfg, steps=half, batch=args.batch,
                        seq_len=args.seq_len, ckpt_dir=ckpt_dir,
                        ckpt_every=max(half // 4, 1), log_every=20)
        print(f"— phase 2: restart from checkpoint, continue to "
              f"{args.steps} —")
        r2 = train_loop(cfg, steps=args.steps, batch=args.batch,
                        seq_len=args.seq_len, ckpt_dir=ckpt_dir,
                        resume=True, log_every=20)
        first = r1["losses"][0]
        last = r2["losses"][-1]
        print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
              f"({r2['tokens_per_s']:.0f} tok/s phase-2)")
        assert last < first - 0.5, "loss should drop substantially"
        print("OK")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
