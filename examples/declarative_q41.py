"""SSB Q4.1 (the paper's Figure-11 dataflow) built with the declarative
flow API — expression DSL + FlowBuilder + Session — and cross-checked
against the independent oracle.

  PYTHONPATH=src python examples/declarative_q41.py [--rows 200000]
                                                    [--backend jax]
                                                    [--engine streaming]

CI runs this script on every push (small --rows) as the doc-rot guard for
the README's "Declarative flow API" section: if the public API drifts from
what is documented here, the build fails.
"""
import argparse
import sys

import numpy as np

import repro
from repro import col
from repro.etl import BUILDERS, DimTable
from repro.etl.ssb import generate, mfgr_id, region_id


def build_flow(data) -> repro.Flow:
    AMERICA = region_id("AMERICA")
    M1, M2 = mfgr_id("MFGR#1"), mfgr_id("MFGR#2")
    cust = DimTable(data.customer["c_custkey"],
                    {"c_nation": data.customer["c_nation"]},
                    row_filter=data.customer["c_region"] == AMERICA)
    supp = DimTable(data.supplier["s_suppkey"],
                    {"s_nation": data.supplier["s_nation"]},
                    row_filter=data.supplier["s_region"] == AMERICA)
    part = DimTable(data.part["p_partkey"], {"p_mfgr": data.part["p_mfgr"]},
                    row_filter=((data.part["p_mfgr"] == M1)
                                | (data.part["p_mfgr"] == M2)))
    date = DimTable(data.date["d_datekey"], {"d_year": data.date["d_year"]})

    # every predicate/expression is an AST node: read sets are derived, the
    # optimizer commutes/fuses without hand-declared reads=, and the jax
    # backend traces the predicate into its fused segment kernel
    return (repro.flow("q4.1-declarative")
            .source(data.lineorder, name="lineorder")
            .lookup(cust, "lo_custkey", {"c_nation": "c_nation"})
            .lookup(supp, "lo_suppkey", {"s_nation": "s_nation"})
            .lookup(part, "lo_partkey", {"p_mfgr": "p_mfgr"})
            .lookup(date, "lo_orderdate", {"d_year": "d_year"})
            .filter((col("c_nation") >= 0) & (col("s_nation") >= 0)
                    & (col("p_mfgr") >= 0) & (col("d_year") >= 0))
            .project("d_year", "c_nation", "lo_revenue", "lo_supplycost")
            .derive("profit", col("lo_revenue") - col("lo_supplycost"))
            .aggregate(["d_year", "c_nation"], {"profit": ("profit", "sum")})
            .sort(["d_year", "c_nation"])
            .sink())


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--backend", default=None,
                    help="operator backend: numpy (default) or jax")
    ap.add_argument("--engine", default="streaming",
                    choices=repro.Session.ENGINES)
    ap.add_argument("--optimize", type=int, default=2)
    args = ap.parse_args()

    data = generate(lineorder_rows=args.rows)
    f = build_flow(data)
    print(f"built {f.name}: {len(f.flow)} components, "
          f"sink schema {sorted(f.schema)}")

    session = repro.Session(backend=args.backend)
    kwargs = {}
    if args.engine in ("optimized", "streaming"):
        kwargs = dict(optimize=args.optimize, fuse=True, num_splits=8)
    res = session.run(f, engine=args.engine, **kwargs)
    print(res.summary())
    for r in res.run.rewrites:
        print(f"  rewrite: {r['rule']}: {r['detail']}")
    for r in res.run.refusals:
        print(f"  refusal: {r['rule']}: {r['detail']}")

    # cross-check against the independent Q4.1 oracle
    from repro.core import resolve_backend
    rtol = resolve_backend(args.backend).oracle_rtol
    expect = BUILDERS["Q4.1"](data).oracle(data)
    assert set(res.table) == set(expect), "column set mismatch"
    for k in expect:
        np.testing.assert_allclose(res.table[k], expect[k], rtol=rtol)
    undeclared = [r for r in res.run.refusals if "undeclared" in r["detail"]]
    assert not undeclared, f"undeclared-read refusals on a DSL flow: {undeclared}"
    print(f"OK: {len(res.table['profit'])} result rows match the oracle "
          f"(rtol={rtol})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
