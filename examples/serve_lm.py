"""Batched serving example: prefill + decode with donated KV caches (the
shared caching scheme applied to inference) on a smoke-scale model.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np

from repro.configs import get_config
from repro.launch.serve import BatchedServer, Request


def main():
    cfg = get_config("mixtral-8x7b", smoke=True)   # MoE + sliding window
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, 24
                                        ).astype(np.int32),
                    max_new=16, t_submit=time.time())
            for i in range(8)]
    server = BatchedServer(cfg, batch=4, temperature=0.0)
    t0 = time.time()
    done = server.run(reqs)
    wall = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {n_tok} tokens in {wall:.2f}s")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")
    # same-prompt determinism (greedy)
    assert done[0].out_tokens != [] and len(done) == 8
    print("OK")


if __name__ == "__main__":
    main()
