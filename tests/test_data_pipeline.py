"""ETL-based training input pipeline: packing correctness, determinism,
window carry, prefetch semantics."""
import numpy as np
import pytest

from repro.data import (InputPipeline, PipelineConfig, PrefetchQueue,
                        SyntheticTokenSource, make_lm_batch_fn)
from repro.data.pipeline import SequencePacker, build_lm_dataflow
from repro.core import OptimizedEngine, OptimizeOptions, partition
from repro.core.shared_cache import SharedCache
from repro.configs import get_config


def _pc(**kw):
    base = dict(seq_len=64, global_batch=4, vocab_size=500,
                docs_per_window=128, num_splits=4, pipeline_degree=2,
                max_doc_len=96, min_doc_len=8, seed=3)
    base.update(kw)
    return PipelineConfig(**base)


def test_batches_shape_and_range():
    it = iter(InputPipeline(_pc()))
    for _ in range(3):
        b = next(it)
        assert b.shape == (4, 65)
        assert b.min() >= 0 and b.max() < 500


def test_determinism_across_instances():
    a = iter(InputPipeline(_pc()))
    b = iter(InputPipeline(_pc()))
    for _ in range(3):
        np.testing.assert_array_equal(next(a), next(b))


def test_packing_preserves_token_stream():
    """Reassembling the packed blocks must reproduce doc tokens + EOS
    separators in document order (the row-order synchronizer guarantee)."""
    pc = _pc()
    pipe = InputPipeline(pc)
    it = iter(pipe)
    blocks = [next(it) for _ in range(4)]
    stream = np.concatenate([b.reshape(-1) for b in blocks])

    # independently rebuild the expected stream from the filtered source,
    # using the engine's chunking (docs_per_window / num_splits) — the
    # source's RNG stream is chunk-granular
    src = SyntheticTokenSource("s", pc, window=0)
    parts = []
    for cache in src.chunks(pc.docs_per_window // pc.num_splits):
        toks, lens = cache.col("tokens"), cache.col("length")
        for i in range(cache.n):
            if lens[i] >= pc.min_doc_len:
                parts.append(toks[i, : lens[i]])
                parts.append(np.array([pc.eos_id], np.int32))
    expect = np.concatenate(parts)[: len(stream)]
    np.testing.assert_array_equal(stream, expect)


def test_leftover_carry_across_windows():
    pc = _pc(docs_per_window=4, global_batch=8)
    pipe = InputPipeline(pc)
    it = iter(pipe)
    next(it)
    assert len(pipe.engine_runs) >= 2     # needed multiple windows
    # no tokens lost at window boundaries: covered by stream test above


def test_dataflow_partitions_into_two_trees():
    flow, _, _ = build_lm_dataflow(_pc(), window=0)
    g = partition(flow)
    assert len(g.trees) == 2              # packer (block) roots tree 2
    roots = {t.root for t in g.trees}
    assert roots == {"doc_source", "sequence_packer"}


def test_prefetch_queue_yields_all_and_propagates_errors():
    q = PrefetchQueue(iter(range(10)), depth=2, stage_fn=lambda x: x * 2)
    assert sorted(q) == [0, 2, 4, 6, 8, 10, 12, 14, 16, 18]

    def boom():
        yield 1
        raise ValueError("source died")

    q2 = PrefetchQueue(boom(), depth=2)
    assert next(q2) == 1
    with pytest.raises(ValueError, match="source died"):
        next(q2)
        next(q2)


def test_batch_fns_per_family():
    blk = np.arange(4 * 33, dtype=np.int32).reshape(4, 33) % 100
    lm = make_lm_batch_fn(get_config("stablelm-3b", smoke=True))(blk)
    assert lm["tokens"].shape == (4, 32)
    au_cfg = get_config("hubert-xlarge", smoke=True)
    au = make_lm_batch_fn(au_cfg)(blk)
    assert au["frames"].shape == (4, 32, au_cfg.d_model)
    assert au["labels"].shape == (4, 32)
    vl_cfg = get_config("llama-3.2-vision-11b", smoke=True)
    vl = make_lm_batch_fn(vl_cfg)(blk)
    assert vl["vision"].shape == (4, vl_cfg.n_vision_tokens, vl_cfg.d_model)


def test_packer_block_component_semantics():
    p = SequencePacker("p", seq_len=4, eos_id=9)
    state = p.new_state()
    p.accumulate(state, SharedCache({
        "tokens": np.array([[1, 2, 3, 0]], np.int32),
        "length": np.array([3], np.int32)}))
    p.accumulate(state, SharedCache({
        "tokens": np.array([[4, 5, 0, 0]], np.int32),
        "length": np.array([2], np.int32)}))
    out = p.finish(state)
    # stream = 1 2 3 9 4 5 9 -> one row of 5, leftover [5 9]... seq_len+1=5
    np.testing.assert_array_equal(out.col("tokens"),
                                  [[1, 2, 3, 9, 4]])
    np.testing.assert_array_equal(p.leftover, [5, 9])
