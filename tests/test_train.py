"""Training substrate: optimizer, microbatch accumulation equivalence,
gradient compression, end-to-end loss decrease."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import forward_train, init_params
from repro.train.compression import (bf16_compress, compress_tree_int8,
                                     int8_quantize,
                                     make_error_feedback_state)
from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state,
                                   lr_at)
from repro.train.train_step import make_train_step


def test_adamw_converges_on_quadratic():
    cfg = get_config("stablelm-3b", smoke=True)      # dtype policy carrier
    ocfg = OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                     weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    opt = init_opt_state(params, cfg)
    target = jnp.array([1.0, 1.0, 1.0])
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw_update(g, params, opt, ocfg, cfg)
    np.testing.assert_allclose(np.array(params["w"]), np.array(target),
                               atol=0.05)


def test_lr_schedule_shape():
    ocfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                     min_lr_frac=0.1)
    lrs = [float(lr_at(jnp.asarray(s), ocfg)) for s in range(100)]
    assert lrs[0] == pytest.approx(1e-4)
    assert max(lrs) == pytest.approx(1e-3, rel=0.01)
    assert lrs[-1] >= 1e-4 * 0.9
    assert np.argmax(lrs) <= 11


def test_grad_accum_equivalence():
    """Medium-level horizontal partitioning: m microbatches of the same
    global batch give (numerically) the same update as m=1."""
    cfg1 = get_config("stablelm-3b", smoke=True).replace(
        grad_accum=1, remat_policy="none")
    cfg4 = cfg1.replace(grad_accum=4)
    params = init_params(cfg1, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, cfg1.vocab_size)}
    ocfg = OptConfig(total_steps=10)
    opt1 = init_opt_state(params, cfg1)
    opt4 = init_opt_state(params, cfg4)
    p1, _, m1 = make_train_step(cfg1, ocfg)(params, opt1, batch)
    p4, _, m4 = make_train_step(cfg4, ocfg)(params, opt4, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-2, atol=2e-5)


def test_loss_decreases_end_to_end():
    from repro.launch.train import train_loop
    cfg = get_config("stablelm-3b", smoke=True).replace(grad_accum=2)
    res = train_loop(cfg, steps=30, batch=8, seq_len=64, log_every=100)
    losses = res["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_int8_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    grads = {"g": g}
    err = make_error_feedback_state(grads)
    # accumulated quantized updates track the accumulated true gradient
    acc_q = np.zeros(256)
    for _ in range(20):
        deq, err = compress_tree_int8(grads, err)
        acc_q += np.array(deq["g"])
    acc_true = np.array(g) * 20
    # with error feedback the accumulated bias stays bounded by one quantum
    q_step = float(jnp.max(jnp.abs(g))) / 127.0
    assert np.max(np.abs(acc_q - acc_true)) < 2 * q_step * 20 ** 0.5 + 1e-3


def test_int8_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, scale = int8_quantize(x)
    err = np.abs(np.array(x) - np.array(q, np.float32) * float(scale))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_bf16_compress_is_2x_and_close():
    x = {"a": jnp.linspace(-1, 1, 1000, dtype=jnp.float32)}
    y = bf16_compress(x)
    assert y["a"].dtype == jnp.float32
    np.testing.assert_allclose(np.array(y["a"]), np.array(x["a"]),
                               atol=0.01)
