"""Property-based flow-equivalence harness for the cost-based optimizer.

A hypothesis-driven generator draws random single-source dataflow chains of
Filter / Lookup / Expression / Aggregate / Sort components (plus explicit
StageBoundary cuts) over synthetic columnar caches, then asserts that
running the flow with ``optimize_level=2`` — calibration, statistics-driven
graph rewriting, measured re-partitioning/re-planning — produces
BYTE-IDENTICAL sink output (same columns, same dtypes, same rows, same
order) as the untouched static flow.

The engine backend follows ``REPRO_BACKEND`` (the CI matrix runs this file
under both ``numpy`` and ``jax``), so every rewrite is exercised against
both operator backends.  ``REPRO_OPTEQ_EXAMPLES`` scales the example count
(default 100 per engine property, per the acceptance bar).
"""
import warnings

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover — env without the `test` extra
    from _hypothesis_compat import given, settings, st

from repro.core import (OptimizeOptions, OptimizedEngine, OrdinaryEngine,
                        StreamingEngine, config, partition)
from repro.core.component import StageBoundary
from repro.etl.components import (Aggregate, ArraySource, CollectSink,
                                  DimTable, Expression, Filter, Lookup, Sort)

N_EXAMPLES = config.opteq_examples()
ROWS = 400                 # fixed size keeps jitted-kernel shapes stable
KEYSPACE = 40


# ---------------------------------------------------------------------------
#  spec -> flow builder (rebuildable: engines mutate flows and sinks)
# ---------------------------------------------------------------------------
def build_flow(spec):
    """Construct a fresh Dataflow + sink from a drawn spec.  Deterministic:
    the same spec always builds the same flow over the same data."""
    seed, num_splits, ops = spec
    r = np.random.RandomState(seed)
    cols = {
        "k0": r.randint(1, KEYSPACE + 1, ROWS).astype(np.int64),
        "k1": r.randint(1, KEYSPACE + 1, ROWS).astype(np.int64),
        "g": r.randint(0, 4, ROWS).astype(np.int64),
        "v0": r.randint(0, 1000, ROWS).astype(np.int64),
        "v1": r.randint(-50, 50, ROWS).astype(np.int64),
    }
    from repro.core import Dataflow
    flow = Dataflow(f"rand-{seed}")
    comps = [ArraySource("src", cols)]
    avail = list(cols.keys())

    for i, op in enumerate(ops):
        kind = op[0]
        if kind == "filter":
            col_i, thresh, declared = op[1:]
            col = avail[col_i % len(avail)]
            reads = [col] if declared else None
            with warnings.catch_warnings():
                if not declared:
                    # the undeclared-reads path is deliberately part of the
                    # property space (rewrites must REFUSE on it) — silence
                    # the contract DeprecationWarning for these specs only
                    warnings.simplefilter("ignore", DeprecationWarning)
                comps.append(Filter(
                    f"filter{i}",
                    # default-arg binding: each lambda captures ITS column
                    lambda c, rows, col=col, t=thresh:
                        c.col(col)[rows] % 97 < t,
                    reads=reads))
        elif kind == "lookup":
            dim_seed, key_i, drop = op[1:]
            keyish = [c for c in avail if c.startswith("k")] or avail
            key = keyish[key_i % len(keyish)]
            rd = np.random.RandomState(dim_seed)
            nk = KEYSPACE if not drop else KEYSPACE // 2   # some unmatched
            dim = DimTable(np.arange(1, nk + 1, dtype=np.int64),
                           {"pay": rd.randint(0, 9, nk).astype(np.int64)})
            out = f"l{i}"
            comps.append(Lookup(f"lookup{i}", dim, key, {out: "pay"}))
            avail.append(out)
        elif kind == "expr":
            a_i, b_i, mul = op[1:]
            a, b = avail[a_i % len(avail)], avail[b_i % len(avail)]
            out = f"e{i}"
            if mul:
                fn = (lambda c, rows, a=a, b=b:
                      c.col(a)[rows] * (c.col(b)[rows] % 7 + 1))
            else:
                fn = (lambda c, rows, a=a, b=b:
                      c.col(a)[rows] + c.col(b)[rows])
            comps.append(Expression(f"expr{i}", out, fn, reads=[a, b]))
            avail.append(out)
        elif kind == "boundary":
            comps.append(StageBoundary(f"cut{i}"))
        elif kind == "agg":
            g_i, v_i, agg_op = op[1:]
            group = avail[g_i % len(avail)]
            val = avail[v_i % len(avail)]
            comps.append(Aggregate(f"agg{i}", [group],
                                   {f"a{i}": (val, agg_op)}))
            avail = [group, f"a{i}"]
        elif kind == "sort":
            by_i = op[1]
            comps.append(Sort(f"sort{i}", [avail[by_i % len(avail)]]))
    sink = CollectSink("sink")
    comps.append(sink)
    flow.chain(*comps)
    return flow, sink


@st.composite
def flow_spec(draw):
    seed = draw(st.integers(0, 10_000))
    num_splits = draw(st.sampled_from([1, 2, 4]))
    n_ops = draw(st.integers(1, 6))
    ops = []
    for _ in range(n_ops):
        kind = draw(st.sampled_from(
            ["filter", "lookup", "lookup", "expr", "expr", "boundary",
             "agg", "sort"]))
        if kind == "filter":
            ops.append(("filter", draw(st.integers(0, 9)),
                        draw(st.integers(10, 90)),
                        draw(st.sampled_from([True, True, False]))))
        elif kind == "lookup":
            ops.append(("lookup", draw(st.integers(0, 1000)),
                        draw(st.integers(0, 3)),
                        draw(st.sampled_from([True, False]))))
        elif kind == "expr":
            ops.append(("expr", draw(st.integers(0, 9)),
                        draw(st.integers(0, 9)),
                        draw(st.sampled_from([True, False]))))
        elif kind == "boundary":
            ops.append(("boundary",))
        elif kind == "agg":
            ops.append(("agg", draw(st.integers(0, 9)),
                        draw(st.integers(0, 9)),
                        draw(st.sampled_from(["sum", "min", "max", "count"]))))
        else:
            ops.append(("sort", draw(st.integers(0, 9))))
    return (seed, num_splits, ops)


# ---------------------------------------------------------------------------
#  the property
# ---------------------------------------------------------------------------
def _assert_byte_identical(spec, engine_cls):
    _, num_splits, _ = spec
    flow_s, sink_s = build_flow(spec)
    engine_cls(flow_s, OptimizeOptions(num_splits=num_splits)).run()
    static = sink_s.result()

    flow_a, sink_a = build_flow(spec)
    run = engine_cls(flow_a, OptimizeOptions(num_splits=num_splits,
                                             optimize_level=2,
                                             calibration_rows=128)).run()
    adaptive = sink_a.result()

    assert set(adaptive.keys()) == set(static.keys()), \
        f"column sets differ after rewrites {run.rewrites}"
    for k in static:
        assert adaptive[k].dtype == static[k].dtype, \
            f"dtype of {k} changed: {run.rewrites}"
        np.testing.assert_array_equal(
            adaptive[k], static[k],
            err_msg=f"column {k} differs after rewrites {run.rewrites} "
                    f"(spec={spec})")
    # the rewritten flow must still be a valid partitionable dataflow
    partition(flow_a)


@given(flow_spec())
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_rewritten_flow_equivalence_streaming(spec):
    """optimize_level=2 (calibrate + rewrite + re-plan) on the STREAMING
    engine is byte-identical to the static flow, for every generated DAG."""
    _assert_byte_identical(spec, StreamingEngine)


@given(flow_spec())
@settings(max_examples=max(N_EXAMPLES // 4, 10), deadline=None)
def test_rewritten_flow_equivalence_optimized(spec):
    """Same property on the non-streaming OptimizedEngine (exercises the
    remove-boundary path: cuts never pay off without streaming)."""
    _assert_byte_identical(spec, OptimizedEngine)


# ---------------------------------------------------------------------------
#  segment fusion: fused flows must be byte-identical too
# ---------------------------------------------------------------------------
def _assert_fused_identical(spec, engine_cls, adaptive=False):
    """Fusion (OptimizeOptions.fuse_segments) — alone or stacked on the
    optimize_level=2 adaptive rewrites — produces byte-identical sink output
    versus the untouched static flow, for every generated DAG."""
    _, num_splits, _ = spec
    flow_s, sink_s = build_flow(spec)
    # fuse_segments=False pins the baseline even under REPRO_FUSION=1
    engine_cls(flow_s, OptimizeOptions(num_splits=num_splits,
                                       fuse_segments=False)).run()
    static = sink_s.result()

    flow_f, sink_f = build_flow(spec)
    opts = OptimizeOptions(num_splits=num_splits, fuse_segments=True)
    if adaptive:
        opts = OptimizeOptions(num_splits=num_splits, fuse_segments=True,
                               optimize_level=2, calibration_rows=128)
    run = engine_cls(flow_f, opts).run()
    fused = sink_f.result()

    assert set(fused.keys()) == set(static.keys()), \
        f"column sets differ after rewrites {run.rewrites}"
    for k in static:
        assert fused[k].dtype == static[k].dtype, \
            f"dtype of {k} changed: {run.rewrites}"
        np.testing.assert_array_equal(
            fused[k], static[k],
            err_msg=f"column {k} differs after rewrites {run.rewrites} "
                    f"(spec={spec})")
    partition(flow_f)


@given(flow_spec())
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_fused_flow_equivalence_streaming(spec):
    """Segment fusion on the STREAMING engine is byte-identical to the
    static flow, for every generated DAG (both backends via REPRO_BACKEND)."""
    _assert_fused_identical(spec, StreamingEngine)


@given(flow_spec())
@settings(max_examples=max(N_EXAMPLES // 4, 10), deadline=None)
def test_fused_adaptive_flow_equivalence_streaming(spec):
    """Fusion stacked on the full optimize_level=2 adaptive path (commutes,
    expression fusion, boundary cuts, re-planning) stays byte-identical."""
    _assert_fused_identical(spec, StreamingEngine, adaptive=True)


def test_fused_equivalence_all_rules_fire_together():
    spec = (7, 4, [("lookup", 3, 0, True),
                   ("expr", 3, 4, False),
                   ("expr", 5, 0, True),
                   ("filter", 4, 30, True),
                   ("agg", 2, 5, "sum"),
                   ("sort", 0)])
    _assert_fused_identical(spec, StreamingEngine, adaptive=True)


def test_fused_equivalence_undeclared_reads_fall_back():
    """Undeclared read sets force the whole-cache upload fallback on device
    backends — results must still be byte-identical."""
    spec = (13, 4, [("lookup", 5, 1, False), ("filter", 2, 40, False),
                    ("expr", 1, 6, False)])
    _assert_fused_identical(spec, StreamingEngine)


# ---------------------------------------------------------------------------
#  deterministic regressions: shapes the generator rarely lands on exactly
# ---------------------------------------------------------------------------
def test_equivalence_all_rules_fire_together():
    """One flow where commute + fusion + boundary-insert can all apply."""
    spec = (7, 4, [("lookup", 3, 0, True),
                   ("expr", 3, 4, False),
                   ("expr", 5, 0, True),
                   ("filter", 4, 30, True),
                   ("agg", 2, 5, "sum"),
                   ("sort", 0)])
    _assert_byte_identical(spec, StreamingEngine)


def test_equivalence_boundary_only_chain():
    spec = (11, 2, [("boundary",), ("expr", 0, 3, True), ("boundary",)])
    _assert_byte_identical(spec, StreamingEngine)


def test_equivalence_filter_drops_everything():
    # threshold 10 over % 97 keeps ~10%; two stacked filters can drop all
    spec = (3, 2, [("filter", 3, 10, True), ("filter", 4, 10, True),
                   ("agg", 1, 2, "count")])
    _assert_byte_identical(spec, StreamingEngine)


def test_equivalence_single_component_flow():
    spec = (5, 1, [])
    _assert_byte_identical(spec, StreamingEngine)


# ---------------------------------------------------------------------------
#  AST-vs-lambda: DSL-built SSB flows are byte-identical to the legacy
#  lambda-built flows — both backends, every engine, levels 0/2, fused and
#  unfused (the api_redesign acceptance matrix)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def ssb_dsl_data():
    from repro.etl.ssb import generate
    return generate(lineorder_rows=3000, customers=200, suppliers=40,
                    parts=150, seed=17)


def _dsl_backends():
    from repro.core import available_backends, get_backend
    out = ["numpy"]
    if "jax" in available_backends():
        try:
            get_backend("jax")
            out.append("jax")
        except Exception:      # pragma: no cover — jax present in-container
            pass
    return out


#: (engine, optimize_level, fuse_segments); the ordinary baseline has no
#: optimizer/fusion knobs
_DSL_MATRIX = [("ordinary", None, None)] + [
    (eng, lvl, fuse)
    for eng in ("optimized", "streaming")
    for lvl in (0, 2)
    for fuse in (False, True)]


@pytest.mark.parametrize("qname", ["Q1.1", "Q2.1", "Q3.1", "Q4.1", "Q4.1s"])
def test_dsl_vs_lambda_ssb_byte_identical(qname, ssb_dsl_data):
    """Every SSB builder constructed via the expression DSL produces
    byte-identical sink output to the pre-DSL lambda builder, on both
    operator backends, across Ordinary/Optimized/Streaming engines at
    optimize levels 0 and 2 with segment fusion off and on."""
    from repro.etl import BUILDERS
    for backend in _dsl_backends():
        for engine, level, fuse in _DSL_MATRIX:
            tables = {}
            for use_dsl in (True, False):
                qf = BUILDERS[qname](ssb_dsl_data, use_dsl=use_dsl)
                if engine == "ordinary":
                    OrdinaryEngine(qf.flow, chunk_rows=1024,
                                   backend=backend).run()
                else:
                    cls = (StreamingEngine if engine == "streaming"
                           else OptimizedEngine)
                    cls(qf.flow, OptimizeOptions(
                        num_splits=2, backend=backend,
                        optimize_level=level, calibration_rows=256,
                        fuse_segments=fuse)).run()
                tables[use_dsl] = qf.sink.result()
            label = f"{qname}/{backend}/{engine}/lvl={level}/fuse={fuse}"
            dsl_t, lam_t = tables[True], tables[False]
            assert set(dsl_t) == set(lam_t), f"{label}: column sets differ"
            for k in lam_t:
                assert dsl_t[k].dtype == lam_t[k].dtype, \
                    f"{label}: dtype of {k} differs"
                np.testing.assert_array_equal(
                    dsl_t[k], lam_t[k],
                    err_msg=f"{label}: column {k} differs (DSL vs lambda)")


# ---------------------------------------------------------------------------
#  kernel impl routes: the hash-join probe and the dense radix groupby must
#  be byte-identical to the legacy searchsorted/sort routes AND to the
#  numpy-backend oracle, across the same property harness
# ---------------------------------------------------------------------------
def _run_with_impls(spec, backend, join_impl, groupby_impl, fuse=False):
    import os
    _, num_splits, _ = spec
    saved = {k: os.environ.get(k)
             for k in (config.ENV_JOIN_IMPL, config.ENV_GROUPBY_IMPL)}
    os.environ[config.ENV_JOIN_IMPL] = join_impl
    os.environ[config.ENV_GROUPBY_IMPL] = groupby_impl
    try:
        flow, sink = build_flow(spec)
        StreamingEngine(flow, OptimizeOptions(
            num_splits=num_splits, backend=backend,
            fuse_segments=fuse)).run()
        return sink.result()
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _assert_tables_equal(got, oracle, label, check_dtype=True):
    """check_dtype=False for cross-backend comparisons: jax computes narrow
    ints where numpy keeps int64 (a backend property, not a route property)
    — there the oracle is the VALUES, not the width."""
    assert set(got) == set(oracle), f"{label}: column sets differ"
    for k in oracle:
        if check_dtype:
            assert got[k].dtype == oracle[k].dtype, f"{label}: dtype of {k}"
        np.testing.assert_array_equal(got[k], oracle[k],
                                      err_msg=f"{label}: column {k}")


@given(flow_spec())
@settings(max_examples=max(N_EXAMPLES // 4, 10), deadline=None)
def test_kernel_impl_routes_byte_identical(spec):
    """For every generated DAG: the jax backend under the hash-probe +
    dense-groupby routes produces byte-identical sinks to the legacy
    searchsorted + sort routes and to the numpy-backend oracle."""
    if "jax" not in _dsl_backends():      # pragma: no cover
        pytest.skip("jax backend unavailable")
    oracle = _run_with_impls(spec, "numpy", "searchsorted", "sort")
    legacy = _run_with_impls(spec, "jax", "searchsorted", "sort")
    kernel = _run_with_impls(spec, "jax", "reference", "reference")
    # within-backend: new routes vs legacy routes, dtypes strict
    _assert_tables_equal(kernel, legacy, f"kernel-vs-legacy (spec={spec})")
    # cross-backend: values vs the numpy oracle (int widths differ by design)
    _assert_tables_equal(kernel, oracle, f"kernel-vs-oracle (spec={spec})",
                         check_dtype=False)


def test_kernel_impl_interpret_route_fused():
    """The Pallas kernel BODIES (interpret mode) behind the same flows, with
    segment fusion on — the fused runner inlines the hash probe, the
    Aggregate rides the dense groupby."""
    if "jax" not in _dsl_backends():      # pragma: no cover
        pytest.skip("jax backend unavailable")
    spec = (7, 4, [("lookup", 3, 0, True),
                   ("expr", 3, 4, False),
                   ("filter", 4, 30, True),
                   ("agg", 2, 5, "sum"),
                   ("sort", 0)])
    legacy = _run_with_impls(spec, "jax", "searchsorted", "sort")
    got = _run_with_impls(spec, "jax", "interpret", "interpret", fuse=True)
    _assert_tables_equal(got, legacy, "interpret-routes+fusion")


# ---------------------------------------------------------------------------
#  sharded execution: hash/range-partitioned N-shard runs (partial →
#  shuffle → merge, core/shard) must be byte-identical to the serial run
#  for every generated DAG — the backend follows REPRO_BACKEND, so the CI
#  matrix exercises this under both numpy and jax
# ---------------------------------------------------------------------------
def _assert_sharded_identical(spec, shards, fuse=False):
    _, num_splits, _ = spec
    flow_s, sink_s = build_flow(spec)
    StreamingEngine(flow_s, OptimizeOptions(num_splits=num_splits,
                                            fuse_segments=fuse)).run()
    serial = sink_s.result()

    flow_n, sink_n = build_flow(spec)
    run = StreamingEngine(flow_n, OptimizeOptions(
        num_splits=num_splits, fuse_segments=fuse,
        shards=shards, shard_impl="inline")).run()
    sharded = sink_n.result()

    label = f"spec={spec} shards={shards} fuse={fuse}"
    assert set(sharded) == set(serial), f"{label}: column sets differ"
    for k in serial:
        assert sharded[k].dtype == serial[k].dtype, \
            f"{label}: dtype of {k} differs"
        np.testing.assert_array_equal(
            sharded[k], serial[k], err_msg=f"{label}: column {k} differs")
    if run.shards > 1:
        # every source row lands in exactly one shard
        assert sum(run.shard_rows) == ROWS, label


@given(flow_spec(), st.sampled_from([1, 2, 3]),
       st.sampled_from([False, True]))
@settings(max_examples=max(N_EXAMPLES // 4, 10), deadline=None)
def test_sharded_flow_equivalence(spec, shards, fuse):
    """For every generated DAG, running partitioned over N shards (N=1 is
    the serial fast path) produces byte-identical sink output to serial,
    with and without segment fusion stacked on top."""
    _assert_sharded_identical(spec, shards, fuse)


def test_sharded_equivalence_all_rules_fire_together():
    """Deterministic shape where lookup/expr/filter/agg/sort all appear —
    the aggregate is keyed on a source column, so this exercises the HASH
    partitioning mode (group-disjoint shards)."""
    spec = (7, 4, [("lookup", 3, 0, True),
                   ("expr", 3, 4, False),
                   ("filter", 4, 30, True),
                   ("agg", 2, 5, "sum"),
                   ("sort", 0)])
    _assert_sharded_identical(spec, 3)


def test_sharded_equivalence_boundary_and_empty():
    spec = (11, 2, [("boundary",), ("expr", 0, 3, True), ("boundary",)])
    _assert_sharded_identical(spec, 2)
    # two stacked filters can drop every row of a shard
    spec = (3, 2, [("filter", 3, 10, True), ("filter", 4, 10, True),
                   ("agg", 1, 2, "count")])
    _assert_sharded_identical(spec, 3)


# ---------------------------------------------------------------------------
#  fault tolerance: under any seeded plan of TRANSIENT faults the retried
#  run produces byte-identical sink output to the fault-free run — chunk
#  replay, run-level replay, edge faults and arena degradation all covered,
#  fused and unfused, both backends via REPRO_BACKEND
# ---------------------------------------------------------------------------
@st.composite
def fault_rules(draw):
    """1-3 transient single-fire rules.  Component is left None (fusion
    renames components, and the property must hold wherever the fault
    lands); per-rule count=1 keeps the worst-case failures at one dispatch
    (all rules hitting the same chunk) within the default REPRO_RETRY_MAX."""
    n = draw(st.integers(1, 3))
    rules = []
    for _ in range(n):
        rules.append(dict(
            site=draw(st.sampled_from(["chunk", "chunk", "kernel", "edge",
                                       "arena"])),
            kind="transient", count=1,
            after=draw(st.integers(0, 4)),
            split=draw(st.sampled_from([None, None, 0, 1]))))
    return rules


def _assert_fault_tolerant(spec, rule_kws, fuse):
    import os

    from repro.core import faults
    _, num_splits, _ = spec
    flow_b, sink_b = build_flow(spec)
    StreamingEngine(flow_b, OptimizeOptions(num_splits=num_splits,
                                            fuse_segments=fuse)).run()
    baseline = sink_b.result()

    saved = os.environ.get(config.ENV_RETRY_BACKOFF)
    os.environ[config.ENV_RETRY_BACKOFF] = "0.001"
    # the exact-attribution assertion below needs OUR plan to be the only
    # fault source — drop any ambient plan (the CI chaos leg exports one)
    saved_faults = os.environ.pop(config.ENV_FAULTS, None)
    try:
        plan = faults.FaultPlan([faults.FaultRule(**kw) for kw in rule_kws],
                                seed=1)
        flow_f, sink_f = build_flow(spec)
        with faults.fault_scope(plan):
            run = StreamingEngine(flow_f, OptimizeOptions(
                num_splits=num_splits, fuse_segments=fuse)).run()
        faulty = sink_f.result()
    finally:
        if saved is None:
            os.environ.pop(config.ENV_RETRY_BACKOFF, None)
        else:
            os.environ[config.ENV_RETRY_BACKOFF] = saved
        if saved_faults is not None:
            os.environ[config.ENV_FAULTS] = saved_faults
    label = f"spec={spec} rules={rule_kws} fuse={fuse}"
    assert set(faulty) == set(baseline), f"{label}: column sets differ"
    for k in baseline:
        assert faulty[k].dtype == baseline[k].dtype, \
            f"{label}: dtype of {k} differs"
        np.testing.assert_array_equal(
            faulty[k], baseline[k],
            err_msg=f"{label}: column {k} differs under fault plan")
    # every fired rule is attributed to the run's counters
    assert run.faults_injected == plan.injected, label


@given(flow_spec(), fault_rules(), st.sampled_from([True, False]))
@settings(max_examples=max(N_EXAMPLES // 4, 10), deadline=None)
def test_transient_fault_plans_byte_identical(spec, rule_kws, fuse):
    """For every generated DAG and every seeded transient fault plan, the
    retried/degraded run's sink output is byte-identical to fault-free."""
    _assert_fault_tolerant(spec, rule_kws, fuse)


def test_fault_plan_run_level_replay_deterministic():
    """Source + accumulate + edge faults all escalate to run-level replay
    (none is replay_safe); the rerun is byte-identical — a deterministic
    shape the generator rarely lands on exactly."""
    spec = (7, 4, [("lookup", 3, 0, True),
                   ("expr", 3, 4, False),
                   ("boundary",),
                   ("filter", 4, 30, True),
                   ("agg", 2, 5, "sum"),
                   ("sort", 0)])
    rules = [dict(site="chunk", kind="transient", count=1, after=0),
             dict(site="edge", kind="transient", count=1),
             dict(site="chunk", kind="transient", count=1, after=7)]
    _assert_fault_tolerant(spec, rules, fuse=True)


def test_dsl_flows_report_no_undeclared_refusals(ssb_dsl_data):
    """On DSL-built SSB flows the cost-based optimizer never refuses a
    rewrite for an undeclared read/write set (provenance is derived from
    the AST) — the silent-opt-out failure mode of the lambda API."""
    from repro.etl import BUILDERS
    for qname in ("Q1.1", "Q2.1", "Q3.1", "Q4.1", "Q4.1s"):
        qf = BUILDERS[qname](ssb_dsl_data, use_dsl=True)
        run = StreamingEngine(qf.flow, OptimizeOptions(
            num_splits=2, optimize_level=2, calibration_rows=256,
            fuse_segments=True)).run()
        bad = [r for r in run.refusals if "undeclared" in r["detail"]]
        assert not bad, f"{qname}: undeclared-read refusals on a DSL flow: {bad}"
