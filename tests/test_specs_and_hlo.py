"""launch/: sharding-spec divisibility, kv_repeat selection, HLO cost
walker unit behaviour, serve server."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.hlo_cost import (analyze_hlo_text, parse_computations,
                                   shape_elems_bytes)
from repro.launch.specs import kv_repeat_for, limit_spec


class _FakeMesh:
    shape = {"data": 16, "model": 16}


def test_limit_spec_drops_indivisible_axes():
    mesh = _FakeMesh()
    sds = jax.ShapeDtypeStruct((1280, 504), jnp.float32)
    spec = limit_spec(P("data", "model"), sds, mesh)
    assert spec == P("data", None)          # 504 % 16 != 0
    sds2 = jax.ShapeDtypeStruct((1280, 512), jnp.float32)
    assert limit_spec(P("data", "model"), sds2, mesh) == P("data", "model")


def test_limit_spec_tuple_axes():
    mesh = _FakeMesh()
    sds = jax.ShapeDtypeStruct((64, 8), jnp.float32)
    # ('data','model') = 256 does not divide 64 -> dropped
    assert limit_spec(P(("data", "model"), None), sds, mesh) == P(None, None)


def test_kv_repeat_selection():
    # kh=8, h=64 -> r=2 (kh_eff=16, G_eff stays even)
    assert kv_repeat_for(get_config("qwen2-72b"), 16) == 2
    # kh=8, h=40 -> kh*2=16 but 40 % 16 != 0 -> no replication
    assert kv_repeat_for(get_config("qwen2.5-32b"), 16) == 1
    # MQA kv=1, h=48 -> r=16 divides h (48 % 16 == 0)
    assert kv_repeat_for(get_config("granite-20b"), 16) == 16
    # already divisible
    assert kv_repeat_for(get_config("stablelm-3b"), 16) == 1
    assert kv_repeat_for(get_config("hubert-xlarge"), 16) == 1
    # attn-free
    assert kv_repeat_for(get_config("falcon-mamba-7b"), 16) == 1


SAMPLE_HLO = """\
HloModule test, num_partitions=4

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %c1 = s32[] constant(1)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ni = s32[] add(%i, %c1)
  %ar = f32[8,8]{1,0} all-reduce(%x), replica_groups=[2,2]<=[4], to_apply=%sum
  %d = f32[8,8]{1,0} dot(%ar, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_walker_trip_counts_and_collectives():
    cost = analyze_hlo_text(SAMPLE_HLO, 4)
    # dot: 2*8*8*8 = 1024 flops/iter + add + compare, 5 iterations
    assert cost.flops == pytest.approx((1024 + 1 + 1) * 5)
    assert cost.while_trip_counts == [5]
    # one all-reduce of 256 bytes per iteration: wire = 2*(g-1)/g*256 = 256
    assert cost.collectives.counts["all-reduce"] == 5
    assert cost.collectives.wire_bytes["all-reduce"] == pytest.approx(
        5 * 2 * 256 * (2 - 1) / 2)
    assert cost.collectives.operand_bytes["all-reduce"] == 5 * 256


def test_hlo_walker_known_trip_count_attr():
    txt = SAMPLE_HLO.replace(
        'condition=%cond, body=%body',
        'condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"9"}}')
    cost = analyze_hlo_text(txt, 4)
    assert cost.while_trip_counts == [9]


def test_shape_elems_bytes():
    assert shape_elems_bytes("f32[8,8]{1,0}") == (64, 256)
    assert shape_elems_bytes("bf16[2,3]") == (6, 12)
    assert shape_elems_bytes("(f32[4], s32[])") == (5, 20)
    assert shape_elems_bytes("pred[]") == (1, 1)


def test_parse_computations_entry_alias():
    comps = parse_computations(SAMPLE_HLO)
    assert "__entry__" in comps
    assert comps["__entry__"].name == "main"


def test_batched_server_matches_generate():
    from repro.launch.serve import BatchedServer, Request
    from repro.models import init_params
    from repro.train.serve_step import generate

    cfg = get_config("stablelm-3b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size, (2, 16)).astype(np.int32)
    server = BatchedServer(cfg, params=params, batch=2)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=6) for i in range(2)]
    done = server.run(reqs)
    ref = np.array(generate(params, cfg, jnp.asarray(prompts), 6))
    got = np.array([r.out_tokens for r in done])
    np.testing.assert_array_equal(got, ref)
