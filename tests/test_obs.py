"""Observability: tracer scoping, metric reconciliation, Perfetto export,
the attribution report, and the zero-cost disabled path."""
import json

import numpy as np
import pytest

from repro.core import (OptimizedEngine, OptimizeOptions, OrdinaryEngine,
                        StreamingEngine)
from repro.core.executor import SharedWorkerPool
from repro.etl.queries import build_q4
from repro.etl.ssb import generate
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace


@pytest.fixture(scope="module")
def data():
    return generate(lineorder_rows=5000, customers=100, suppliers=40,
                    parts=60, seed=7)


# ---------------------------------------------------------------------------
#  Metrics registry
# ---------------------------------------------------------------------------
def test_metrics_registry_counters_gauges_histograms():
    m = obs_metrics.MetricsRegistry()
    m.inc("a")
    m.inc("a", 4)
    m.gauge_set("g", 2.5)
    m.gauge_max("hw", 3)
    m.gauge_max("hw", 1)           # max keeps the high water
    m.observe("lat", 0.001)
    m.observe("lat", 0.002)
    snap = m.snapshot()
    assert snap["counters"]["a"] == 5
    assert snap["gauges"]["g"] == 2.5
    assert snap["gauges"]["hw"] == 3
    h = snap["histograms"]["lat"]
    assert h["count"] == 2
    assert h["sum_s"] == pytest.approx(0.003)
    assert sum(n for _, n in h["buckets"]) + h["overflow"] == 2


def test_histogram_bucket_monotone():
    h = obs_metrics.Histogram()
    for s in (1e-6, 1e-4, 1e-2, 1.0):
        h.observe(s)
    snap = h.snapshot()
    les = [le for le, _ in snap["buckets"]]
    assert les == sorted(les)
    assert snap["min_s"] == pytest.approx(1e-6)
    assert snap["max_s"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
#  Tracer scoping
# ---------------------------------------------------------------------------
def test_trace_scope_disabled_is_null():
    assert not obs_trace.active()
    s1 = obs_trace.span("compute", "x")
    s2 = obs_trace.span("compute", "y")
    assert s1 is s2                      # shared no-op singleton: no alloc
    with s1:
        pass


def test_trace_scope_records_spans_and_nests():
    with obs_trace.trace_scope() as outer:
        with obs_trace.span("phase", "outer-span"):
            with obs_trace.trace_scope() as inner:
                with obs_trace.span("compute", "inner-span", rows=3):
                    pass
    names = [e["name"] for e in outer.events]
    assert "outer-span" in names and "inner-span" in names   # outer sees all
    assert [e["name"] for e in inner.events] == ["inner-span"]
    ev = inner.events[0]
    assert ev["ph"] == "X" and ev["cat"] == "compute"
    assert ev["args"]["rows"] == 3
    assert ev["dur"] >= 0
    assert not obs_trace.active()


def test_scope_propagates_through_worker_pool():
    """SharedWorkerPool runs tasks under the submitter's contextvars, so a
    span emitted on a pool thread lands in the submitting scope's tracer."""
    pool = SharedWorkerPool(width=2, name="obs-test")
    try:
        with obs_trace.trace_scope() as tr:
            fut = pool.submit(lambda: obs_trace.complete(
                "compute", "pool-task", 0.0, 0.001))
            fut.result()
        assert [e["name"] for e in tr.events] == ["pool-task"]
        assert tr.events[0]["tid"] != 0
    finally:
        pool.shutdown()


def test_run_scope_yields_none_when_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    with obs_trace.run_scope(flow="f") as tr:
        assert tr is None


# ---------------------------------------------------------------------------
#  Engine integration + exact reconciliation
# ---------------------------------------------------------------------------
def _reconcile(run):
    c = run.metrics.get("counters", {})
    for field in ("copies", "bytes_copied", "h2d_transfers", "h2d_bytes",
                  "d2h_transfers", "d2h_bytes", "dispatch_calls",
                  "arena_hits", "arena_misses", "arena_bytes_reused"):
        assert c.get(field, 0) == getattr(run, field), field


@pytest.mark.parametrize("engine_cls", [OptimizedEngine, StreamingEngine])
def test_engine_metrics_reconcile_exactly(data, engine_cls):
    qf = build_q4(data, staged=engine_cls is StreamingEngine)
    with obs_trace.trace_scope() as tr:
        run = engine_cls(qf.flow, OptimizeOptions(num_splits=4)).run()
    _reconcile(run)
    # every component dispatch produced exactly one compute span
    dispatch_spans = [e for e in tr.events if e["cat"] == "compute"
                     and not (e.get("args") or {}).get("phase")]
    assert len(dispatch_spans) == run.dispatch_calls
    # the execute phase span exists and has real width
    phases = [e["name"] for e in tr.events if e["cat"] == "phase"]
    assert "execute" in phases and "plan" in phases
    # run identity is present
    assert len(run.run_id) == 32
    assert run.created.endswith("+00:00")
    # gauges were derived
    g = run.metrics["gauges"]
    assert g["pool_width"] >= 1
    assert "arena_pooled_bytes" in g


def test_ordinary_engine_traces_and_reconciles(data):
    qf = build_q4(data)
    with obs_trace.trace_scope():
        run = OrdinaryEngine(qf.flow, chunk_rows=2048).run()
    _reconcile(run)
    assert run.copies > 0                  # copy-everywhere baseline
    assert run.metrics["counters"]["copies"] == run.copies


def test_adaptive_run_calibration_outside_measure_window(data):
    """optimize_level=2 calibrates inside the tracer scope but OUTSIDE the
    metric window: dispatch_calls must still reconcile exactly."""
    qf = build_q4(data)
    with obs_trace.trace_scope() as tr:
        run = OptimizedEngine(qf.flow, OptimizeOptions(
            num_splits=2, optimize_level=2, calibration_rows=512)).run()
    _reconcile(run)
    phases = [e["name"] for e in tr.events if e["cat"] == "phase"]
    for expect in ("calibrate", "optimize", "plan", "execute"):
        assert expect in phases, expect


def test_untraced_run_has_empty_metrics(data, monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    qf = build_q4(data)
    run = OptimizedEngine(qf.flow, OptimizeOptions(num_splits=2)).run()
    assert run.metrics == {}
    assert run.trace_file is None
    assert len(run.run_id) == 32           # identity is always on


# ---------------------------------------------------------------------------
#  Export + report
# ---------------------------------------------------------------------------
def test_trace_file_export_and_report(data, tmp_path, monkeypatch):
    path = tmp_path / "trace.json"
    monkeypatch.setenv("REPRO_TRACE", "1")
    monkeypatch.setenv("REPRO_TRACE_PATH", str(path))
    qf = build_q4(data)
    run = OptimizedEngine(qf.flow, OptimizeOptions(num_splits=2)).run()
    assert run.trace_file == str(path)

    payload = json.loads(path.read_text())
    events = payload["traceEvents"]
    assert events, "empty trace"
    # Chrome-trace shape: process metadata + X spans with ts/dur
    assert any(e.get("ph") == "M" and e.get("name") == "process_name"
               for e in events)
    spans = [e for e in events if e.get("ph") == "X"]
    assert all("ts" in e and "dur" in e for e in spans)
    run_meta = payload["otherData"]["runs"]
    assert run_meta and run_meta[-1]["run_id"] == run.run_id

    result = obs_report.analyze(payload)
    rep = result["runs"][-1]
    assert rep["meta"]["run_id"] == run.run_id
    cats = rep["categories"]
    assert cats["compute"] > 0             # self-time µs per class
    assert set(rep["components"])           # per-component attribution
    text = obs_report.render(result)
    assert "compute" in text and run.run_id[:8] in text

    # CLI entry point: --json round trip
    rc = obs_report.main([str(path), "--json"])
    assert rc == 0


def test_trace_file_accumulates_runs_as_processes(data, tmp_path,
                                                  monkeypatch):
    path = tmp_path / "multi.json"
    monkeypatch.setenv("REPRO_TRACE", "1")
    monkeypatch.setenv("REPRO_TRACE_PATH", str(path))
    r1 = OptimizedEngine(build_q4(data).flow,
                         OptimizeOptions(num_splits=2)).run()
    r2 = StreamingEngine(build_q4(data, staged=True).flow,
                         OptimizeOptions(num_splits=2)).run()
    payload = json.loads(path.read_text())
    pids = {e["pid"] for e in payload["traceEvents"] if e.get("ph") == "X"}
    ids = [m["run_id"] for m in payload["otherData"]["runs"]]
    assert len(pids) >= 2                   # one Perfetto process per run
    assert r1.run_id in ids and r2.run_id in ids


def test_report_self_time_subtracts_nesting():
    """A child span's time is attributed to the child, not double-counted
    in the parent (stack-based self-time)."""
    with obs_trace.trace_scope() as tr:
        obs_trace.complete("phase", "parent", 0.0, 0.010)
        obs_trace.complete("compute", "child", 0.002, 0.004)
    tr.meta = {"run_id": "x" * 32}
    payload = {"traceEvents": tr.to_chrome(pid=1),
               "otherData": {"runs": [tr.meta]}}
    rep = obs_report.analyze(payload)["runs"][0]
    assert rep["categories"]["overhead"] == pytest.approx(6000, rel=0.01)
    assert rep["categories"]["compute"] == pytest.approx(4000, rel=0.01)
    # 10ms parent minus the 4ms nested child = 6ms coordination overhead


# ---------------------------------------------------------------------------
#  Disabled-path cost guard
# ---------------------------------------------------------------------------
def test_results_identical_traced_vs_untraced(data):
    qf1 = build_q4(data)
    run1 = OptimizedEngine(qf1.flow, OptimizeOptions(num_splits=4)).run()
    base = qf1.sink.result()
    qf2 = build_q4(data)
    with obs_trace.trace_scope():
        run2 = OptimizedEngine(qf2.flow, OptimizeOptions(num_splits=4)).run()
    got = qf2.sink.result()
    assert set(got) == set(base)
    for k in base:
        np.testing.assert_array_equal(got[k], base[k])
    # instrumentation must not change the deterministic counters either
    for field in ("copies", "bytes_copied", "h2d_transfers", "d2h_transfers",
                  "dispatch_calls"):
        assert getattr(run1, field) == getattr(run2, field), field


# ---------------------------------------------------------------------------
#  Bounded retention (resident serving must not leak trace memory)
# ---------------------------------------------------------------------------
def test_tracer_event_cap_rotates_oldest_half():
    tr = obs_trace.Tracer(max_events=100)
    for i in range(1000):
        tr.emit("X", "t", f"ev{i}", ts_us=float(i), dur_us=1.0)
    assert len(tr.events) <= 100
    assert tr.dropped_events == 1000 - len(tr.events)
    # the SURVIVORS are the newest events, in order
    names = [e["name"] for e in tr.events]
    assert names == [f"ev{i}" for i in range(1000 - len(names), 1000)]


def test_tracer_cap_zero_disables_rotation():
    tr = obs_trace.Tracer(max_events=0)
    for i in range(500):
        tr.emit("X", "t", "e", ts_us=float(i))
    assert len(tr.events) == 500 and tr.dropped_events == 0


def test_tracer_cap_defaults_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_MAX_EVENTS", "7")
    assert obs_trace.Tracer().max_events == 7


def test_trace_file_rotates_oldest_runs(tmp_path, monkeypatch):
    """The process trace file keeps at most REPRO_TRACE_MAX_EVENTS events
    across runs: old runs rotate out, the newest run always survives."""
    monkeypatch.setenv("REPRO_TRACE_MAX_EVENTS", "50")
    path = tmp_path / "rot.json"
    tf = obs_trace._TraceFile()
    for r in range(10):
        tr = obs_trace.Tracer(name=f"run{r}", max_events=0)
        tr.meta = {"flow": f"run{r}"}
        for i in range(20):
            tr.emit("X", "t", "e", ts_us=float(i), dur_us=1.0)
        tf.add_and_flush(tr, str(path))
    assert tf.rotated_runs == 8              # 10 runs of 20 events, cap 50
    payload = json.loads(path.read_text())
    kept = [m["flow"] for m in payload["otherData"]["runs"]]
    assert kept == ["run8", "run9"]          # newest runs retained, in order
    assert payload["otherData"]["rotated_runs"] == 8


def test_trace_file_keeps_oversized_newest_run(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_MAX_EVENTS", "10")
    path = tmp_path / "big.json"
    tf = obs_trace._TraceFile()
    small = obs_trace.Tracer(name="small", max_events=0)
    small.emit("X", "t", "e", ts_us=0.0)
    tf.add_and_flush(small, str(path))
    big = obs_trace.Tracer(name="big", max_events=0)
    big.meta = {"flow": "big"}
    for i in range(100):                     # alone it already exceeds the cap
        big.emit("X", "t", "e", ts_us=float(i))
    tf.add_and_flush(big, str(path))
    payload = json.loads(path.read_text())
    assert [m["flow"] for m in payload["otherData"]["runs"]] == ["big"]
