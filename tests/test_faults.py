"""Fault-injection subsystem units: taxonomy, FaultPlan grammar and
plan-lifetime accounting, retry backoff, degradation ladders (sticky
routes, REPRO_DEGRADE gate), serving dead letters + long-lived faulty
session survival, pool shutdown leak accounting, and chunk
snapshot/restore.

Engine-level byte-equality under fault plans lives in
``test_fusion.py`` / ``test_optimizer_equivalence.py``; this file pins
the primitives those properties are built from.
"""
import threading
import time

import numpy as np
import pytest

import repro
from repro.core import config, faults
from repro.core.executor import SharedWorkerPool
from repro.core.faults import (FaultPlan, FaultRule, PermanentFault,
                               PoisonFault, TransientFault, backoff_schedule,
                               classify, fault_recorder, fault_scope,
                               restore_cache, retry_call, snapshot_cache,
                               with_retries)
from repro.core.shared_cache import SharedCache
from repro.session import replay_deltas


@pytest.fixture(autouse=True)
def _no_ambient_faults(monkeypatch):
    """These units assert EXACT fire/retry counts, so an ambient process-wide
    plan (the CI chaos leg exports REPRO_FAULTS) must not add injections."""
    monkeypatch.delenv("REPRO_FAULTS", raising=False)


# ---------------------------------------------------------------------------
#  taxonomy
# ---------------------------------------------------------------------------
def test_classify_injected_faults_carry_their_kind():
    assert classify(TransientFault("x")) == "transient"
    assert classify(PermanentFault("x")) == "permanent"
    assert classify(PoisonFault("x")) == "poison"


def test_classify_real_exceptions():
    for exc in (ConnectionError("net"), TimeoutError("slow"),
                InterruptedError("sig"), OSError("io")):
        assert classify(exc) == "transient"
    for exc in (ValueError("logic"), KeyError("k"), RuntimeError("r"),
                ZeroDivisionError()):
        assert classify(exc) == "permanent"


# ---------------------------------------------------------------------------
#  FaultPlan grammar + accounting
# ---------------------------------------------------------------------------
def test_plan_parse_full_grammar():
    plan = FaultPlan.parse(
        "seed=7; chunk@filt:kind=transient,count=2,after=1,split=3;"
        " kernel:kind=poison,p=0.5; arena:delay=0.01")
    assert plan.seed == 7 and len(plan.rules) == 3
    r0, r1, r2 = plan.rules
    assert (r0.site, r0.component, r0.kind) == ("chunk", "filt", "transient")
    assert (r0.count, r0.after, r0.split) == (2, 1, 3)
    assert (r1.site, r1.component, r1.kind, r1.p) == ("kernel", None,
                                                      "poison", 0.5)
    assert (r2.site, r2.kind, r2.delay_s) == ("arena", "transient", 0.01)


def test_plan_parse_rejects_unknowns():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan.parse("disk:kind=transient")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("chunk:kind=flaky")
    with pytest.raises(ValueError, match="unknown fault-rule option"):
        FaultPlan.parse("chunk:bogus=1")


def test_rule_matching_component_split_after_count():
    plan = FaultPlan([FaultRule("chunk", component="filt", kind="transient",
                                count=2, after=1, split=0)])
    with fault_scope(plan):
        faults.inject("chunk", component="other", split=0)   # wrong component
        faults.inject("chunk", component="filt", split=1)    # wrong split
        faults.inject("kernel", component="filt", split=0)   # wrong site
        faults.inject("chunk", component="filt", split=0)    # seen=1 <= after
        with pytest.raises(TransientFault):
            faults.inject("chunk", component="filt", split=0)
        with pytest.raises(TransientFault):
            faults.inject("chunk", component="filt", split=0)
        faults.inject("chunk", component="filt", split=0)    # count exhausted
    assert plan.injected == 2
    assert plan.rules[0].fired == 2 and plan.rules[0].seen == 4


def test_plan_reset_restores_fresh_lifetime():
    plan = FaultPlan.parse("seed=5; chunk:kind=transient,count=1")
    with fault_scope(plan):
        with pytest.raises(TransientFault):
            faults.inject("chunk")
        faults.inject("chunk")                               # spent
    assert plan.injected == 1
    plan.reset()
    assert plan.injected == 0 and plan.rules[0].fired == 0
    with fault_scope(plan), pytest.raises(TransientFault):
        faults.inject("chunk")


def test_probabilistic_rule_is_seed_deterministic():
    def fires(seed):
        plan = FaultPlan([FaultRule("chunk", kind="transient", count=100,
                                    p=0.5)], seed=seed)
        out = []
        with fault_scope(plan):
            for _ in range(32):
                try:
                    faults.inject("chunk")
                    out.append(0)
                except TransientFault:
                    out.append(1)
        return out
    a, b = fires(11), fires(11)
    assert a == b                      # same seed => same firing pattern
    assert 0 < sum(a) < 32             # and p=0.5 actually skips some
    assert fires(12) != a


def test_delay_rule_sleeps_instead_of_raising():
    plan = FaultPlan([FaultRule("chunk", kind="transient", delay_s=0.02)])
    with fault_scope(plan):
        t0 = time.perf_counter()
        faults.inject("chunk")         # must NOT raise
        assert time.perf_counter() - t0 >= 0.015
    assert plan.injected == 1


def test_env_plan_installed_via_repro_faults(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "chunk:kind=permanent,count=1")
    assert faults.active()
    with pytest.raises(PermanentFault):
        faults.inject("chunk")
    faults.inject("chunk")             # plan-lifetime: spent for the process
    monkeypatch.delenv("REPRO_FAULTS")
    assert not faults.active()


# ---------------------------------------------------------------------------
#  retry helpers
# ---------------------------------------------------------------------------
def test_backoff_schedule_doubles_and_caps():
    assert backoff_schedule(5, 0.1) == [0.1, 0.2, 0.4, 0.8, 1.6]
    assert backoff_schedule(7, 0.1)[-2:] == [2.0, 2.0]   # capped
    assert backoff_schedule(0, 0.1) == []


def test_retry_call_retries_transient_until_success():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientFault("flaky")
        return "ok"

    with fault_recorder() as rec:
        assert retry_call(flaky, max_retries=3, backoff=0.0) == "ok"
    assert len(calls) == 3
    assert [r["attempt"] for r in rec.retries] == [0, 1]


def test_retry_call_permanent_raises_immediately():
    calls = []

    def broken():
        calls.append(1)
        raise ValueError("logic bug")

    with pytest.raises(ValueError):
        retry_call(broken, max_retries=5, backoff=0.0)
    assert len(calls) == 1


def test_retry_call_exhaustion_reraises_last():
    def always():
        raise TransientFault("never up")

    with pytest.raises(TransientFault):
        retry_call(always, max_retries=2, backoff=0.0)


def test_with_retries_filter_and_shim():
    from repro.train.fault import with_retries as train_with_retries
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 2:
            raise OSError("io")
        return 7

    assert with_retries(flaky, backoff=0.0)() == 7
    # the train-module shim delegates to the core implementation with its
    # historical (RuntimeError, OSError) filter
    calls.clear()
    assert train_with_retries(flaky, backoff=0.0)() == 7
    with pytest.raises(KeyError):      # outside retry_on: no retry
        with_retries(lambda: (_ for _ in ()).throw(KeyError("k")),
                     backoff=0.0)()


# ---------------------------------------------------------------------------
#  snapshot / restore
# ---------------------------------------------------------------------------
def test_snapshot_restore_rewinds_and_bumps_version():
    c = SharedCache({"a": np.arange(8, dtype=np.int64)}, 8)
    v0 = c.version
    snap = snapshot_cache(c)
    c.columns["a"][:] = -1
    c.columns["b"] = np.zeros(8, dtype=np.int64)
    c.n = 4
    restore_cache(c, snap)
    assert c.n == 8 and set(c.columns) == {"a"}
    np.testing.assert_array_equal(c.columns["a"], np.arange(8))
    assert c.version > v0              # device views must be invalidated
    # restored buffers are fresh — mutating the snapshot can't corrupt them
    snap["cols"]["a"][:] = 99
    np.testing.assert_array_equal(c.columns["a"], np.arange(8))


# ---------------------------------------------------------------------------
#  degradation ladders (jax kernel routes)
# ---------------------------------------------------------------------------
def _jax_backend():
    try:
        from repro.core.backend.jax_backend import JaxBackend
        return JaxBackend()
    except Exception:                  # pragma: no cover — no jax in env
        pytest.skip("jax backend unavailable")


def test_degraded_impl_walks_ladder_and_sticks():
    bk = _jax_backend()
    with fault_recorder() as rec:
        assert bk._degraded_impl("join", "auto", ValueError("x")) == "interpret"
        assert bk._join_route == "interpret"
        assert bk._degraded_impl(
            "join", "interpret", ValueError("x")) == "reference"
        assert bk._degraded_impl(
            "join", "reference", ValueError("x")) == "searchsorted"
        # ladder floor: nothing below searchsorted
        assert bk._degraded_impl("join", "searchsorted", ValueError("x")) is None
        assert bk._join_route == "searchsorted"
    assert [d.dst for d in rec.degradations] == ["interpret", "reference",
                                                 "searchsorted"]
    assert all(d.kind == "kernel" for d in rec.degradations)


def test_degraded_impl_propagates_transient_and_injected():
    bk = _jax_backend()
    # transient => replay retries the SAME route instead of degrading
    assert bk._degraded_impl("join", "pallas", TransientFault("t")) is None
    assert bk._degraded_impl("join", "pallas", ConnectionError("t")) is None
    # injected permanent/poison faults must abort, not silently degrade
    assert bk._degraded_impl("groupby", "pallas", PermanentFault("p")) is None
    assert bk._degraded_impl("groupby", "pallas", PoisonFault("p")) is None
    assert bk._join_route is None and bk._groupby_route is None


def test_degrade_disabled_by_env(monkeypatch):
    monkeypatch.setenv("REPRO_DEGRADE", "0")
    bk = _jax_backend()
    assert bk._degraded_impl("join", "pallas", ValueError("x")) is None
    assert bk._join_route is None


# ---------------------------------------------------------------------------
#  serving: tick retries, dead letters, long-lived faulty session
# ---------------------------------------------------------------------------
def _serve_flow(rows=0, seed=0):
    r = np.random.RandomState(seed)
    data = {"k": r.randint(0, 5, rows).astype(np.int64),
            "v": r.randint(0, 100, rows).astype(np.int64)}
    schema = {c: a[:0] for c, a in data.items()}
    f = (repro.flow("faulty-serve").source(schema)
         .derive("e", repro.col("v") + 1)
         .aggregate(["k"], {"out": ("e", "sum"), "cnt": ("e", "count")})
         .sink())
    return f, data


def _tick_cols(seed, rows=40):
    r = np.random.RandomState(seed)
    return {"k": r.randint(0, 5, rows).astype(np.int64),
            "v": r.randint(0, 100, rows).astype(np.int64)}


def test_serving_transient_tick_retried_not_double_counted(monkeypatch):
    monkeypatch.setenv("REPRO_RETRY_BACKOFF", "0.001")
    f, _ = _serve_flow()
    session = repro.Session(metadata=None)
    plan = FaultPlan.parse("tick:kind=transient,count=2")
    with session.serve(f) as srv, fault_scope(plan):
        deltas = [srv.tick(_tick_cols(s)) for s in range(3)]
    assert plan.injected == 2
    assert sum(t.retries for t in deltas) == 2
    assert not any(t.dead_lettered for t in deltas)
    # the retried ticks' aggregates were rolled back before replay: the
    # replayed deltas equal a clean one-shot run of the same rows
    ref_f, _ = _serve_flow()
    with session.serve(ref_f) as ref_srv:
        ref = [ref_srv.tick(_tick_cols(s)) for s in range(3)]
    got, want = replay_deltas(deltas), replay_deltas(ref)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_serving_poison_tick_dead_lettered_session_survives():
    f, _ = _serve_flow()
    session = repro.Session(metadata=None)
    plan = FaultPlan.parse("tick:kind=poison,count=1")
    with session.serve(f) as srv:
        with fault_scope(plan):
            bad = srv.tick(_tick_cols(0))
        good = srv.tick(_tick_cols(1))
    assert bad.dead_lettered and bad.delta == {}
    assert len(srv.dead_letters) == 1
    dl = srv.dead_letters[0]
    assert dl["attempts"] == 1         # poison: no pointless retries
    np.testing.assert_array_equal(dl["columns"]["k"], _tick_cols(0)["k"])
    assert not good.dead_lettered      # the stream moved on
    assert srv.dead_letters.maxlen == config.DEAD_LETTER_MAX


def test_serving_dead_letter_buffer_is_bounded():
    f, _ = _serve_flow()
    session = repro.Session(metadata=None)
    n = config.DEAD_LETTER_MAX + 20
    plan = FaultPlan([FaultRule("tick", kind="poison", count=n)])
    with session.serve(f) as srv, fault_scope(plan):
        for s in range(n):
            assert srv.tick(_tick_cols(s, rows=4)).dead_lettered
    assert len(srv.dead_letters) == config.DEAD_LETTER_MAX
    # oldest entries were evicted, newest kept (identified by their columns)
    np.testing.assert_array_equal(srv.dead_letters[0]["columns"]["v"],
                                  _tick_cols(20, rows=4)["v"])
    np.testing.assert_array_equal(srv.dead_letters[-1]["columns"]["v"],
                                  _tick_cols(n - 1, rows=4)["v"])


def test_serving_survives_long_mixed_fault_run(monkeypatch):
    """~60 ticks with interleaved transient and poison faults: the session
    must stay alive throughout, and the surviving deltas must replay to
    exactly the clean-run aggregate over the surviving rows."""
    monkeypatch.setenv("REPRO_RETRY_BACKOFF", "0.0001")
    f, _ = _serve_flow()
    session = repro.Session(metadata=None)
    plan = FaultPlan([
        FaultRule("tick", kind="transient", count=100, p=0.3),
        FaultRule("tick", kind="poison", count=100, p=0.1),
    ], seed=42)
    deltas, survived = [], []
    with session.serve(f) as srv, fault_scope(plan):
        for s in range(60):
            t = srv.tick(_tick_cols(s, rows=20))
            deltas.append(t)
            if not t.dead_lettered:
                survived.append(s)
    assert plan.injected > 0                         # the run was actually hit
    assert len(survived) < 60 or plan.injected >= 1
    ref_f, _ = _serve_flow()
    with session.serve(ref_f) as ref_srv:
        ref = [ref_srv.tick(_tick_cols(s, rows=20)) for s in survived]
    got, want = replay_deltas(deltas), replay_deltas(ref)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


# ---------------------------------------------------------------------------
#  pool shutdown accounting (no silent thread leaks)
# ---------------------------------------------------------------------------
def test_pool_shutdown_joins_cleanly_by_default():
    pool = SharedWorkerPool(2, name="t-clean")
    futs = [pool.submit(lambda: time.sleep(0.01)) for _ in range(4)]
    for fut in futs:
        fut.result()
    pool.shutdown()
    assert pool.leaked_threads == 0
    assert pool.stats()["leaked_threads"] == 0


def test_pool_shutdown_counts_and_warns_on_stragglers():
    release = threading.Event()
    pool = SharedWorkerPool(1, name="t-straggler", join_timeout=0.05)
    pool.submit(release.wait)
    time.sleep(0.05)                   # let the worker pick the task up
    try:
        with pytest.warns(RuntimeWarning, match="did not join"):
            pool.shutdown(wait=True)
        assert pool.leaked_threads == 1
        assert pool.stats()["leaked_threads"] == 1
    finally:
        release.set()                  # unblock the straggler for real
