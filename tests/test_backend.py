"""Operator-backend subsystem tests.

1. Registry/selection semantics (names, env var, per-engine override).
2. Backend equivalence: every operator kernel's jax result equals the numpy
   reference on randomized inputs (hypothesis where available, fallback shim
   otherwise).
3. SharedCache edge cases: empty compact mask, zero-row split, `take` with
   reordering / out-of-window indices / duplicate-gather growth, and
   `concat_caches` column-set mismatch reporting.
4. Device-resident columns: a full query under the jax backend with
   host<->device transfer accounting.
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover — env without the `test` extra
    from _hypothesis_compat import given, settings, st

from repro.core import OptimizeOptions, StreamingEngine
from repro.core.backend import (available_backends, get_backend,
                                get_default_backend, resolve_backend,
                                set_default_backend)
from repro.core.shared_cache import (GLOBAL_CACHE_STATS, SharedCache,
                                     concat_caches)
from repro.etl import BUILDERS
from repro.etl.components import DimTable


def _np():
    return get_backend("numpy")


def _jax():
    return get_backend("jax")


def _host(bk, x):
    return np.asarray(bk.to_host(x))


# ---------------------------------------------------------------- registry
def test_registry_and_selection(monkeypatch):
    assert {"numpy", "jax"} <= set(available_backends())
    assert get_backend("numpy").name == "numpy"
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("tensorflow")
    # explicit name wins over everything
    monkeypatch.setenv("REPRO_BACKEND", "jax")
    assert resolve_backend("numpy").name == "numpy"
    # env var picks the default
    assert resolve_backend(None).name == "jax"
    monkeypatch.delenv("REPRO_BACKEND")
    # set_default_backend overrides the builtin default
    set_default_backend("jax")
    try:
        assert get_default_backend().name == "jax"
    finally:
        set_default_backend(None)


def test_backend_instances_are_singletons():
    assert get_backend("numpy") is get_backend("numpy")
    assert get_backend("jax") is get_backend("jax")


def test_dtype_width_canonicalization():
    # numpy reports native widths; jax canonicalizes 64-bit to 32-bit (x64 off)
    assert _np().dtype_width(np.int64) == 8
    assert _jax().dtype_width(np.int64) == 4
    assert _jax().dtype_width(np.float64) == 4
    cols = {"a": np.zeros(10, dtype=np.int64)}
    assert _np().est_nbytes(cols) == 80
    assert _jax().est_nbytes(cols) == 40


def test_etl_config_engine_options_carry_backend():
    from repro.configs.ssb_etl import ETLConfig
    cfg = ETLConfig(backend="jax")
    opts = cfg.engine_options()
    assert opts.backend == "jax"
    assert opts.num_splits == cfg.num_splits
    assert cfg.engine_options(backend="numpy").backend == "numpy"


def test_chunk_sensitive_source_ignores_backend_alignment(ssb_tiny):
    from repro.data import InputPipeline, PipelineConfig
    # the synthetic LM source is chunk-sensitive: identical batches under
    # both backends even though jax plans aligned chunk sizes
    pc = PipelineConfig(seq_len=32, global_batch=2, vocab_size=100,
                        docs_per_window=64, num_splits=4, pipeline_degree=2,
                        max_doc_len=48, min_doc_len=4, seed=9)
    batches = {}
    for bname in ("numpy", "jax"):
        set_default_backend(bname)
        try:
            it = iter(InputPipeline(pc))
            batches[bname] = [next(it) for _ in range(2)]
        finally:
            set_default_backend(None)
    for a, b in zip(batches["numpy"], batches["jax"]):
        np.testing.assert_array_equal(a, b)


def test_batch_align_feeds_planner_chunk_rows(ssb_tiny):
    from repro.core import backend_chunk_rows
    qf = BUILDERS["Q4.1"](ssb_tiny)
    assert backend_chunk_rows(qf.flow, 4, _np()) is None
    chunk = backend_chunk_rows(qf.flow, 4, _jax())
    align = _jax().batch_align
    assert chunk % align == 0
    assert chunk >= ssb_tiny.lineorder["lo_orderkey"].size // 4


# ------------------------------------------------- kernel equivalence (jax)
def _rand_cache(r):
    n = r.randint(1, 400)
    rng = np.random.default_rng(r.randint(0, 2**31))
    return SharedCache({
        "a": rng.integers(-50, 50, n).astype(np.int64),
        "b": rng.integers(0, 1000, n).astype(np.int64),
        "f": rng.uniform(-1e3, 1e3, n).astype(np.float64),
    }, n)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_filter_mask_equivalence(seed):
    import random
    c = _rand_cache(random.Random(seed))
    pred = lambda ca, r: (ca.col("a")[r] % 3 == 0) & (ca.col("b")[r] > 100)
    rows = slice(0, c.n)
    m_np = _np().filter_mask(pred, c, rows)
    m_jax = _host(_jax(), _jax().filter_mask(pred, c, rows))
    np.testing.assert_array_equal(m_np, m_jax)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_eval_expression_equivalence(seed):
    import random
    c = _rand_cache(random.Random(seed))
    fn = lambda ca, r: ca.col("a")[r] * 2 + ca.col("b")[r]
    rows = slice(0, c.n)
    np.testing.assert_array_equal(
        _np().eval_expression(fn, c, rows),
        _host(_jax(), _jax().eval_expression(fn, c, rows)))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_searchsorted_probe_and_gather_equivalence(seed):
    rng = np.random.default_rng(seed)
    n_dim = int(rng.integers(1, 100))
    keys = np.unique(rng.integers(0, 500, n_dim)).astype(np.int64)
    payload = {"v": rng.integers(0, 10_000, len(keys)).astype(np.int64)}
    qual = rng.random(len(keys)) < 0.7
    dim = DimTable(keys, payload, row_filter=qual)
    vals = rng.integers(0, 500, int(rng.integers(1, 300))).astype(np.int64)

    i_np, m_np = _np().searchsorted_probe(dim, vals)
    g_np = _np().lookup_gather(dim, "v", i_np, m_np, -1)
    i_j, m_j = _jax().searchsorted_probe(dim, vals)
    g_j = _host(_jax(), _jax().lookup_gather(dim, "v", i_j, m_j, -1))
    np.testing.assert_array_equal(m_np, _host(_jax(), m_j))
    np.testing.assert_array_equal(g_np, g_j)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_groupby_reduce_equivalence(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 500))
    keys = [rng.integers(0, 6, n).astype(np.int64),
            rng.integers(0, 4, n).astype(np.int64)]
    vals = rng.integers(-1000, 1000, n).astype(np.int64)
    aggs = {"s": (vals, "sum"), "mn": (vals, "min"), "mx": (vals, "max"),
            "av": (vals, "avg"), "ct": (vals, "count")}
    gk_np, ag_np = _np().groupby_reduce(keys, aggs, n)
    gk_j, ag_j = _jax().groupby_reduce(keys, aggs, n)
    for a, b in zip(gk_np, gk_j):
        np.testing.assert_array_equal(a, _host(_jax(), b))
    np.testing.assert_array_equal(ag_np["ct"], _host(_jax(), ag_j["ct"]))
    np.testing.assert_array_equal(ag_np["mn"], _host(_jax(), ag_j["mn"]))
    np.testing.assert_array_equal(ag_np["mx"], _host(_jax(), ag_j["mx"]))
    # float32 accumulation on device vs float64 reference
    rtol = _jax().oracle_rtol
    np.testing.assert_allclose(ag_np["s"], _host(_jax(), ag_j["s"]), rtol=rtol)
    np.testing.assert_allclose(ag_np["av"], _host(_jax(), ag_j["av"]), rtol=rtol)


def test_groupby_reduce_global_group():
    vals = np.array([1.0, 2.0, 3.0, 4.0])
    for bk in (_np(), _jax()):
        gk, ag = bk.groupby_reduce([], {"s": (vals, "sum"),
                                        "ct": (vals, "count")}, len(vals))
        assert gk == []
        assert float(_host(bk, ag["s"])[0]) == 10.0
        assert int(_host(bk, ag["ct"])[0]) == 4


def test_aggregate_global_empty_aggs_one_row():
    from repro.etl.components import Aggregate
    out = Aggregate("a", [], {}).finish(
        [SharedCache({"v": np.array([1.0, 2.0])}, 2)])
    assert out.n == 1 and out.names == []


def test_est_nbytes_counts_multidim_columns():
    cols = {"tokens": np.zeros((10, 32), dtype=np.int32)}
    assert _np().est_nbytes(cols) == 10 * 32 * 4


def test_device_view_shared_across_ranges_and_invalidated():
    bk = _jax()
    c = SharedCache({"x": np.arange(64, dtype=np.int64)}, 64)
    pred = lambda ca, r: ca.col("x")[r] % 2 == 0
    before = GLOBAL_CACHE_STATS.snapshot()
    bk.filter_mask(pred, c, slice(0, 32))
    mid = GLOBAL_CACHE_STATS.snapshot()
    bk.filter_mask(pred, c, slice(32, 64))     # same cache version: no upload
    after = GLOBAL_CACHE_STATS.snapshot()
    assert mid["h2d_bytes"] > before["h2d_bytes"]
    assert after["h2d_bytes"] == mid["h2d_bytes"]
    # mutation bumps version -> stale view dropped, column re-uploaded
    c.compact(np.ones(64, dtype=bool))
    m = bk.filter_mask(pred, c, slice(0, c.n))
    assert GLOBAL_CACHE_STATS.snapshot()["h2d_bytes"] > after["h2d_bytes"]
    np.testing.assert_array_equal(_host(bk, m), np.arange(64) % 2 == 0)


def test_groupby_reduce_rejects_unknown_op():
    for bk in (_np(), _jax()):
        with pytest.raises(ValueError, match="unknown agg op"):
            bk.groupby_reduce([np.zeros(3, np.int64)],
                              {"x": (np.zeros(3), "median")}, 3)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_sort_rows_equivalence(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 400))
    keys = [rng.integers(0, 5, n).astype(np.int64),
            rng.integers(0, 7, n).astype(np.int64)]
    for ascending in (True, False):
        o_np = _np().sort_rows(keys, ascending=ascending)
        o_j = _host(_jax(), _jax().sort_rows(keys, ascending=ascending))
        # both lexsorts are stable => identical permutations
        np.testing.assert_array_equal(o_np, o_j)


# ------------------------------------------------------- SharedCache edges
def test_compact_empty_mask():
    c = SharedCache({"x": np.arange(10)}, 10)
    c.compact(np.zeros(10, dtype=bool))
    assert c.n == 0
    assert len(c.col("x")) == 0


def test_split_zero_rows():
    c = SharedCache({"x": np.array([], dtype=np.int64)}, 0)
    splits = c.split(4)
    assert len(splits) == 1
    assert splits[0].n == 0


def test_take_reorders_in_place():
    c = SharedCache({"x": np.arange(5, dtype=np.int64)}, 5)
    buf = c.columns["x"]
    c.take(np.array([4, 3, 2, 1, 0]))
    np.testing.assert_array_equal(c.col("x"), [4, 3, 2, 1, 0])
    assert c.columns["x"] is buf          # same buffer: shared caching


def test_take_rejects_out_of_window_indices():
    # buffer longer than the valid window: index into the stale tail must
    # raise, not silently read stale rows
    c = SharedCache({"x": np.arange(10, dtype=np.int64)}, 10)
    c.compact(np.arange(10) < 4)          # n=4; rows 4..9 are stale
    with pytest.raises(IndexError, match="valid row window"):
        c.take(np.array([0, 5]))
    with pytest.raises(IndexError, match="valid row window"):
        c.take(np.array([-5]))


def test_take_duplicate_gather_grows_buffer_explicitly():
    c = SharedCache({"x": np.arange(4, dtype=np.int64)}, 4)
    c.take(np.array([0, 1, 2, 3, 0, 1, 2, 3]))     # k > n: explicit grow
    assert c.n == 8
    np.testing.assert_array_equal(c.col("x"), [0, 1, 2, 3, 0, 1, 2, 3])
    assert len(c.columns["x"]) == 8


def test_take_rejects_boolean_mask():
    c = SharedCache({"x": np.arange(4)}, 4)
    with pytest.raises(TypeError, match="integer indices"):
        c.take(np.array([True, False, True, False]))


def test_concat_caches_reports_column_mismatch():
    a = SharedCache({"x": np.array([1]), "y": np.array([2])}, split_index=0)
    b = SharedCache({"x": np.array([3]), "z": np.array([4])}, split_index=1)
    with pytest.raises(ValueError) as ei:
        concat_caches([a, b])
    msg = str(ei.value)
    assert "cache #1" in msg and "'y'" in msg and "'z'" in msg


# ----------------------------------------------------- device columns (jax)
def test_device_columns_in_cache_roundtrip():
    bk = _jax()
    c = SharedCache({"h": np.arange(8, dtype=np.int64),
                     "d": bk.asarray(np.arange(8, dtype=np.int64) * 10)}, 8)
    c.compact(np.asarray(np.arange(8) % 2 == 0))
    assert c.n == 4
    np.testing.assert_array_equal(c.col("h"), [0, 2, 4, 6])
    np.testing.assert_array_equal(_host(bk, c.col("d")), [0, 20, 40, 60])
    c.take(np.array([3, 2, 1, 0]))
    out = c.to_dict()
    np.testing.assert_array_equal(out["h"], [6, 4, 2, 0])
    np.testing.assert_array_equal(out["d"], [60, 40, 20, 0])
    assert all(isinstance(v, np.ndarray) for v in out.values())


def test_jax_engine_run_records_transfers(ssb_tiny):
    before = GLOBAL_CACHE_STATS.snapshot()
    qf = BUILDERS["Q4.1"](ssb_tiny)
    expect = qf.oracle(ssb_tiny)
    r = StreamingEngine(qf.flow, OptimizeOptions(num_splits=2,
                                                 backend="jax")).run()
    got = qf.sink.result()
    assert r.backend == "jax"
    rtol = _jax().oracle_rtol
    for k in expect:
        np.testing.assert_allclose(got[k], expect[k], rtol=rtol,
                                   err_msg=f"Q4.1 jax column {k}")
    after = GLOBAL_CACHE_STATS.snapshot()
    # device kernels must have moved bytes host->device (and the engine run
    # must surface them — the §3 copy-cost analogue for the device tier)
    assert r.h2d_bytes > 0
    assert after["h2d_bytes"] - before["h2d_bytes"] >= r.h2d_bytes
    # backend-aligned source chunking came from the runtime plan
    assert r.runtime_plan.chunk_rows is not None
    assert r.runtime_plan.chunk_rows % _jax().batch_align == 0


def test_numpy_engine_reference_unchanged(ssb_tiny):
    qf = BUILDERS["Q4.1"](ssb_tiny)
    expect = qf.oracle(ssb_tiny)
    r = StreamingEngine(qf.flow, OptimizeOptions(num_splits=2,
                                                 backend="numpy")).run()
    got = qf.sink.result()
    assert r.backend == "numpy"
    for k in expect:
        np.testing.assert_allclose(got[k], expect[k], rtol=1e-9)
    assert r.h2d_bytes == 0 and r.d2h_bytes == 0
