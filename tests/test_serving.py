"""Resident serving: ``Session.serve`` micro-batch ingestion with
incremental aggregates, plus the long-lived-session bug sweep.

The core property: feeding a table through ``serve()`` tick by tick and
replaying the emitted deltas (``replay_deltas``) is BYTE-IDENTICAL to the
one-shot streaming batch run of the same flow — on the active backend
(the CI matrix runs this file under both ``numpy`` and ``jax``), fused and
unfused, for hypothesis-generated flows and deterministic regressions.

The long-lived-session sweep pins the bugs a per-run CLI never surfaces:
unbounded tracer growth, sink pollution after an aborted tick, stale
split-gate state across ticks, arena buffers acquired in one run and
released in another.
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover — env without the `test` extra
    from _hypothesis_compat import given, settings, st

import repro
from repro.core import GLOBAL_ARENA, config
from repro.core.shared_cache import CacheStats, cache_stats_scope
from repro.session import replay_deltas

ROWS = 400
KEYSPACE = 30
N_EXAMPLES = max(config.opteq_examples() // 5, 10)


# ---------------------------------------------------------------------------
#  spec -> (serve flow, batch flow) builders
# ---------------------------------------------------------------------------
def _make_data(seed, rows=ROWS):
    r = np.random.RandomState(seed)
    # bounded integer values: every partial sum a serving tick can merge
    # stays exactly representable in float32 (< 2^24), so incremental
    # tick-by-tick accumulation is bit-identical to the one-shot reduction
    return {
        "k0": r.randint(1, KEYSPACE + 1, rows).astype(np.int64),
        "g": r.randint(0, 5, rows).astype(np.int64),
        "v0": r.randint(0, 100, rows).astype(np.int64),
        "v1": r.randint(-50, 50, rows).astype(np.int64),
    }


def _dim(dim_seed, drop):
    rd = np.random.RandomState(dim_seed)
    nk = KEYSPACE if not drop else KEYSPACE // 2    # some unmatched keys
    return (np.arange(1, nk + 1, dtype=np.int64),
            {"pay": rd.randint(0, 9, nk).astype(np.int64)})


def build_serving_flow(spec, data, empty_source):
    """Construct a fresh Flow from a drawn spec.  Deterministic: the same
    spec always builds the same flow; ``empty_source=True`` builds the
    serving variant (schema-only source, fed via ticks)."""
    seed, ops, agg = spec
    src = ({c: a[:0] for c, a in data.items()} if empty_source else data)
    b = repro.flow(f"serve-{seed}").source(src)
    avail = list(data.keys())
    for i, op in enumerate(ops):
        kind = op[0]
        if kind == "filter":
            col_i, thresh = op[1:]
            col = avail[col_i % len(avail)]
            b = b.filter(repro.col(col) % 97 < thresh)
        elif kind == "lookup":
            dim_seed, key_i, drop = op[1:]
            key = avail[key_i % len(avail)]
            out = f"l{i}"
            b = b.lookup(_dim(dim_seed, drop), key, {out: "pay"})
            avail.append(out)
        elif kind == "derive":
            a_i, b_i, mul = op[1:]
            a, c = avail[a_i % len(avail)], avail[b_i % len(avail)]
            out = f"e{i}"
            # factor capped at 3: chained multiplying derives must keep every
            # per-group partial sum < 2^24 so float32 accumulation (jax) is
            # exact and tick-by-tick merging stays byte-identical
            expr = (repro.col(a) * (repro.col(c) % 3 + 1) if mul
                    else repro.col(a) + repro.col(c))
            b = b.derive(out, expr)
            avail.append(out)
    group_by = None
    if agg is not None:
        g_i, v_i, agg_op = agg
        group = avail[g_i % len(avail)]
        val = avail[v_i % len(avail)]
        aggs = {"out": (val, agg_op), "cnt": (val, "count")}
        b = b.aggregate([group], aggs)
        group_by = [group]
    return b.sink(), group_by


@st.composite
def serve_spec(draw):
    seed = draw(st.integers(0, 10_000))
    n_ops = draw(st.integers(0, 4))
    ops = []
    for _ in range(n_ops):
        kind = draw(st.sampled_from(["filter", "lookup", "derive", "derive"]))
        if kind == "filter":
            ops.append(("filter", draw(st.integers(0, 9)),
                        draw(st.integers(10, 90))))
        elif kind == "lookup":
            ops.append(("lookup", draw(st.integers(0, 1000)),
                        draw(st.integers(0, 3)),
                        draw(st.sampled_from([True, False]))))
        else:
            ops.append(("derive", draw(st.integers(0, 9)),
                        draw(st.integers(0, 9)),
                        draw(st.sampled_from([True, False]))))
    agg = None
    if draw(st.sampled_from([True, False])):
        agg = (draw(st.integers(0, 9)), draw(st.integers(0, 9)),
               draw(st.sampled_from(["sum", "avg", "min", "max", "count"])))
    return (seed, ops, agg)


def _serve_vs_batch(spec, ticks=3, fuse=None, **serve_opts):
    seed, _, _ = spec
    data = _make_data(seed)

    batch, group_by = build_serving_flow(spec, data, empty_source=False)
    session = repro.Session(metadata=None)
    ref = session.run(batch, engine="streaming", fuse=fuse).table

    serve_f, _ = build_serving_flow(spec, data, empty_source=True)
    splits = np.array_split(np.arange(ROWS), ticks)
    deltas = []
    with session.serve(serve_f, fuse=fuse, **serve_opts) as srv:
        for idx in splits:
            deltas.append(srv.tick({c: a[idx] for c, a in data.items()}))
        srv.close()

    rep = replay_deltas(deltas, group_by=group_by)
    if not ref or not len(next(iter(ref.values()))):
        total = sum(r.rows_out for r in deltas)
        assert total == 0, f"batch empty but serve emitted {total} rows"
        return
    assert set(rep) == set(ref), f"column sets differ (spec={spec})"
    for k in ref:
        assert rep[k].dtype == ref[k].dtype, \
            f"dtype of {k}: {rep[k].dtype} != {ref[k].dtype} (spec={spec})"
        assert rep[k].tobytes() == ref[k].tobytes(), \
            f"column {k} differs from the batch run (spec={spec})"


# ---------------------------------------------------------------------------
#  the property: serve == batch, byte for byte
# ---------------------------------------------------------------------------
@given(serve_spec())
@settings(max_examples=N_EXAMPLES, deadline=None)
def test_serve_replay_byte_identical_to_batch(spec):
    """Replaying a serving session's per-tick deltas reproduces the one-shot
    batch run byte-for-byte, for every generated flow (active backend via
    REPRO_BACKEND; fusion follows REPRO_FUSION)."""
    _serve_vs_batch(spec)


@given(serve_spec())
@settings(max_examples=max(N_EXAMPLES // 2, 5), deadline=None)
def test_serve_replay_byte_identical_fused(spec):
    """Same property with segment fusion forced ON (compiled segment
    kernels resident across ticks)."""
    _serve_vs_batch(spec, fuse=True)


# -------------------------------------------------- deterministic regressions
def test_serve_all_agg_ops_single_and_many_ticks():
    """Every aggregate op through serving upserts, one tick and many."""
    for agg_op in ("sum", "avg", "min", "max", "count"):
        for ticks in (1, 4):
            _serve_vs_batch((17, [("lookup", 3, 0, True),
                                  ("derive", 2, 4, True)],
                             (1, 5, agg_op)), ticks=ticks)


def test_serve_row_sync_flow_appends_in_tick_order():
    """No terminal aggregate: deltas are pure appends; concatenating them in
    tick order IS the batch output."""
    _serve_vs_batch((23, [("filter", 2, 55), ("derive", 0, 2, False)], None),
                    ticks=4)


def test_serve_empty_ticks_and_filter_drops_everything():
    data = _make_data(31)
    spec = (31, [("filter", 2, 1)], (1, 2, "sum"))   # ~1% survive
    serve_f, group_by = build_serving_flow(spec, data, empty_source=True)
    session = repro.Session(metadata=None)
    deltas = []
    with session.serve(serve_f) as srv:
        r = srv.tick({c: a[:0] for c, a in data.items()})   # fully empty tick
        assert r.rows_in == 0 and r.rows_out == 0
        deltas.append(r)
        for idx in np.array_split(np.arange(ROWS), 3):
            deltas.append(srv.tick({c: a[idx] for c, a in data.items()}))
    batch, _ = build_serving_flow(spec, data, empty_source=False)
    ref = session.run(batch, engine="streaming").table
    rep = replay_deltas(deltas, group_by=group_by)
    if len(next(iter(ref.values()))):
        for k in ref:
            assert rep[k].tobytes() == ref[k].tobytes(), k
    else:
        assert sum(r.rows_out for r in deltas) == 0


def test_serve_varying_tick_sizes():
    """Ragged micro-batches (every tick a different row count) stay
    byte-identical — the pow2 layout bucketing keeps the jitted shapes
    bounded but must not change results."""
    data = _make_data(41)
    spec = (41, [("derive", 2, 3, True)], (0, 4, "sum"))
    serve_f, group_by = build_serving_flow(spec, data, empty_source=True)
    session = repro.Session(metadata=None)
    sizes = [7, 130, 1, 90, 172]
    assert sum(sizes) == ROWS
    bounds = np.cumsum([0] + sizes)
    deltas = []
    with session.serve(serve_f) as srv:
        for lo, hi in zip(bounds, bounds[1:]):
            deltas.append(srv.tick({c: a[lo:hi] for c, a in data.items()}))
    batch, _ = build_serving_flow(spec, data, empty_source=False)
    ref = session.run(batch, engine="streaming").table
    rep = replay_deltas(deltas, group_by=group_by)
    for k in ref:
        assert rep[k].dtype == ref[k].dtype, k
        assert rep[k].tobytes() == ref[k].tobytes(), k


# ---------------------------------------------------------------------------
#  resident-state contract: warm ticks recompile and re-upload nothing
# ---------------------------------------------------------------------------
def test_warm_ticks_zero_recompiles_and_dim_uploads():
    from repro.core import available_backends
    if "jax" not in available_backends():      # pragma: no cover
        pytest.skip("jax backend unavailable")
    data = _make_data(7)
    spec = (7, [("lookup", 3, 0, False), ("derive", 0, 4, True)],
            (1, 5, "sum"))
    serve_f, _ = build_serving_flow(spec, data, empty_source=True)
    session = repro.Session(backend="jax", metadata=None)
    with session.serve(serve_f, fuse=True) as srv:
        ticks = [srv.tick({c: a[idx] for c, a in data.items()})
                 for idx in np.array_split(np.arange(ROWS), 5)]
    cold, warm = ticks[0], ticks[1:]
    assert cold.cache_stats["segment_compiles"] >= 1
    assert cold.cache_stats["dim_h2d_transfers"] >= 1
    for t in warm:
        assert t.cache_stats["segment_compiles"] == 0, \
            f"tick {t.tick} recompiled a segment kernel"
        assert t.cache_stats["dim_h2d_transfers"] == 0, \
            f"tick {t.tick} re-uploaded a dim table"


# ---------------------------------------------------------------------------
#  watermark semantics
# ---------------------------------------------------------------------------
def _tiny_session(**opts):
    data = _make_data(3, rows=40)
    f, _ = build_serving_flow((3, [], None), data, empty_source=True)
    return repro.Session(metadata=None).serve(f, **opts), data


def test_watermark_regression_raises_by_default(monkeypatch):
    monkeypatch.delenv(config.ENV_SERVE_STRICT_WATERMARK, raising=False)
    srv, data = _tiny_session()
    batch = {c: a[:5] for c, a in data.items()}
    try:
        srv.tick(batch, watermark=100.0)
        with pytest.raises(ValueError, match="watermark regressed"):
            srv.tick(batch, watermark=99.0)
        assert srv.watermark == 100.0
        # equal and advancing watermarks are fine
        srv.tick(batch, watermark=100.0)
        srv.tick(batch, watermark=101.5)
        assert srv.watermark == 101.5
    finally:
        srv.close()


def test_watermark_regression_clamps_when_lenient(monkeypatch):
    monkeypatch.setenv(config.ENV_SERVE_STRICT_WATERMARK, "0")
    srv, data = _tiny_session()
    batch = {c: a[:5] for c, a in data.items()}
    try:
        srv.tick(batch, watermark=100.0)
        r = srv.tick(batch, watermark=42.0)     # clamped, not raised
        assert r.watermark == 100.0
        assert srv.watermark == 100.0
    finally:
        srv.close()


def test_untimed_ticks_leave_watermark_none():
    srv, data = _tiny_session()
    try:
        r = srv.tick({c: a[:5] for c, a in data.items()})
        assert r.watermark is None and srv.watermark is None
    finally:
        srv.close()


# ---------------------------------------------------------------------------
#  lifecycle: validation, close, reuse
# ---------------------------------------------------------------------------
def test_serve_rejects_adaptive_optimizer():
    data = _make_data(3, rows=40)
    f, _ = build_serving_flow((3, [], None), data, empty_source=True)
    with pytest.raises(ValueError, match="optimize"):
        repro.Session(metadata=None).serve(f, optimize=2)


def test_serve_rejects_mid_flow_blocking_component():
    data = _make_data(3, rows=40)
    f = (repro.flow("bad").source({c: a[:0] for c, a in data.items()})
         .sort(["k0"]).derive("d", repro.col("v0") + 1).sink())
    srv = repro.Session(metadata=None).serve(f)
    with pytest.raises(ValueError, match="Sort"):
        srv.tick({c: a[:5] for c, a in data.items()})
    srv.close()


def test_serve_rejects_non_terminal_aggregate():
    data = _make_data(3, rows=40)
    f = (repro.flow("bad-agg").source({c: a[:0] for c, a in data.items()})
         .aggregate(["g"], {"s": ("v0", "sum")})
         .derive("d", repro.col("s") + 1).sink())
    srv = repro.Session(metadata=None).serve(f)
    with pytest.raises(ValueError, match="sinks only"):
        srv.tick({c: a[:5] for c, a in data.items()})
    srv.close()


def test_tick_schema_mismatch_names_columns():
    srv, data = _tiny_session()
    try:
        bad = {c: a[:5] for c, a in data.items() if c != "v1"}
        bad["zz"] = np.arange(5)
        with pytest.raises(ValueError) as ei:
            srv.tick(bad)
        assert "v1" in str(ei.value) and "zz" in str(ei.value)
    finally:
        srv.close()


def test_close_is_idempotent_and_tick_after_close_raises():
    srv, data = _tiny_session()
    srv.tick({c: a[:5] for c, a in data.items()})
    s1 = srv.close()
    s2 = srv.close()
    assert s1["ticks"] == s2["ticks"] == 1
    assert s1["engine"] == "serving"
    with pytest.raises(RuntimeError, match="closed"):
        srv.tick({c: a[:5] for c, a in data.items()})


def test_flow_reusable_after_serving_session():
    """close() ends serving mode: the SAME flow then batch-runs correctly,
    and a fresh serve() on it works too."""
    data = _make_data(29)
    spec = (29, [("derive", 0, 2, False)], (1, 4, "sum"))
    f, group_by = build_serving_flow(spec, data, empty_source=True)
    session = repro.Session(metadata=None)

    with session.serve(f) as srv:
        deltas = [srv.tick({c: a[idx] for c, a in data.items()})
                  for idx in np.array_split(np.arange(ROWS), 2)]
    first = replay_deltas(deltas, group_by=group_by)

    # batch-run the same (serving) flow object with the full table
    src = next(c for c in f.flow.vertices.values()
               if type(c).__name__ == "ArraySource")
    src.set_data(data)
    batch = session.run(f, engine="streaming").table
    for k in batch:
        assert first[k].tobytes() == batch[k].tobytes(), k

    # and a fresh serving session over the same flow
    src.set_data({c: a[:0] for c, a in data.items()})
    with session.serve(f) as srv2:
        deltas2 = [srv2.tick({c: a[idx] for c, a in data.items()})
                   for idx in np.array_split(np.arange(ROWS), 3)]
    second = replay_deltas(deltas2, group_by=group_by)
    for k in batch:
        assert second[k].tobytes() == batch[k].tobytes(), k


# ---------------------------------------------------------------------------
#  abort mid-tick: the session survives and stays correct (bug sweep)
# ---------------------------------------------------------------------------
class _Exploding:
    """Filter predicate that raises when armed (reads declared: no
    DeprecationWarning, provenance stays visible)."""

    def __init__(self):
        self.armed = False

    def __call__(self, cache, rows):
        if self.armed:
            raise RuntimeError("mid-tick failure injected")
        return cache.col("v0")[rows] >= 0


def test_abort_mid_tick_session_reusable(monkeypatch):
    """A tick that dies mid-flight propagates the error, releases its
    buffers guard-clean, and leaves the session fully usable: subsequent
    ticks produce exactly the deltas they would have without the abort."""
    monkeypatch.setenv("REPRO_CACHE_GUARD", "1")    # poisoned releases + guard
    data = _make_data(37)
    from repro.etl.components import Filter
    bomb = _Exploding()
    b = repro.flow("abortable").source({c: a[:0] for c, a in data.items()})
    b._append(Filter("boom", bomb, reads=["v0"]))
    f = (b.derive("d", repro.col("v0") + repro.col("v1"))
          .aggregate(["g"], {"s": ("d", "sum"), "n": ("d", "count")})
          .sink())
    session = repro.Session(metadata=None)
    splits = np.array_split(np.arange(ROWS), 3)
    deltas = []
    # fuse=False: a fused segment traces callables into the compiled kernel
    # (pure row-local contract) — a STATEFUL raising predicate only fires
    # unfused, which is exactly the executor abort path under test
    with session.serve(f, fuse=False) as srv:
        deltas.append(srv.tick({c: a[splits[0]] for c, a in data.items()}))
        bomb.armed = True
        with pytest.raises(RuntimeError, match="mid-tick failure"):
            srv.tick({c: a[splits[1]] for c, a in data.items()})
        bomb.armed = False
        # the session keeps serving; the failed tick contributed nothing
        deltas.append(srv.tick({c: a[splits[1]] for c, a in data.items()}))
        deltas.append(srv.tick({c: a[splits[2]] for c, a in data.items()}))
        srv.close()

    ref_b = repro.flow("ref").source(data)
    ref_b._append(Filter("boom-ref", _Exploding(), reads=["v0"]))
    ref_f = (ref_b.derive("d", repro.col("v0") + repro.col("v1"))
             .aggregate(["g"], {"s": ("d", "sum"), "n": ("d", "count")})
             .sink())
    ref = session.run(ref_f, engine="streaming").table
    rep = replay_deltas(deltas, group_by=["g"])
    for k in ref:
        assert rep[k].tobytes() == ref[k].tobytes(), k


def test_abort_mid_tick_row_sync_sink_not_polluted(monkeypatch):
    """In a row-sync flow the sink receives per-split writes BEFORE the
    abort fires — those partial rows must not leak into the next tick's
    delta."""
    monkeypatch.setenv("REPRO_CACHE_GUARD", "1")
    data = _make_data(43)
    from repro.etl.components import Filter

    calls = {"n": 0}

    def late_bomb(cache, rows, _c=calls):
        _c["n"] += 1
        if _c["n"] == 999:                      # re-armed via calls["n"]
            raise RuntimeError("late failure")
        return cache.col("v0")[rows] % 2 == 0

    b = repro.flow("rowsync").source({c: a[:0] for c, a in data.items()})
    b._append(Filter("maybe", late_bomb, reads=["v0"]))
    f = b.derive("d", repro.col("v0") * 2).sink()
    session = repro.Session(metadata=None)
    splits = np.array_split(np.arange(ROWS), 2)
    with session.serve(f, fuse=False) as srv:    # see abort test above
        r1 = srv.tick({c: a[splits[0]] for c, a in data.items()})
        # arm so the NEXT filter call fails: splits already flowed for tick 1
        calls["n"] = 998
        with pytest.raises(RuntimeError, match="late failure"):
            srv.tick({c: a[splits[1]] for c, a in data.items()})
        calls["n"] = 0
        r2 = srv.tick({c: a[splits[1]] for c, a in data.items()})
    # tick outputs must chain to exactly the batch result — no duplicated
    # rows from the aborted attempt
    got = replay_deltas([r1, r2])
    rb = repro.flow("rowsync-ref").source(data)
    rb._append(Filter("maybe-ref",
                      lambda c, r: c.col("v0")[r] % 2 == 0, reads=["v0"]))
    ref = session.run(rb.derive("d", repro.col("v0") * 2).sink(),
                      engine="streaming").table
    assert got["d"].tobytes() == ref["d"].tobytes()


# ---------------------------------------------------------------------------
#  arena + scoped stats across runs (bug sweep: cross-run lifetimes)
# ---------------------------------------------------------------------------
def test_arena_acquire_in_one_scope_release_in_another(monkeypatch):
    """A buffer acquired under run A's stats scope and released under run
    B's must not corrupt pool accounting or double-count in either scope —
    and under REPRO_CACHE_GUARD=1 the release path must stay clean."""
    monkeypatch.setenv("REPRO_CACHE_GUARD", "1")
    with cache_stats_scope() as stats_a:
        arr, root = GLOBAL_ARENA.acquire(np.int64, 4096)
        arr[:] = 7
    before = GLOBAL_ARENA.pooled_bytes
    with cache_stats_scope() as stats_b:
        GLOBAL_ARENA.release(root)
    # release is not an acquire: neither scope gains hits/misses from it
    assert stats_b.arena_hits == 0 and stats_b.arena_misses == 0
    assert stats_a.arena_hits + stats_a.arena_misses >= 1
    assert GLOBAL_ARENA.pooled_bytes >= before
    # double release across yet another scope trips the guard loudly
    with pytest.raises(RuntimeError, match="double release"):
        GLOBAL_ARENA.release(root)


def test_arena_double_release_ignored_without_guard(monkeypatch):
    monkeypatch.delenv("REPRO_CACHE_GUARD", raising=False)
    arr, root = GLOBAL_ARENA.acquire(np.float64, 512)
    if root is None:                     # pragma: no cover — arena disabled
        pytest.skip("arena disabled")
    GLOBAL_ARENA.release(root)
    pooled = GLOBAL_ARENA.pooled_bytes
    GLOBAL_ARENA.release(root)           # silently ignored
    assert GLOBAL_ARENA.pooled_bytes == pooled


def test_arena_release_foreign_buffer_is_noop():
    foreign = np.zeros(1024, np.uint8)[10:]      # view: not OWNDATA
    pooled = GLOBAL_ARENA.pooled_bytes
    GLOBAL_ARENA.release(foreign)
    GLOBAL_ARENA.release(np.zeros(1000, np.uint8))   # not a pow2 bucket
    assert GLOBAL_ARENA.pooled_bytes == pooled


def test_scoped_stats_capture_serving_ticks_exactly():
    """A cache_stats_scope opened AROUND a serving session sees the sum of
    what the per-tick scopes see — scope nesting holds across the resident
    pool's threads."""
    data = _make_data(11, rows=200)
    f, _ = build_serving_flow((11, [("derive", 0, 2, False)], None),
                              data, empty_source=True)
    session = repro.Session(metadata=None)
    outer = CacheStats()
    with cache_stats_scope(outer):
        with session.serve(f) as srv:
            ticks = [srv.tick({c: a[idx] for c, a in data.items()})
                     for idx in np.array_split(np.arange(200), 4)]
    summed = sum(t.cache_stats["copies"] for t in ticks)
    assert outer.copies >= summed        # outer also saw source set_data etc.
    t_h2d = sum(t.cache_stats["h2d_transfers"] for t in ticks)
    assert outer.h2d_transfers >= t_h2d


# ---------------------------------------------------------------------------
#  trace growth stays bounded over a long session (bug sweep)
# ---------------------------------------------------------------------------
def test_thousand_tick_traced_session_stays_bounded(monkeypatch):
    """A traced 1000-tick serving session must not grow its event buffer
    without bound: the tracer rotates at REPRO_TRACE_MAX_EVENTS."""
    monkeypatch.setenv(config.ENV_TRACE_MAX_EVENTS, "2000")
    from repro.obs import trace as obs_trace
    data = _make_data(13, rows=1000)
    f, _ = build_serving_flow((13, [], None), data, empty_source=True)
    session = repro.Session(metadata=None)
    with obs_trace.trace_scope():
        with session.serve(f) as srv:
            engine = srv.engine
            for t in range(1000):
                srv.tick({c: a[t % 1000: t % 1000 + 1]
                          for c, a in data.items()})
            assert engine.tracer is not None
            assert len(engine.tracer.events) <= 2000, \
                "serving tracer grew past REPRO_TRACE_MAX_EVENTS"
            assert engine.tracer.dropped_events > 0
            summary = srv.close()
    assert summary["metrics"]["counters"]["ticks"] == 1000
    hist = summary["metrics"]["histograms"]["tick_s"]
    assert hist["count"] == 1000         # metrics never rotate
