"""Minimal stand-in for the parts of `hypothesis` these tests use, so the
tier-1 suite still runs (property tests become seeded random sampling) in
environments where the `test` extra is not installed.  Install the real
thing with ``pip install -e .[test]`` — when available it is always
preferred (see the try/except import in each test module)."""
from __future__ import annotations

import functools
import random
from typing import Any, Callable, List, Optional

_DEFAULT_MAX_EXAMPLES = 30
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw_fn: Callable[[random.Random], Any]):
        self._draw = draw_fn


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda r: tuple(s._draw(r) for s in strategies))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          unique: bool = False) -> _Strategy:
    def draw(r: random.Random):
        n = r.randint(min_size, max(min_size, max_size))
        if not unique:
            return [elements._draw(r) for _ in range(n)]
        out: List[Any] = []
        seen = set()
        for _ in range(20 * max(n, 1)):
            v = elements._draw(r)
            if v not in seen:
                seen.add(v)
                out.append(v)
            if len(out) >= n:
                break
        return out
    return _Strategy(draw)


def composite(fn: Callable) -> Callable[..., _Strategy]:
    @functools.wraps(fn)
    def build(*args, **kwargs) -> _Strategy:
        def draw_outer(r: random.Random):
            def draw(strategy: _Strategy):
                return strategy._draw(r)
            return fn(draw, *args, **kwargs)
        return _Strategy(draw_outer)
    return build


class _StrategiesModule:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    tuples = staticmethod(tuples)
    lists = staticmethod(lists)
    composite = staticmethod(composite)


st = _StrategiesModule()


def settings(max_examples: Optional[int] = None, deadline=None, **_ignored):
    def deco(fn):
        if max_examples is not None:
            fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        # deliberately NOT functools.wraps: the wrapper must expose a
        # zero-parameter signature or pytest asks for fixtures matching the
        # wrapped function's drawn arguments
        def runner():
            n = getattr(runner, "_max_examples", None) or \
                getattr(fn, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rnd = random.Random(_SEED + i)
                drawn = [s._draw(rnd) for s in strategies]
                fn(*drawn)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return deco
