"""Segment fusion + CacheArena: discovery/refusal rules, fused-vs-unfused
engine equality, arena reuse + buffer-poisoning guards, split-aliasing
checks and scoped per-run statistics.

Backend follows ``REPRO_BACKEND`` (the CI matrix runs this file under both
``numpy`` and ``jax``); jax-specific assertions are gated on the active
backend.
"""
import os

import numpy as np
import pytest

from repro.core import (GLOBAL_ARENA, GLOBAL_CACHE_STATS, CacheArena,
                        Dataflow, MetadataStore, OptimizeOptions,
                        OptimizedEngine, SharedCache, StreamingEngine,
                        cache_stats_scope, discover_segments,
                        fuse_segments_flow, get_default_backend, partition)
from repro.core import faults
from repro.core.component import StageBoundary
from repro.core.shared_cache import assert_views_disjoint
from repro.etl import BUILDERS
from repro.etl.components import (Aggregate, ArraySource, CollectSink,
                                  Converter, DimTable, Expression, Filter,
                                  FusedSegment, Lookup, Project)
from repro.etl.ssb import generate


# ---------------------------------------------------------------------------
#  helpers
# ---------------------------------------------------------------------------
def _data():
    return generate(lineorder_rows=12_000, customers=500, suppliers=80,
                    parts=300, seed=11)


def _chain_flow(*comps):
    flow = Dataflow("t")
    flow.chain(*comps)
    return flow


def _src(n=100, seed=0):
    r = np.random.RandomState(seed)
    return ArraySource("src", {
        "k": r.randint(1, 20, n).astype(np.int64),
        "v": r.randint(0, 100, n).astype(np.int64)})


def _expr(name, out="e"):
    return Expression(name, out, lambda c, r: c.col("v")[r] + 1, reads=["v"])


def _filt(name):
    return Filter(name, lambda c, r: c.col("v")[r] % 2 == 0, reads=["v"])


# ---------------------------------------------------------------------------
#  discovery + refusal rules
# ---------------------------------------------------------------------------
def test_discover_q41_single_segment():
    qf = BUILDERS["Q4.1"](_data())
    segs = discover_segments(qf.flow)
    assert segs == [["lookup_customer", "lookup_supplier", "lookup_part",
                     "lookup_date", "filter_unmatched", "project",
                     "profit_expr"]]


def test_discover_refuses_stage_boundary():
    """Q4.1s: the explicit StageBoundary cut splits the chain in two."""
    qf = BUILDERS["Q4.1s"](_data())
    segs = discover_segments(qf.flow)
    assert segs == [["lookup_customer", "lookup_supplier", "lookup_part",
                     "lookup_date"],
                    ["filter_unmatched", "project", "profit_expr"]]


def test_discover_refuses_block_and_singletons():
    """An Aggregate terminates the chain; a lone fusable component is not a
    segment (length >= 2)."""
    agg = Aggregate("agg", ["k"], {"s": ("v", "sum")})
    flow = _chain_flow(_src(), _expr("e1"), agg, _expr("e2", out="e2"),
                       CollectSink("sink"))
    assert discover_segments(flow) == []


def test_discover_refuses_order_sensitive():
    e1, e2, e3 = _expr("e1", "a"), _expr("e2", "b"), _expr("e3", "c")
    e2.order_sensitive = True
    flow = _chain_flow(_src(), e1, e2, e3, CollectSink("sink"))
    assert discover_segments(flow) == []


def test_discover_refuses_chunk_sensitive():
    e1, e2, e3 = _expr("e1", "a"), _expr("e2", "b"), _expr("e3", "c")
    e2.chunk_sensitive = True        # data semantics depend on chunking
    flow = _chain_flow(_src(), e1, e2, e3, CollectSink("sink"))
    assert discover_segments(flow) == []


def test_discover_refuses_fan_out():
    flow = Dataflow("fan")
    src, e1 = _src(), _expr("e1", "a")
    f1, f2 = _filt("f1"), _filt("f2")
    s1, s2 = CollectSink("s1"), CollectSink("s2")
    flow.chain(src, e1)
    flow.add(f1), flow.add(f2), flow.add(s1), flow.add(s2)
    flow.connect(e1, f1), flow.connect(e1, f2)
    flow.connect(f1, s1), flow.connect(f2, s2)
    # e1 fans out: no chain crosses it; f1/f2 are singletons
    assert discover_segments(flow) == []


def test_discover_through_terminal_aggregate():
    """``through_aggregates=True`` extends a chain through the single
    Aggregate that consumes it — the planner's marker for keep-mask
    deferral.  Default discovery is unchanged."""
    qf = BUILDERS["Q4.1"](_data())
    segs = discover_segments(qf.flow, through_aggregates=True)
    assert segs == [["lookup_customer", "lookup_supplier", "lookup_part",
                     "lookup_date", "filter_unmatched", "project",
                     "profit_expr", "groupby_sum"]]
    # the appended tail really is the Aggregate, not a fusable member
    agg = qf.flow.component("groupby_sum")
    assert getattr(agg, "segment_terminal_aggregate", False)


def test_discover_through_aggregate_requires_direct_single_edge():
    """No extension when something sits between the chain and the
    Aggregate, or when the Aggregate has fan-in."""
    agg = Aggregate("agg", ["k"], {"s": ("v", "sum")})
    flow = _chain_flow(_src(), _expr("e1", "a"), _expr("e2", "b"), agg,
                       CollectSink("sink"))
    assert discover_segments(flow, through_aggregates=True) == [
        ["e1", "e2", "agg"]]

    # fan-in: a second producer also feeds the Aggregate
    flow2 = Dataflow("fanin")
    src, e1, e2 = _src(), _expr("e1", "a"), _expr("e2", "b")
    agg2 = Aggregate("agg", ["k"], {"s": ("v", "sum")})
    side = _src(50, seed=3)
    side.name = "side"
    flow2.chain(src, e1, e2, agg2, CollectSink("sink"))
    flow2.add(side)
    flow2.connect(side, agg2)
    assert discover_segments(flow2, through_aggregates=True) == [
        ["e1", "e2"]]


def test_fuse_segments_flow_defers_mask_to_aggregate():
    """The fuse-segment-aggregate rewrite: the Aggregate stays a separate
    vertex, the FusedSegment carries the deferral metadata."""
    agg = Aggregate("agg", ["k"], {"s": ("v", "sum")})
    flow = _chain_flow(_src(), _expr("e1", "a"), _filt("f1"), agg,
                       CollectSink("sink"))
    rewrites = fuse_segments_flow(flow)
    assert [r.rule for r in rewrites] == ["fuse-segment",
                                          "fuse-segment-aggregate"]
    fused = flow.component("fusedseg(e1+f1)")
    assert fused.defer_to == "agg"
    assert fused.defer_cols == agg.consumed_columns()
    assert "defer_mask_to" in fused.spec()
    assert "agg" in set(flow.vertices)     # aggregate NOT collapsed
    partition(flow)


def test_fused_segment_provenance_and_spec():
    lk = Lookup("lk", DimTable(np.arange(1, 5, dtype=np.int64),
                               {"p": np.arange(4, dtype=np.int64)}),
                "k", {"p": "p"})
    ex = Expression("ex", "y", lambda c, r: c.col("p")[r] * 2, reads=["p"])
    fl = Filter("fl", lambda c, r: c.col("y")[r] > 0, reads=["y"])
    seg = FusedSegment.from_components([lk, ex, fl])
    assert seg.produced_columns() == frozenset({"p", "y"})
    # p and y are internal to the segment; only k is an external read
    assert seg.consumed_columns() == frozenset({"k"})
    assert seg.kernel_input_columns() == frozenset({"k"})
    assert not seg.row_preserving          # contains a row-dropper
    assert seg.spec()["members"] == "lk,ex,fl"
    # undeclared reads poison the declared sets (and warn by contract)
    with pytest.warns(DeprecationWarning, match="reads="):
        ex2 = Expression("ex2", "z", lambda c, r: c.col("y")[r])
    seg2 = FusedSegment.from_components([lk, ex, ex2])
    assert seg2.consumed_columns() is None
    assert seg2.kernel_input_columns() is None
    assert seg2.row_preserving


def test_from_components_rejects_unfusable():
    agg = Aggregate("agg", ["k"], {"s": ("v", "sum")})
    with pytest.raises(ValueError, match="cannot join"):
        FusedSegment.from_components([_expr("e1"), agg])


def test_fuse_segments_flow_rewrites_graph():
    flow = _chain_flow(_src(), _expr("e1", "a"), _expr("e2", "b"),
                       _filt("f1"), CollectSink("sink"))
    rewrites = fuse_segments_flow(flow)
    assert [r.rule for r in rewrites] == ["fuse-segment"]
    assert set(flow.vertices) == {"src", "fusedseg(e1+e2+f1)", "sink"}
    partition(flow)                 # still a valid partitionable dataflow


# ---------------------------------------------------------------------------
#  engine equality + instrumentation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qname", ["Q4.1", "Q4.1s"])
def test_fused_engine_byte_identical(qname):
    data = _data()
    qf_s = BUILDERS[qname](data)
    # fuse_segments=False pins the baseline even under REPRO_FUSION=1
    r_s = StreamingEngine(qf_s.flow, OptimizeOptions(
        num_splits=4, fuse_segments=False)).run()
    static = qf_s.sink.result()

    qf_f = BUILDERS[qname](data)
    r_f = StreamingEngine(qf_f.flow, OptimizeOptions(
        num_splits=4, fuse_segments=True)).run()
    fused = qf_f.sink.result()

    assert set(fused) == set(static)
    for k in static:
        assert fused[k].dtype == static[k].dtype
        np.testing.assert_array_equal(fused[k], static[k], err_msg=k)
    assert any(x["rule"] == "fuse-segment" for x in r_f.rewrites)
    # both SSB Q4 flows end their row-sync chain in groupby_sum: the
    # keep-mask deferral rewrite must fire alongside plain fusion
    assert any(x["rule"] == "fuse-segment-aggregate" for x in r_f.rewrites)
    # the headline: the whole row-sync chain dispatches once per chunk
    assert r_f.dispatch_calls < r_s.dispatch_calls
    if get_default_backend().name == "jax":
        assert r_f.h2d_transfers < r_s.h2d_transfers
        # deferral removes the per-chunk keep-mask sync: one compact at
        # Aggregate.finish replaces num_splits per-chunk compacts
        assert r_s.d2h_transfers - r_f.d2h_transfers >= 4 - 1


def test_fusion_env_var_and_metadata_run_record(monkeypatch):
    monkeypatch.setenv("REPRO_FUSION", "1")
    data = _data()
    qf = BUILDERS["Q4.1"](data)
    md = MetadataStore()
    run = OptimizedEngine(qf.flow, OptimizeOptions(num_splits=2),
                          metadata=md).run()
    assert any(x["rule"] == "fuse-segment" for x in run.rewrites)
    rec = md.runs["ssb-q4.1"]
    assert rec["dispatch_calls"] == run.dispatch_calls
    assert rec["arena_hits"] == run.arena_hits
    # JSON roundtrip keeps the run record
    assert MetadataStore.from_json(md.to_json()).runs["ssb-q4.1"] == rec


def test_fused_segment_lying_read_declaration(monkeypatch):
    """A declared read set that misses a column the lambda touches: the host
    reference runner pulls the column lazily from the cache and stays
    correct; the jax kernel (which uploads exactly the declared set) fails —
    the degradation ladder falls back to the reference runner and records a
    VISIBLE kernel Degradation (never silently wrong rows), and with
    ``REPRO_DEGRADE=0`` the failure raises loudly as before."""
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    def build():
        ex = Expression("ex", "y",
                        lambda c, r: c.col("v")[r] + c.col("k")[r],
                        reads=["v"])          # lies: also reads k
        return _chain_flow(_src(), ex, _filt("fl"), CollectSink("sink"))

    if get_default_backend().name == "jax":
        monkeypatch.setenv("REPRO_DEGRADE", "0")
        flow = build()
        fuse_segments_flow(flow)
        with pytest.raises(Exception, match="not visible|k"):
            StreamingEngine(flow, OptimizeOptions(num_splits=2)).run()

        monkeypatch.delenv("REPRO_DEGRADE")
        flow_s = build()
        sink_s = flow_s.component("sink")
        StreamingEngine(flow_s, OptimizeOptions(
            num_splits=2, fuse_segments=False)).run()
        flow_d = build()
        sink_d = flow_d.component("sink")
        assert fuse_segments_flow(flow_d)
        run = StreamingEngine(flow_d, OptimizeOptions(num_splits=2)).run()
        assert run.degradations >= 1
        assert any(d["kind"] == "kernel" and d["dst"] == "reference"
                   for d in run.degradation_events)
        for k, v in sink_s.result().items():
            np.testing.assert_array_equal(sink_d.result()[k], v, err_msg=k)
    else:
        flow_s = build()
        sink_s = flow_s.component("sink")
        StreamingEngine(flow_s, OptimizeOptions(num_splits=2)).run()
        flow_f = build()
        sink_f = flow_f.component("sink")
        assert fuse_segments_flow(flow_f)
        StreamingEngine(flow_f, OptimizeOptions(num_splits=2)).run()
        for k, v in sink_s.result().items():
            np.testing.assert_array_equal(sink_f.result()[k], v, err_msg=k)


def test_fused_segment_does_not_resurrect_projected_columns():
    """A component reading a column an earlier Project dropped fails inside
    the fused segment exactly like the unfused chain (KeyError) — the host
    runner must not silently re-read it from the underlying cache."""
    def build():
        proj = Project("proj", ["k"])                 # drops v
        conv = Converter("conv", {"v": np.float32})   # reads dropped v
        return _chain_flow(_src(), proj, conv, CollectSink("sink"))

    flow_u = build()
    with pytest.raises(KeyError):
        StreamingEngine(flow_u, OptimizeOptions(
            num_splits=2, fuse_segments=False)).run()

    flow_f = build()
    assert fuse_segments_flow(flow_f)
    with pytest.raises(KeyError):
        StreamingEngine(flow_f, OptimizeOptions(num_splits=2)).run()


# ---------------------------------------------------------------------------
#  CacheArena
# ---------------------------------------------------------------------------
def test_arena_reuse_hit_miss_counters():
    arena = CacheArena(enabled=True, max_bytes=1 << 20)
    before = GLOBAL_CACHE_STATS.snapshot()
    a1, r1 = arena.acquire(np.int64, (100,))
    assert a1.shape == (100,) and a1.dtype == np.int64
    arena.release(r1)
    a2, r2 = arena.acquire(np.int64, (100,))
    assert r2 is r1                       # same root buffer recycled
    after = GLOBAL_CACHE_STATS.snapshot()
    assert after["arena_hits"] - before["arena_hits"] == 1
    assert after["arena_misses"] - before["arena_misses"] == 1
    assert after["arena_bytes_reused"] - before["arena_bytes_reused"] == 800


def test_arena_bucket_cap_and_foreign_buffers():
    arena = CacheArena(enabled=True, max_bytes=1024)
    _, r1 = arena.acquire(np.uint8, (4096,))
    arena.release(r1)                     # 4096 > cap: dropped
    assert arena.pooled_buffers() == 0
    arena.release(np.empty(100, np.uint8))   # not a pow2 arena bucket
    arena.release(np.empty(512, np.int64))   # wrong dtype
    assert arena.pooled_buffers() == 0


def test_arena_disabled_is_plain_allocation():
    arena = CacheArena(enabled=False)
    arr, root = arena.acquire(np.float64, (10,))
    assert root is None and arr.flags["OWNDATA"]
    arena.release(root)                   # no-op


def test_arena_poisoning_and_double_release_guard(monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_GUARD", "1")
    arena = CacheArena(enabled=True, max_bytes=1 << 20)
    arr, root = arena.acquire(np.uint8, (300,))
    arr[:] = 7
    arena.release(root)
    assert (root == 0xAB).all()           # poisoned: use-after-recycle is loud
    with pytest.raises(RuntimeError, match="double release"):
        arena.release(root)


def test_recycle_returns_buffers_and_is_idempotent():
    arena_before = GLOBAL_ARENA.pooled_buffers()
    c = SharedCache({"a": np.arange(64, dtype=np.int64)}, 64)
    cp = c.copy()
    assert cp._owned is not None
    cp.recycle()
    assert cp._owned is None
    cp.recycle()                          # idempotent
    assert GLOBAL_ARENA.pooled_buffers() >= arena_before


def test_engine_equality_under_guard(monkeypatch):
    """With poisoning on, a premature recycle anywhere in the executor would
    corrupt sink rows — byte equality against the unfused/no-guard run is
    the use-after-recycle detector."""
    data = _data()
    qf = BUILDERS["Q4.1"](data)
    StreamingEngine(qf.flow, OptimizeOptions(num_splits=4)).run()
    baseline = qf.sink.result()

    monkeypatch.setenv("REPRO_CACHE_GUARD", "1")
    qf2 = BUILDERS["Q4.1"](data)
    StreamingEngine(qf2.flow, OptimizeOptions(
        num_splits=4, fuse_segments=True)).run()
    guarded = qf2.sink.result()
    for k in baseline:
        np.testing.assert_array_equal(guarded[k], baseline[k], err_msg=k)


def test_fault_retry_under_guard_no_poisoned_reuse(monkeypatch):
    """Mid-segment transient faults abort chunks that already wrote into
    arena-pooled buffers; the retry must not see those poisoned bytes.
    With REPRO_CACHE_GUARD=1 recycled buffers are 0xAB-filled and double
    releases raise, so byte equality against the fault-free baseline is
    the use-after-recycle / double-release detector for the replay path."""
    monkeypatch.delenv("REPRO_FAULTS", raising=False)   # exact counts below
    data = _data()
    qf = BUILDERS["Q4.1"](data)
    StreamingEngine(qf.flow, OptimizeOptions(
        num_splits=4, fuse_segments=True)).run()
    baseline = qf.sink.result()

    monkeypatch.setenv("REPRO_CACHE_GUARD", "1")
    monkeypatch.setenv("REPRO_RETRY_BACKOFF", "0.001")
    plan = faults.FaultPlan.parse(
        "seed=3; kernel:kind=transient,count=1,after=1; "
        "chunk:kind=transient,count=1")
    qf2 = BUILDERS["Q4.1"](data)
    with faults.fault_scope(plan):
        run = StreamingEngine(qf2.flow, OptimizeOptions(
            num_splits=4, fuse_segments=True)).run()
    faulty = qf2.sink.result()

    assert run.faults_injected == plan.injected >= 1
    assert run.retries >= 1
    for k in baseline:
        np.testing.assert_array_equal(faulty[k], baseline[k], err_msg=k)


def test_permanent_fault_aborts_and_releases_buffers(monkeypatch):
    """A permanent mid-segment fault must abort promptly (no retries), hand
    every in-flight buffer back to the arena exactly once (guard raises on
    double release), and leave the flow rerunnable byte-identically."""
    monkeypatch.delenv("REPRO_FAULTS", raising=False)   # exact counts below
    monkeypatch.setenv("REPRO_CACHE_GUARD", "1")
    data = _data()
    qf = BUILDERS["Q4.1"](data)
    plan = faults.FaultPlan.parse("kernel:kind=permanent,after=1")
    with faults.fault_scope(plan):
        with pytest.raises(faults.PermanentFault):
            StreamingEngine(qf.flow, OptimizeOptions(
                num_splits=4, fuse_segments=True)).run()
    assert plan.injected == 1

    # same flow objects, no plan: the rerun must match a fresh baseline —
    # stranded or double-released buffers from the abort would corrupt it
    run = StreamingEngine(qf.flow, OptimizeOptions(
        num_splits=4, fuse_segments=True)).run()
    rerun = qf.sink.result()
    assert run.retries == 0 and run.faults_injected == 0

    qf_ref = BUILDERS["Q4.1"](data)
    StreamingEngine(qf_ref.flow, OptimizeOptions(
        num_splits=4, fuse_segments=True)).run()
    ref = qf_ref.sink.result()
    for k in ref:
        np.testing.assert_array_equal(rerun[k], ref[k], err_msg=k)


# ---------------------------------------------------------------------------
#  split aliasing guard
# ---------------------------------------------------------------------------
def test_split_views_alias_parent_but_are_disjoint():
    c = SharedCache({"a": np.arange(100, dtype=np.int64)}, 100)
    parts = c.split(4)
    assert all(p.columns["a"].base is not None for p in parts)  # views
    assert_views_disjoint(parts)          # contract: pairwise disjoint


def test_overlap_guard_raises_on_aliased_splits():
    base = np.arange(100, dtype=np.int64)
    a = SharedCache({"a": base[0:60]}, 60)
    b = SharedCache({"a": base[40:100]}, 60)   # overlaps rows 40..59
    with pytest.raises(RuntimeError, match="overlap"):
        assert_views_disjoint([a, b])


def test_split_guard_active_under_env(monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_GUARD", "1")
    c = SharedCache({"a": np.arange(50, dtype=np.int64)}, 50)
    assert len(c.split(3)) == 3           # clean splits pass the check


# ---------------------------------------------------------------------------
#  scoped per-run statistics
# ---------------------------------------------------------------------------
def test_cache_stats_scope_attributes_per_run():
    from repro.core.shared_cache import record_copy
    c = SharedCache({"a": np.arange(256, dtype=np.int64)}, 256)
    record_copy(c)                        # outside any scope
    with cache_stats_scope() as s1:
        record_copy(c)
        record_copy(c)
        with cache_stats_scope() as s2:   # nested scopes both observe
            record_copy(c)
    assert s1.snapshot()["copies"] == 3
    assert s2.snapshot()["copies"] == 1


def test_engine_runs_report_scoped_counters():
    """Two sequential engine runs attribute copies/arena traffic to their
    own EngineRun — equal workloads report equal counters."""
    data = _data()
    runs = []
    for _ in range(2):
        qf = BUILDERS["Q4.1"](data)
        runs.append(StreamingEngine(
            qf.flow, OptimizeOptions(num_splits=4)).run())
    assert runs[0].copies == runs[1].copies
    assert runs[0].dispatch_calls == runs[1].dispatch_calls
    assert runs[0].h2d_transfers == runs[1].h2d_transfers


def test_worker_pool_propagates_scope():
    from repro.core import SharedWorkerPool
    from repro.core.shared_cache import record_transfer
    pool = SharedWorkerPool(2)
    try:
        with cache_stats_scope() as s:
            futs = [pool.submit(record_transfer, "h2d", 10)
                    for _ in range(4)]
            for f in futs:
                f.result()
        assert s.snapshot()["h2d_transfers"] == 4
        assert s.snapshot()["h2d_bytes"] == 40
    finally:
        pool.shutdown()


# ---------------------------------------------------------------------------
#  bench JSON writer
# ---------------------------------------------------------------------------
def test_bench_json_schema(tmp_path, monkeypatch):
    import json as _json
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))
    from benchmarks.run import write_bench_json
    monkeypatch.setenv("BENCH_TAG", "unittest")
    path = tmp_path / "BENCH_unittest.json"
    stats = GLOBAL_CACHE_STATS.snapshot()
    write_bench_json({"sec": {"wall_s": 1.0, "status": "ok",
                              "cache_stats": stats}},
                     mode="full", path=str(path))
    payload = _json.loads(path.read_text())
    assert payload["tag"] == "unittest"
    assert payload["mode"] == "full"
    assert payload["backend"] in ("numpy", "jax")
    sec = payload["sections"]["sec"]
    assert sec["status"] == "ok"
    for key in ("copies", "h2d_transfers", "arena_hits", "arena_misses",
                "arena_bytes_reused"):
        assert key in sec["cache_stats"]
