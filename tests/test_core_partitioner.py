"""Algorithm 1 (execution-tree partitioning): shape tests on the paper's
figures + hypothesis property tests on random DAGs."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover — env without the `test` extra
    from _hypothesis_compat import given, settings, st

from repro.core import ComponentType, Dataflow, partition
from repro.core.component import (BlockComponent, Component,
                                  SemiBlockComponent, SinkComponent,
                                  SourceComponent)
from repro.core.shared_cache import SharedCache, concat_caches
from repro.etl.queries import build_q4
from repro.etl.ssb import generate


class _Src(SourceComponent):
    def total_rows(self):
        return 0

    def chunks(self, chunk_rows):
        return iter(())


class _Row(Component):
    def _run(self, cache):
        return [cache]


class _Blk(BlockComponent):
    def finish(self, state):
        return concat_caches(state)


class _Semi(SemiBlockComponent):
    def finish(self, state):
        return concat_caches(state)


class _Sink(SinkComponent):
    def write(self, cache):
        pass


def test_figure6_shape():
    """The paper's Figure 6: source -> row-syncs with a sort (block), a
    semi-block union of two branches, and an aggregation -> 4 trees."""
    f = Dataflow("fig6")
    src = f.add(_Src("source"))
    ext = f.add(_Row("extract"))
    f.connect(src, ext)
    filt = f.add(_Row("filter_rows"))
    conv = f.add(_Row("convert"))
    f.connect(ext, filt)
    f.connect(ext, conv)
    sort = f.add(_Blk("sort"))              # roots T (block)
    f.connect(conv, sort)
    look = f.add(_Row("lookup"))
    f.connect(sort, look)
    uni = f.add(_Semi("union"))             # roots T (semi-block)
    f.connect(filt, uni)
    f.connect(look, uni)
    agg = f.add(_Blk("sum"))                # roots T (block)
    f.connect(uni, agg)
    s1 = f.add(_Sink("target1"))
    f.connect(agg, s1)
    s2 = f.add(_Sink("target2"))
    f.connect(uni, s2)

    g = partition(f)
    assert len(g.trees) == 4
    roots = {t.root for t in g.trees}
    assert roots == {"source", "sort", "union", "sum"}
    by_root = {t.root: t for t in g.trees}
    assert set(by_root["source"].members) == {"source", "extract",
                                              "filter_rows", "convert"}
    assert set(by_root["sort"].members) == {"sort", "lookup"}
    assert set(by_root["union"].members) == {"union", "target2"}
    assert set(by_root["sum"].members) == {"sum", "target1"}
    # inter-tree edges: source->sort, source->union, sort->union, union->sum
    ids = {r: by_root[r].tree_id for r in roots}
    assert set(g.edges) == {(ids["source"], ids["sort"]),
                            (ids["source"], ids["union"]),
                            (ids["sort"], ids["union"]),
                            (ids["union"], ids["sum"])}


def test_q41_paper_trees():
    """Figure 11: Q4.1 partitions into T1 (src + 4 lookups + filter +
    project + expr), T2 (groupby), T3 (sort + sink)."""
    data = generate(lineorder_rows=100, customers=50, suppliers=20,
                    parts=20)
    qf = build_q4(data)
    g = partition(qf.flow)
    members = sorted([sorted(t.members) for t in g.trees], key=len)
    assert len(g.trees) == 3
    assert members[0] == ["groupby_sum"]
    assert members[1] == ["sink", "sort"]
    assert len(members[2]) == 8          # T1


# ---------------------------------------------------------------------------
#  property: random layered DAGs
# ---------------------------------------------------------------------------
@st.composite
def random_flow(draw):
    f = Dataflow("rand")
    n_src = draw(st.integers(1, 3))
    sources = [f.add(_Src(f"src{i}")) for i in range(n_src)]
    frontier = [s.name for s in sources]
    n_mid = draw(st.integers(1, 12))
    for i in range(n_mid):
        kind = draw(st.sampled_from(["row", "block", "semi"]))
        if kind == "row":
            c = f.add(_Row(f"row{i}"))
            up = draw(st.sampled_from(frontier))
            f.connect(up, c)
        elif kind == "block":
            c = f.add(_Blk(f"blk{i}"))
            up = draw(st.sampled_from(frontier))
            f.connect(up, c)
        else:
            c = f.add(_Semi(f"semi{i}"))
            ups = draw(st.lists(st.sampled_from(frontier), min_size=1,
                                max_size=3, unique=True))
            for u in ups:
                f.connect(u, c)
        frontier.append(c.name)
    # every sink-less leaf gets a sink
    for leaf in list(f.sinks()):
        if f.component(leaf).ctype != ComponentType.SINK:
            s = f.add(_Sink(f"sink_{leaf}"))
            f.connect(leaf, s)
    return f


@given(random_flow())
@settings(max_examples=60, deadline=None)
def test_partition_invariants(flow):
    g = partition(flow)
    # 1. every vertex is in exactly one tree
    all_members = [m for t in g.trees for m in t.members]
    assert sorted(all_members) == sorted(flow.vertices.keys())
    for t in g.trees:
        root_c = flow.component(t.root)
        # 2. roots are sources or block/semi-block (paper §4.1)
        assert (root_c.ctype.roots_tree
                or flow.in_degree(t.root) == 0)
        # 3. non-root members stream (row-sync or sink)
        for m in t.members[1:]:
            assert flow.component(m).ctype.streams
    # 4. the tree graph is acyclic with consistent edges
    order = g.topo_tree_order()
    assert sorted(order) == sorted(t.tree_id for t in g.trees)
    for a, b in g.edges:
        assert a != b
