"""Algorithm 1 (execution-tree partitioning): shape tests on the paper's
figures + hypothesis property tests on random DAGs."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover — env without the `test` extra
    from _hypothesis_compat import given, settings, st

from repro.core import ComponentType, Dataflow, partition
from repro.core.component import (BlockComponent, Component,
                                  SemiBlockComponent, SinkComponent,
                                  SourceComponent)
from repro.core.shared_cache import SharedCache, concat_caches
from repro.etl.queries import build_q4
from repro.etl.ssb import generate


class _Src(SourceComponent):
    def total_rows(self):
        return 0

    def chunks(self, chunk_rows):
        return iter(())


class _Row(Component):
    def _run(self, cache):
        return [cache]


class _Blk(BlockComponent):
    def finish(self, state):
        return concat_caches(state)


class _Semi(SemiBlockComponent):
    def finish(self, state):
        return concat_caches(state)


class _Sink(SinkComponent):
    def write(self, cache):
        pass


def test_figure6_shape():
    """The paper's Figure 6: source -> row-syncs with a sort (block), a
    semi-block union of two branches, and an aggregation -> 4 trees."""
    f = Dataflow("fig6")
    src = f.add(_Src("source"))
    ext = f.add(_Row("extract"))
    f.connect(src, ext)
    filt = f.add(_Row("filter_rows"))
    conv = f.add(_Row("convert"))
    f.connect(ext, filt)
    f.connect(ext, conv)
    sort = f.add(_Blk("sort"))              # roots T (block)
    f.connect(conv, sort)
    look = f.add(_Row("lookup"))
    f.connect(sort, look)
    uni = f.add(_Semi("union"))             # roots T (semi-block)
    f.connect(filt, uni)
    f.connect(look, uni)
    agg = f.add(_Blk("sum"))                # roots T (block)
    f.connect(uni, agg)
    s1 = f.add(_Sink("target1"))
    f.connect(agg, s1)
    s2 = f.add(_Sink("target2"))
    f.connect(uni, s2)

    g = partition(f)
    assert len(g.trees) == 4
    roots = {t.root for t in g.trees}
    assert roots == {"source", "sort", "union", "sum"}
    by_root = {t.root: t for t in g.trees}
    assert set(by_root["source"].members) == {"source", "extract",
                                              "filter_rows", "convert"}
    assert set(by_root["sort"].members) == {"sort", "lookup"}
    assert set(by_root["union"].members) == {"union", "target2"}
    assert set(by_root["sum"].members) == {"sum", "target1"}
    # inter-tree edges: source->sort, source->union, sort->union, union->sum
    ids = {r: by_root[r].tree_id for r in roots}
    assert set(g.edges) == {(ids["source"], ids["sort"]),
                            (ids["source"], ids["union"]),
                            (ids["sort"], ids["union"]),
                            (ids["union"], ids["sum"])}


def test_q41_paper_trees():
    """Figure 11: Q4.1 partitions into T1 (src + 4 lookups + filter +
    project + expr), T2 (groupby), T3 (sort + sink)."""
    data = generate(lineorder_rows=100, customers=50, suppliers=20,
                    parts=20)
    qf = build_q4(data)
    g = partition(qf.flow)
    members = sorted([sorted(t.members) for t in g.trees], key=len)
    assert len(g.trees) == 3
    assert members[0] == ["groupby_sum"]
    assert members[1] == ["sink", "sort"]
    assert len(members[2]) == 8          # T1


# ---------------------------------------------------------------------------
#  property: random layered DAGs
# ---------------------------------------------------------------------------
@st.composite
def random_flow(draw):
    f = Dataflow("rand")
    n_src = draw(st.integers(1, 3))
    sources = [f.add(_Src(f"src{i}")) for i in range(n_src)]
    frontier = [s.name for s in sources]
    n_mid = draw(st.integers(1, 12))
    for i in range(n_mid):
        kind = draw(st.sampled_from(["row", "block", "semi"]))
        if kind == "row":
            c = f.add(_Row(f"row{i}"))
            up = draw(st.sampled_from(frontier))
            f.connect(up, c)
        elif kind == "block":
            c = f.add(_Blk(f"blk{i}"))
            up = draw(st.sampled_from(frontier))
            f.connect(up, c)
        else:
            c = f.add(_Semi(f"semi{i}"))
            ups = draw(st.lists(st.sampled_from(frontier), min_size=1,
                                max_size=3, unique=True))
            for u in ups:
                f.connect(u, c)
        frontier.append(c.name)
    # every sink-less leaf gets a sink
    for leaf in list(f.sinks()):
        if f.component(leaf).ctype != ComponentType.SINK:
            s = f.add(_Sink(f"sink_{leaf}"))
            f.connect(leaf, s)
    return f


@given(random_flow())
@settings(max_examples=60, deadline=None)
def test_partition_invariants(flow):
    g = partition(flow)
    # 1. every vertex is in exactly one tree
    all_members = [m for t in g.trees for m in t.members]
    assert sorted(all_members) == sorted(flow.vertices.keys())
    for t in g.trees:
        root_c = flow.component(t.root)
        # 2. roots are sources or block/semi-block (paper §4.1)
        assert (root_c.ctype.roots_tree
                or flow.in_degree(t.root) == 0)
        # 3. non-root members stream (row-sync or sink)
        for m in t.members[1:]:
            assert flow.component(m).ctype.streams
    # 4. the tree graph is acyclic with consistent edges
    order = g.topo_tree_order()
    assert sorted(order) == sorted(t.tree_id for t in g.trees)
    for a, b in g.edges:
        assert a != b


# ---------------------------------------------------------------------------
#  edge-case shapes: diamonds, multi-source trees, single-component flows —
#  what the optimizer's random generator produces and re-cuts
# ---------------------------------------------------------------------------
from repro.core.component import StageBoundary
from repro.core.partitioner import streamable_tree_ids
from repro.core.planner import plan_runtime


def test_diamond_flow_partition():
    """src fans out to two row-sync branches that reconverge at a semi-block
    union: one source tree holds BOTH branches; the union roots its own tree
    with a single (deduplicated) inter-tree edge."""
    f = Dataflow("diamond")
    src = f.add(_Src("src"))
    a = f.add(_Row("a"))
    b = f.add(_Row("b"))
    uni = f.add(_Semi("union"))
    sink = f.add(_Sink("sink"))
    f.connect(src, a)
    f.connect(src, b)
    f.connect(a, uni)
    f.connect(b, uni)
    f.connect(uni, sink)
    g = partition(f)
    assert len(g.trees) == 2
    by_root = {t.root: t for t in g.trees}
    assert set(by_root["src"].members) == {"src", "a", "b"}
    assert set(by_root["union"].members) == {"union", "sink"}
    # both dataflow edges a->union, b->union collapse to ONE tree edge
    assert g.edges == [(by_root["src"].tree_id, by_root["union"].tree_id)]
    # the union accumulates (semi-block): never streamable
    assert streamable_tree_ids(f, g) == set()


def test_diamond_reconverging_on_row_sync_is_rejected():
    """Only semi-block components may merge multiple upstreams (paper §3):
    a diamond closing on a row-sync boundary must fail validation."""
    f = Dataflow("bad-diamond")
    src = f.add(_Src("src"))
    a = f.add(_Row("a"))
    b = f.add(_Row("b"))
    cut = f.add(StageBoundary("cut"))
    f.connect(src, a)
    f.connect(src, b)
    f.connect(a, cut)
    f.connect(b, cut)
    with pytest.raises(ValueError, match="in-degree 2"):
        partition(f)


def test_multi_source_trees():
    """Two sources feeding one union: two source trees, two inter-tree
    edges into the union's tree."""
    f = Dataflow("multi-src")
    s1 = f.add(_Src("s1"))
    s2 = f.add(_Src("s2"))
    r1 = f.add(_Row("r1"))
    uni = f.add(_Semi("union"))
    sink = f.add(_Sink("sink"))
    f.connect(s1, r1)
    f.connect(r1, uni)
    f.connect(s2, uni)
    f.connect(uni, sink)
    g = partition(f)
    assert len(g.trees) == 3
    by_root = {t.root: t for t in g.trees}
    u = by_root["union"].tree_id
    assert set(g.edges) == {(by_root["s1"].tree_id, u),
                            (by_root["s2"].tree_id, u)}
    assert g.topo_tree_order()[-1] == u


def test_boundary_downstream_of_union_streamable_unless_order_sensitive():
    """A stage-boundary tree fed by exactly one inter-tree edge (here: the
    union's output) is streamable; an order-sensitive member disables it."""
    f = Dataflow("two-feeds")
    s1 = f.add(_Src("s1"))
    s2 = f.add(_Src("s2"))
    uni = f.add(_Semi("union"))
    cut = f.add(StageBoundary("cut"))
    sink = f.add(_Sink("sink"))
    f.connect(s1, uni)
    f.connect(s2, uni)
    f.connect(uni, cut)
    f.connect(cut, sink)
    g = partition(f)
    by_root = {t.root: t for t in g.trees}
    # exactly one inbound edge targeting the root => streamable
    assert streamable_tree_ids(f, g) == {by_root["cut"].tree_id}
    # but an order-sensitive member disables it
    f.component("sink").order_sensitive = True
    assert streamable_tree_ids(f, g) == set()


def test_single_component_flow():
    """A lone source partitions into one single-member tree with no edges,
    and the runtime planner still produces a sane plan for it."""
    f = Dataflow("lone")
    f.add(_Src("src"))
    g = partition(f)
    assert len(g.trees) == 1
    assert g.trees[0].members == ["src"]
    assert g.edges == []
    assert streamable_tree_ids(f, g) == set()
    plan = plan_runtime(f, g, num_splits=4, m_prime=4)
    assert plan.pool_width >= 1
    assert plan.channel_depth == {}


def test_two_component_source_sink_flow():
    f = Dataflow("pair")
    src = f.add(_Src("src"))
    sink = f.add(_Sink("sink"))
    f.connect(src, sink)
    g = partition(f)
    assert len(g.trees) == 1
    assert g.trees[0].members == ["src", "sink"]
    assert streamable_tree_ids(f, g) == set()
