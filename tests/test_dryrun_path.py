"""The launch path end-to-end on a small host mesh in a subprocess (the
main test process keeps its single default device): cell_specs -> jit with
shardings -> lower -> compile -> roofline walk."""
import os
import subprocess
import sys
import textwrap

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.specs import cell_specs
    from repro.launch.hlo_analysis import analyze_compiled
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step

    from repro.launch.jax_compat import axis_types_kwargs, set_mesh
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         devices=jax.devices()[:4],
                         **axis_types_kwargs(2))
    cfg = get_config("mixtral-8x7b", smoke=True).replace(grad_accum=2)
    shape = ShapeConfig("tiny_train", seq_len=32, global_batch=8,
                        kind="train", grad_accum=2)
    specs = cell_specs(cfg, shape, mesh)
    cfg = specs["cfg"]
    step = make_train_step(cfg, OptConfig(), specs["rules"])
    with set_mesh(mesh):
        fn = jax.jit(step,
                     in_shardings=(specs["param_shardings"],
                                   specs["opt_shardings"],
                                   specs["batch_shardings"]),
                     out_shardings=(specs["param_shardings"],
                                    specs["opt_shardings"], None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(specs["param_shapes"], specs["opt_shapes"],
                           specs["batch_shapes"])
    compiled = lowered.compile()
    roof = analyze_compiled(compiled, 4, model_flops=1.0)
    assert roof.flops_per_device > 0
    assert roof.bytes_per_device > 0
    mem = compiled.memory_analysis()
    assert mem.argument_size_in_bytes > 0
    # the walker found the scan trip counts (layers x microbatches)
    print("DRYRUN_PATH_OK", roof.flops_per_device,
          roof.collective_bytes_per_device)
""")


def test_dryrun_lower_compile_analyze_subprocess():
    r = subprocess.run([sys.executable, "-c", PROG],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "DRYRUN_PATH_OK" in r.stdout, r.stdout + r.stderr
