"""Per-kernel interpret=True allclose sweeps against the pure-jnp oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.hash_join import (hash_build, hash_keys, hash_keys_np,
                                     hash_probe, hash_probe_ref)
from repro.kernels.mamba_scan import mamba_scan, mamba_scan_ref
from repro.kernels.radix_groupby import radix_groupby, radix_groupby_ref
from repro.kernels.segment_sum import segment_sum, segment_sum_ref

RNG = np.random.default_rng(1234)


# ------------------------------------------------------------- segment_sum
@pytest.mark.parametrize("n,c,g,tile", [
    (100, 1, 8, 32), (1000, 4, 37, 128), (513, 3, 64, 256),
    (2048, 8, 128, 512), (7, 2, 4, 512),
])
def test_segment_sum_sweep(n, c, g, tile):
    seg = RNG.integers(-1, g, n).astype(np.int32)
    vals = RNG.normal(size=(n, c)).astype(np.float32)
    ref = segment_sum_ref(jnp.array(seg), jnp.array(vals), g)
    got = segment_sum(jnp.array(seg), jnp.array(vals), g,
                      impl="interpret", rows_tile=tile)
    np.testing.assert_allclose(np.array(got), np.array(ref),
                               rtol=1e-5, atol=1e-5)


def test_segment_sum_all_padding():
    seg = np.full(64, -1, np.int32)
    vals = RNG.normal(size=(64, 2)).astype(np.float32)
    got = segment_sum(jnp.array(seg), jnp.array(vals), 8, impl="interpret")
    np.testing.assert_array_equal(np.array(got), np.zeros((8, 2)))


def test_segment_sum_matches_paper_groupby(ssb_tiny):
    """The kernel computes the paper's block component (Fig-11 groupby_sum)."""
    lo = ssb_tiny.lineorder
    year = lo["lo_orderdate"] // 10000 - 1992
    profit = (lo["lo_revenue"] - lo["lo_supplycost"]).astype(np.float32)
    got = segment_sum(jnp.array(year.astype(np.int32)),
                      jnp.array(profit[:, None]), 7, impl="interpret")
    expect = np.zeros(7)
    np.add.at(expect, year, profit)
    np.testing.assert_allclose(np.array(got)[:, 0], expect, rtol=1e-5)


# ----------------------------------------------------------------- hash join
def _probe_oracle(key_rows, probe_rows):
    """First-occurrence membership oracle: (index, found) per probe row."""
    lut = {}
    for i, row in enumerate(map(tuple, key_rows)):
        lut.setdefault(row, i)
    found = np.array([tuple(r) in lut for r in probe_rows])
    idx = np.array([lut.get(tuple(r), 0) for r in probe_rows], np.int64)
    return idx, found


def _probe(built, cols, impl, **kw):
    return hash_probe(tuple(jnp.asarray(k) for k in built["slot_keys"]),
                      jnp.asarray(built["slot_idx"]),
                      tuple(jnp.asarray(c) for c in cols),
                      built["max_probes"], impl=impl, **kw)


def test_hash_keys_host_device_identical():
    """The host (build-time) and traced (probe-time) hash must agree bit for
    bit — open addressing falls apart on any mismatch."""
    for dt in (np.int64, np.int32, np.uint32, np.int16):
        k1 = RNG.integers(0, np.iinfo(dt).max, 500).astype(dt)
        k2 = RNG.integers(0, 100, 500).astype(dt)
        h_np = hash_keys_np((k1, k2))
        h_j = hash_keys((jnp.asarray(k1), jnp.asarray(k2)))
        np.testing.assert_array_equal(h_np, np.asarray(h_j))


@pytest.mark.parametrize("d,n,key_range,tile", [
    (1, 16, 50, 512),            # tiny table (min size floor)
    (500, 2_000, 3_000, 512),    # ~17% hit rate, misses exercised
    (1000, 1_500, 1_000, 256),   # dense: most probes hit
    (997, 777, 100_000, 128),    # sparse keys, ragged row tile
])
def test_hash_probe_sweep(d, n, key_range, tile):
    keys = np.sort(RNG.choice(key_range, size=min(d, key_range),
                              replace=False)).astype(np.int64)
    built = hash_build((keys,))
    probes = RNG.integers(0, key_range + 10, n).astype(np.int64)
    oi, of = _probe_oracle(keys[:, None], probes[:, None])
    for impl in ("reference", "interpret"):
        idx, found = _probe(built, (probes,), impl, rows_tile=tile)
        idx, found = np.asarray(idx), np.asarray(found)
        np.testing.assert_array_equal(found, of)
        np.testing.assert_array_equal(idx[of], oi[of])


def test_hash_probe_arbitrary_key_order():
    """Unlike searchsorted, the hash table needs NO key ordering: a shuffled
    build probes identically (modulo the first-occurrence index mapping)."""
    keys = RNG.choice(10_000, size=800, replace=False).astype(np.int64)
    shuffled = keys.copy()
    RNG.shuffle(shuffled)
    built = hash_build((shuffled,))
    probes = RNG.integers(0, 11_000, 2_500).astype(np.int64)
    oi, of = _probe_oracle(shuffled[:, None], probes[:, None])
    idx, found = _probe(built, (probes,), "reference")
    np.testing.assert_array_equal(np.asarray(found), of)
    np.testing.assert_array_equal(np.asarray(idx)[of], oi[of])


def test_hash_probe_duplicate_keys_keep_first():
    """Duplicate build keys: probes must land on the FIRST occurrence —
    over sorted keys that is exactly searchsorted's leftmost index, the
    byte-compat contract with the legacy DimTable probe."""
    base = np.sort(RNG.choice(500, size=200, replace=False))
    keys = np.sort(np.concatenate([base, base[:50], base[:25]]))
    built = hash_build((keys.astype(np.int64),))
    probes = np.arange(-5, 520).astype(np.int64)
    ss = np.clip(np.searchsorted(keys, probes), 0, len(keys) - 1)
    hit = keys[ss] == probes
    idx, found = _probe(built, (probes,), "reference")
    np.testing.assert_array_equal(np.asarray(found), hit)
    np.testing.assert_array_equal(np.asarray(idx)[hit], ss[hit])


@pytest.mark.parametrize("impl", ["reference", "interpret"])
def test_hash_probe_multi_column(impl):
    rows = np.unique(RNG.integers(0, 40, size=(600, 3)), axis=0)
    built = hash_build(tuple(rows[:, j].astype(np.int64) for j in range(3)))
    probes = RNG.integers(0, 45, size=(2_000, 3)).astype(np.int64)
    oi, of = _probe_oracle(rows, probes)
    idx, found = _probe(built, tuple(probes[:, j] for j in range(3)), impl)
    idx, found = np.asarray(idx), np.asarray(found)
    np.testing.assert_array_equal(found, of)
    np.testing.assert_array_equal(idx[of], oi[of])


def test_hash_probe_all_miss_and_empty_probe():
    keys = np.arange(100, dtype=np.int64) * 7
    built = hash_build((keys,))
    probes = (np.arange(50, dtype=np.int64) * 7) + 3   # never in table
    idx, found = _probe(built, (probes,), "reference")
    assert not np.asarray(found).any()
    idx, found = _probe(built, (np.zeros(0, np.int64),), "reference")
    assert np.asarray(idx).shape == (0,) and np.asarray(found).shape == (0,)


def test_hash_probe_ref_traceable():
    """hash_probe_ref must trace under jit with max_probes static — the
    fused segment kernel inlines it."""
    keys = np.sort(RNG.choice(1_000, 300, replace=False)).astype(np.int64)
    built = hash_build((keys,))
    sk = tuple(jnp.asarray(k) for k in built["slot_keys"])
    si = jnp.asarray(built["slot_idx"])
    probes = RNG.integers(0, 1_100, 800).astype(np.int64)

    @jax.jit
    def f(p):
        return hash_probe_ref(sk, si, (p,), built["max_probes"])

    idx, found = f(jnp.asarray(probes))
    oi, of = _probe_oracle(keys[:, None], probes[:, None])
    np.testing.assert_array_equal(np.asarray(found), of)
    np.testing.assert_array_equal(np.asarray(idx)[of], oi[of])


# -------------------------------------------------------------- radix groupby
@pytest.mark.parametrize("n,c,g,part,tile", [
    (100, 1, 8, 256, 128),
    (4_000, 3, 300, 64, 512),     # multiple partitions
    (2_048, 2, 1_000, 256, 256),  # sparse occupancy
    (513, 0, 16, 256, 512),       # counts only (C=0)
    (7, 2, 700, 128, 512),        # more groups than rows
])
def test_radix_groupby_sweep(n, c, g, part, tile):
    ids = RNG.integers(-1, g, n).astype(np.int32)     # -1 = padding rows
    vals = RNG.normal(size=(n, c)).astype(np.float32)
    s_ref, c_ref = radix_groupby_ref(jnp.asarray(ids), jnp.asarray(vals), g)
    s_got, c_got = radix_groupby(jnp.asarray(ids), jnp.asarray(vals), g,
                                 impl="interpret", part_groups=part,
                                 rows_tile=tile)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c_got), np.asarray(c_ref))


def test_radix_groupby_matches_numpy():
    ids = RNG.integers(0, 97, 5_000).astype(np.int32)
    vals = RNG.normal(size=(5_000, 2)).astype(np.float32)
    sums, counts = radix_groupby(jnp.asarray(ids), jnp.asarray(vals), 97,
                                 impl="interpret")
    expect_c = np.bincount(ids, minlength=97)
    np.testing.assert_array_equal(np.asarray(counts), expect_c)
    for j in range(2):
        expect_s = np.zeros(97)
        np.add.at(expect_s, ids, vals[:, j])
        np.testing.assert_allclose(np.asarray(sums)[:, j], expect_s,
                                   rtol=1e-4, atol=1e-4)


def test_radix_groupby_all_padding():
    ids = np.full(300, -1, np.int32)
    vals = RNG.normal(size=(300, 2)).astype(np.float32)
    sums, counts = radix_groupby(jnp.asarray(ids), jnp.asarray(vals), 32,
                                 impl="interpret")
    np.testing.assert_array_equal(np.asarray(sums), np.zeros((32, 2)))
    np.testing.assert_array_equal(np.asarray(counts), np.zeros(32))


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,Sq,Skv,Kh,G,hd,causal,window,softcap,bq,bk", [
    (1, 64, 64, 1, 1, 32, True, 0, 0.0, 32, 32),
    (2, 128, 128, 2, 2, 64, True, 0, 0.0, 32, 64),
    (2, 128, 128, 2, 2, 64, False, 0, 0.0, 64, 32),
    (1, 96, 96, 2, 4, 32, True, 24, 0.0, 32, 32),     # sliding window
    (1, 64, 64, 4, 1, 64, True, 0, 30.0, 32, 32),     # grok softcap
    (2, 80, 80, 1, 8, 16, True, 0, 0.0, 32, 32),      # ragged blocks (pad)
    (1, 33, 57, 1, 2, 8, False, 0, 0.0, 16, 16),      # cross-attn shapes
])
def test_flash_attention_sweep(B, Sq, Skv, Kh, G, hd, causal, window,
                               softcap, bq, bk):
    q = jnp.array(RNG.normal(size=(B, Sq, Kh, G, hd)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, Skv, Kh, hd)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, Skv, Kh, hd)), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, impl="interpret",
                          block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.array(got), np.array(ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    B, S, Kh, G, hd = 1, 64, 2, 2, 32
    q = jnp.array(RNG.normal(size=(B, S, Kh, G, hd)), jnp.bfloat16)
    k = jnp.array(RNG.normal(size=(B, S, Kh, hd)), jnp.bfloat16)
    v = jnp.array(RNG.normal(size=(B, S, Kh, hd)), jnp.bfloat16)
    ref = flash_attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, impl="interpret",
                          block_q=32, block_k=32)
    np.testing.assert_allclose(np.array(got, np.float32),
                               np.array(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------- mamba scan
@pytest.mark.parametrize("Bt,T,d,N,chunk,dblk", [
    (1, 16, 8, 4, 8, 8),
    (2, 48, 24, 8, 16, 16),
    (2, 100, 32, 16, 32, 16),     # ragged T (pad)
    (1, 64, 48, 16, 64, 512),     # d < d_block
])
def test_mamba_scan_sweep(Bt, T, d, N, chunk, dblk):
    delta = jnp.array(np.abs(RNG.normal(size=(Bt, T, d))).clip(0.01, 1.0),
                      jnp.float32)
    x = jnp.array(RNG.normal(size=(Bt, T, d)), jnp.float32)
    B = jnp.array(RNG.normal(size=(Bt, T, N)), jnp.float32)
    C = jnp.array(RNG.normal(size=(Bt, T, N)), jnp.float32)
    A = jnp.array(-np.abs(RNG.normal(size=(d, N))) - 0.05, jnp.float32)
    h0 = jnp.array(RNG.normal(size=(Bt, d, N)), jnp.float32)
    y_ref, hT_ref = mamba_scan_ref(delta, x, B, C, A, h0)
    y, hT = mamba_scan(delta, x, B, C, A, h0, impl="interpret",
                       chunk=chunk, d_block=dblk)
    np.testing.assert_allclose(np.array(y), np.array(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(hT), np.array(hT_ref),
                               rtol=1e-4, atol=1e-4)


def test_mamba_scan_continuation():
    """Scanning [0:T1] then [T1:T] from hT equals scanning [0:T] — the
    chunked-carry invariant the kernel's sequential grid relies on."""
    Bt, T, d, N = 1, 32, 8, 4
    delta = jnp.array(np.abs(RNG.normal(size=(Bt, T, d))).clip(0.01, 1.0),
                      jnp.float32)
    x = jnp.array(RNG.normal(size=(Bt, T, d)), jnp.float32)
    B = jnp.array(RNG.normal(size=(Bt, T, N)), jnp.float32)
    C = jnp.array(RNG.normal(size=(Bt, T, N)), jnp.float32)
    A = jnp.array(-np.abs(RNG.normal(size=(d, N))) - 0.05, jnp.float32)
    h0 = jnp.zeros((Bt, d, N), jnp.float32)
    y_full, hT_full = mamba_scan_ref(delta, x, B, C, A, h0)
    y1, h1 = mamba_scan(delta[:, :16], x[:, :16], B[:, :16], C[:, :16],
                        A, h0, impl="interpret", chunk=8, d_block=8)
    y2, h2 = mamba_scan(delta[:, 16:], x[:, 16:], B[:, 16:], C[:, 16:],
                        A, h1, impl="interpret", chunk=8, d_block=8)
    np.testing.assert_allclose(np.array(jnp.concatenate([y1, y2], 1)),
                               np.array(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(h2), np.array(hT_full),
                               rtol=1e-4, atol=1e-4)
