"""Per-kernel interpret=True allclose sweeps against the pure-jnp oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.mamba_scan import mamba_scan, mamba_scan_ref
from repro.kernels.segment_sum import segment_sum, segment_sum_ref

RNG = np.random.default_rng(1234)


# ------------------------------------------------------------- segment_sum
@pytest.mark.parametrize("n,c,g,tile", [
    (100, 1, 8, 32), (1000, 4, 37, 128), (513, 3, 64, 256),
    (2048, 8, 128, 512), (7, 2, 4, 512),
])
def test_segment_sum_sweep(n, c, g, tile):
    seg = RNG.integers(-1, g, n).astype(np.int32)
    vals = RNG.normal(size=(n, c)).astype(np.float32)
    ref = segment_sum_ref(jnp.array(seg), jnp.array(vals), g)
    got = segment_sum(jnp.array(seg), jnp.array(vals), g,
                      impl="interpret", rows_tile=tile)
    np.testing.assert_allclose(np.array(got), np.array(ref),
                               rtol=1e-5, atol=1e-5)


def test_segment_sum_all_padding():
    seg = np.full(64, -1, np.int32)
    vals = RNG.normal(size=(64, 2)).astype(np.float32)
    got = segment_sum(jnp.array(seg), jnp.array(vals), 8, impl="interpret")
    np.testing.assert_array_equal(np.array(got), np.zeros((8, 2)))


def test_segment_sum_matches_paper_groupby(ssb_tiny):
    """The kernel computes the paper's block component (Fig-11 groupby_sum)."""
    lo = ssb_tiny.lineorder
    year = lo["lo_orderdate"] // 10000 - 1992
    profit = (lo["lo_revenue"] - lo["lo_supplycost"]).astype(np.float32)
    got = segment_sum(jnp.array(year.astype(np.int32)),
                      jnp.array(profit[:, None]), 7, impl="interpret")
    expect = np.zeros(7)
    np.add.at(expect, year, profit)
    np.testing.assert_allclose(np.array(got)[:, 0], expect, rtol=1e-5)


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,Sq,Skv,Kh,G,hd,causal,window,softcap,bq,bk", [
    (1, 64, 64, 1, 1, 32, True, 0, 0.0, 32, 32),
    (2, 128, 128, 2, 2, 64, True, 0, 0.0, 32, 64),
    (2, 128, 128, 2, 2, 64, False, 0, 0.0, 64, 32),
    (1, 96, 96, 2, 4, 32, True, 24, 0.0, 32, 32),     # sliding window
    (1, 64, 64, 4, 1, 64, True, 0, 30.0, 32, 32),     # grok softcap
    (2, 80, 80, 1, 8, 16, True, 0, 0.0, 32, 32),      # ragged blocks (pad)
    (1, 33, 57, 1, 2, 8, False, 0, 0.0, 16, 16),      # cross-attn shapes
])
def test_flash_attention_sweep(B, Sq, Skv, Kh, G, hd, causal, window,
                               softcap, bq, bk):
    q = jnp.array(RNG.normal(size=(B, Sq, Kh, G, hd)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, Skv, Kh, hd)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, Skv, Kh, hd)), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, impl="interpret",
                          block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.array(got), np.array(ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    B, S, Kh, G, hd = 1, 64, 2, 2, 32
    q = jnp.array(RNG.normal(size=(B, S, Kh, G, hd)), jnp.bfloat16)
    k = jnp.array(RNG.normal(size=(B, S, Kh, hd)), jnp.bfloat16)
    v = jnp.array(RNG.normal(size=(B, S, Kh, hd)), jnp.bfloat16)
    ref = flash_attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, impl="interpret",
                          block_q=32, block_k=32)
    np.testing.assert_allclose(np.array(got, np.float32),
                               np.array(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------- mamba scan
@pytest.mark.parametrize("Bt,T,d,N,chunk,dblk", [
    (1, 16, 8, 4, 8, 8),
    (2, 48, 24, 8, 16, 16),
    (2, 100, 32, 16, 32, 16),     # ragged T (pad)
    (1, 64, 48, 16, 64, 512),     # d < d_block
])
def test_mamba_scan_sweep(Bt, T, d, N, chunk, dblk):
    delta = jnp.array(np.abs(RNG.normal(size=(Bt, T, d))).clip(0.01, 1.0),
                      jnp.float32)
    x = jnp.array(RNG.normal(size=(Bt, T, d)), jnp.float32)
    B = jnp.array(RNG.normal(size=(Bt, T, N)), jnp.float32)
    C = jnp.array(RNG.normal(size=(Bt, T, N)), jnp.float32)
    A = jnp.array(-np.abs(RNG.normal(size=(d, N))) - 0.05, jnp.float32)
    h0 = jnp.array(RNG.normal(size=(Bt, d, N)), jnp.float32)
    y_ref, hT_ref = mamba_scan_ref(delta, x, B, C, A, h0)
    y, hT = mamba_scan(delta, x, B, C, A, h0, impl="interpret",
                       chunk=chunk, d_block=dblk)
    np.testing.assert_allclose(np.array(y), np.array(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(hT), np.array(hT_ref),
                               rtol=1e-4, atol=1e-4)


def test_mamba_scan_continuation():
    """Scanning [0:T1] then [T1:T] from hT equals scanning [0:T] — the
    chunked-carry invariant the kernel's sequential grid relies on."""
    Bt, T, d, N = 1, 32, 8, 4
    delta = jnp.array(np.abs(RNG.normal(size=(Bt, T, d))).clip(0.01, 1.0),
                      jnp.float32)
    x = jnp.array(RNG.normal(size=(Bt, T, d)), jnp.float32)
    B = jnp.array(RNG.normal(size=(Bt, T, N)), jnp.float32)
    C = jnp.array(RNG.normal(size=(Bt, T, N)), jnp.float32)
    A = jnp.array(-np.abs(RNG.normal(size=(d, N))) - 0.05, jnp.float32)
    h0 = jnp.zeros((Bt, d, N), jnp.float32)
    y_full, hT_full = mamba_scan_ref(delta, x, B, C, A, h0)
    y1, h1 = mamba_scan(delta[:, :16], x[:, :16], B[:, :16], C[:, :16],
                        A, h0, impl="interpret", chunk=8, d_block=8)
    y2, h2 = mamba_scan(delta[:, 16:], x[:, 16:], B[:, 16:], C[:, 16:],
                        A, h1, impl="interpret", chunk=8, d_block=8)
    np.testing.assert_allclose(np.array(jnp.concatenate([y1, y2], 1)),
                               np.array(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(h2), np.array(hT_full),
                               rtol=1e-4, atol=1e-4)
