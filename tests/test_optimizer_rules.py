"""Cost-based optimizer (core/optimizer.py): each rewrite rule in isolation
— the positive case AND the refusals (undeclared read sets, non-row-sync /
block neighbours, chunk-sensitive sources) — plus calibration statistics,
graph surgery, measured-bytes re-planning and the metadata before/after
records."""
import numpy as np
import pytest

from repro.core import (CostBasedOptimizer, Dataflow, FlowStatistics,
                        MetadataStore, OptimizeOptions, OptimizedEngine,
                        StreamingEngine, measured_edge_bytes, partition,
                        run_calibration, suggest_pipeline_degree)
from repro.core.component import StageBoundary
from repro.core.optimizer import ComponentStats
from repro.etl.components import (Aggregate, ArraySource, CollectSink,
                                  DimTable, Expression, Filter,
                                  FusedExpression, Lookup, Sort)


# ---------------------------------------------------------------------------
#  fixtures / helpers
# ---------------------------------------------------------------------------
def _table(n=1000, seed=0):
    r = np.random.RandomState(seed)
    return {"k": r.randint(1, 50, n).astype(np.int64),
            "g": r.randint(0, 4, n).astype(np.int64),
            "v": r.randint(0, 100, n).astype(np.int64)}


def _dim(nk=50, seed=1):
    r = np.random.RandomState(seed)
    keys = np.arange(1, nk + 1, dtype=np.int64)
    return DimTable(keys, {"pay": r.randint(0, 9, nk).astype(np.int64)})


def _stats(flow, **overrides):
    """Hand-crafted statistics: every component saw 1000 rows in/out with
    1ms/krow unless overridden with ComponentStats kwargs."""
    st = FlowStatistics(sample_rows=1000, scale=1.0)
    for name in flow.vertices:
        st.components[name] = ComponentStats(
            rows_in=1000, rows_out=1000, busy_time=1e-3, calls=4,
            out_bytes=8 * 3 * 1000)
    for name, cs in overrides.items():
        st.components[name] = cs
    return st


def _chain_flow(*comps, name="f"):
    flow = Dataflow(name)
    flow.chain(*comps)
    return flow


class _ChunkySource(ArraySource):
    chunk_sensitive = True


# ---------------------------------------------------------------------------
#  graph surgery
# ---------------------------------------------------------------------------
def test_graph_surgery_roundtrip():
    src = ArraySource("src", _table())
    f1 = Filter("f1", lambda c, r: c.col("v")[r] >= 0, reads=["v"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, f1, sink)

    cut = StageBoundary("cut")
    flow.insert_between("f1", "sink", cut)
    assert flow.succ("f1") == ["cut"] and flow.succ("cut") == ["sink"]
    flow.validate()

    flow.remove_passthrough("cut")
    assert flow.succ("f1") == ["sink"]
    assert "cut" not in flow.vertices
    flow.validate()

    with pytest.raises(KeyError):
        flow.insert_between("src", "sink", StageBoundary("x"))   # no such edge
    with pytest.raises(ValueError):
        flow.remove_passthrough("src")       # in-degree 0


def test_graph_swap_adjacent():
    src = ArraySource("src", _table())
    lk = Lookup("lk", _dim(), "k", {"pay": "pay"})
    f1 = Filter("f1", lambda c, r: c.col("v")[r] < 50, reads=["v"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, lk, f1, sink)
    flow.swap_adjacent("lk", "f1")
    assert flow.succ("src") == ["f1"]
    assert flow.succ("f1") == ["lk"]
    assert flow.succ("lk") == ["sink"]
    flow.validate()
    with pytest.raises(KeyError):
        flow.swap_adjacent("lk", "f1")       # edge now reversed


# ---------------------------------------------------------------------------
#  calibration statistics
# ---------------------------------------------------------------------------
def test_calibration_scales_and_skips_sinks():
    cols = _table(n=2000)
    src = ArraySource("src", cols)
    filt = Filter("filt", lambda c, r: c.col("v")[r] < 50, reads=["v"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, filt, sink)
    stats = run_calibration(flow, sample_rows=500)
    assert stats.sample_rows == 500
    assert stats.scale == pytest.approx(4.0)
    s = stats.get("filt")
    # ~half the rows survive v < 50; scaled to the full 2000-row input
    assert 0.3 < s.selectivity < 0.7
    assert s.rows_in == pytest.approx(2000, rel=0.05)
    # sinks are counted, never written: the run's results stay clean
    assert sink.result() == {}
    # component counters were reset for the real run
    assert flow.component("filt").rows_in == 0


def test_calibration_on_multi_tree_flow():
    src = ArraySource("src", _table())
    agg = Aggregate("agg", ["g"], {"s": ("v", "sum")})
    srt = Sort("srt", ["g"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, agg, srt, sink)
    stats = run_calibration(flow, sample_rows=1000)
    assert stats.get("agg").rows_out <= 4       # 4 groups
    assert stats.get("srt").rows_in >= 1


# ---------------------------------------------------------------------------
#  rule 1: filter commute
# ---------------------------------------------------------------------------
def _commute_flow(reads):
    src = ArraySource("src", _table())
    lk = Lookup("lk", _dim(), "k", {"pay": "pay"})
    filt = Filter("filt", lambda c, r: c.col("v")[r] < 30, reads=reads)
    sink = CollectSink("sink")
    return _chain_flow(src, lk, filt, sink), sink


def test_filter_commutes_ahead_of_lookup():
    flow, _ = _commute_flow(reads=["v"])
    stats = _stats(flow, filt=ComponentStats(rows_in=1000, rows_out=300,
                                             busy_time=1e-4, calls=4,
                                             out_bytes=8 * 3 * 300))
    opt = CostBasedOptimizer(flow, stats)
    rewrites = opt.optimize()
    assert [r.rule for r in rewrites] == ["filter-commute"]
    assert flow.succ("src") == ["filt"]          # filter hopped the lookup
    assert flow.succ("filt") == ["lk"]


def test_filter_commute_refuses_dependent_reads():
    # the filter reads the column the lookup PRODUCES: must refuse
    flow, _ = _commute_flow(reads=["pay"])
    opt = CostBasedOptimizer(flow, _stats(flow, filt=ComponentStats(
        rows_in=1000, rows_out=300, busy_time=1e-4, calls=4, out_bytes=100)))
    ok, reason = opt.can_commute("lk", "filt")
    assert not ok and "pay" in reason
    assert opt.optimize() == []
    assert flow.succ("src") == ["lk"]            # untouched


def test_filter_commute_refuses_undeclared_reads():
    with pytest.warns(DeprecationWarning, match="reads="):
        flow, _ = _commute_flow(reads=None)
    opt = CostBasedOptimizer(flow, _stats(flow))
    ok, reason = opt.can_commute("lk", "filt")
    assert not ok and "undeclared read set" in reason
    assert opt.optimize() == []
    # the silent opt-out is now VISIBLE: the refusal is recorded with reason
    assert any(r.rule == "filter-commute" and "undeclared" in r.detail
               for r in opt.refusals)


def test_filter_commute_refuses_block_neighbour():
    src = ArraySource("src", _table())
    srt = Sort("srt", ["k"])
    filt = Filter("filt", lambda c, r: c.col("v")[r] < 30, reads=["v"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, srt, filt, sink)
    opt = CostBasedOptimizer(flow, _stats(flow, filt=ComponentStats(
        rows_in=1000, rows_out=300, busy_time=1e-4, calls=4, out_bytes=100)))
    ok, reason = opt.can_commute("srt", "filt")
    assert not ok and "not row-sync" in reason
    assert opt.optimize() == []


def test_filter_commute_refuses_stage_cut_and_selective_filters_stay():
    src = ArraySource("src", _table())
    cut = StageBoundary("cut")
    filt = Filter("filt", lambda c, r: c.col("v")[r] < 30, reads=["v"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, cut, filt, sink)
    opt = CostBasedOptimizer(flow, _stats(flow))
    ok, reason = opt.can_commute("cut", "filt")
    assert not ok and "stage cut" in reason
    # and a filter observed to drop nothing is never commuted
    flow2, _ = _commute_flow(reads=["v"])
    opt2 = CostBasedOptimizer(flow2, _stats(flow2))   # selectivity 1.0
    assert opt2.optimize() == []


def test_commuted_flow_output_identical():
    flow_a, sink_a = _commute_flow(reads=["v"])
    flow_b, sink_b = _commute_flow(reads=["v"])
    stats = _stats(flow_b, filt=ComponentStats(rows_in=1000, rows_out=300,
                                               busy_time=1e-4, calls=4,
                                               out_bytes=8 * 3 * 300))
    assert CostBasedOptimizer(flow_b, stats).optimize()
    OptimizedEngine(flow_a, OptimizeOptions(num_splits=4)).run()
    OptimizedEngine(flow_b, OptimizeOptions(num_splits=4)).run()
    a, b = sink_a.result(), sink_b.result()
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


# ---------------------------------------------------------------------------
#  rule 2: expression fusion
# ---------------------------------------------------------------------------
def _expr_flow(with_filter_between=False):
    src = ArraySource("src", _table())
    e1 = Expression("e1", "a", lambda c, r: c.col("v")[r] * 2, reads=["v"])
    e2 = Expression("e2", "b", lambda c, r: c.col("a")[r] + c.col("k")[r],
                    reads=["a", "k"])
    sink = CollectSink("sink")
    if with_filter_between:
        filt = Filter("filt", lambda c, r: c.col("v")[r] >= 0, reads=["v"])
        return _chain_flow(src, e1, filt, e2, sink), sink
    return _chain_flow(src, e1, e2, sink), sink


def test_expressions_fuse_and_match():
    flow_a, sink_a = _expr_flow()
    flow_b, sink_b = _expr_flow()
    opt = CostBasedOptimizer(flow_b, _stats(flow_b))
    rewrites = opt.optimize()
    assert [r.rule for r in rewrites] == ["fuse-expressions"]
    fused = [c for c in flow_b.vertices.values()
             if isinstance(c, FusedExpression)]
    assert len(fused) == 1
    # the fused activity's provenance: reads of e2 satisfied by e1 are
    # internal; outputs are both columns
    assert fused[0].produced_columns() == frozenset({"a", "b"})
    assert fused[0].consumed_columns() == frozenset({"v", "k"})
    flow_b.validate()
    OptimizedEngine(flow_a, OptimizeOptions(num_splits=4)).run()
    OptimizedEngine(flow_b, OptimizeOptions(num_splits=4)).run()
    a, b = sink_a.result(), sink_b.result()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_fusion_refuses_non_adjacent():
    flow, _ = _expr_flow(with_filter_between=True)
    opt = CostBasedOptimizer(flow, _stats(flow))
    ok, reason = opt.can_fuse("e1", "e2")
    assert not ok and "chain" in reason
    assert "fuse-expressions" not in [r.rule for r in opt.optimize()]


def test_fusion_refuses_non_expression_neighbour():
    src = ArraySource("src", _table())
    e1 = Expression("e1", "a", lambda c, r: c.col("v")[r] * 2, reads=["v"])
    srt = Sort("srt", ["k"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, e1, srt, sink)
    opt = CostBasedOptimizer(flow, _stats(flow))
    ok, reason = opt.can_fuse("e1", "srt")
    assert not ok and "Expression" in reason


def test_fusion_chains_three_expressions():
    src = ArraySource("src", _table())
    e1 = Expression("e1", "a", lambda c, r: c.col("v")[r] * 2, reads=["v"])
    e2 = Expression("e2", "b", lambda c, r: c.col("a")[r] + 1, reads=["a"])
    e3 = Expression("e3", "c3", lambda c, r: c.col("b")[r] - c.col("v")[r],
                    reads=["b", "v"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, e1, e2, e3, sink)
    opt = CostBasedOptimizer(flow, _stats(flow))
    rewrites = opt.optimize()
    assert [r.rule for r in rewrites] == ["fuse-expressions"] * 2
    fused = [c for c in flow.vertices.values()
             if isinstance(c, FusedExpression)]
    assert len(fused) == 1 and len(fused[0].exprs) == 3


# ---------------------------------------------------------------------------
#  rule 3: stage-boundary insert / remove
# ---------------------------------------------------------------------------
def _cut_flow(src_cls=ArraySource):
    src = src_cls("src", _table(4000))
    lk = Lookup("lk", _dim(), "k", {"pay": "pay"})
    e1 = Expression("e1", "a", lambda c, r: c.col("v")[r] + c.col("pay")[r],
                    reads=["v", "pay"])
    agg = Aggregate("agg", ["g"], {"s": ("a", "sum")})
    sink = CollectSink("sink")
    return _chain_flow(src, lk, e1, agg, sink), sink


def test_boundary_insert_on_heavy_edge():
    flow, _ = _cut_flow()
    # heavy lookup, heavy downstream expression, plenty of bytes crossing
    big = ComponentStats(rows_in=4000, rows_out=4000, busy_time=0.5, calls=4,
                         out_bytes=64 * 1024 * 1024)
    stats = _stats(flow, lk=big, e1=big)
    opt = CostBasedOptimizer(flow, stats, streaming=True)
    rewrites = opt.optimize()
    assert "insert-boundary" in [r.rule for r in rewrites]
    cuts = [n for n, c in flow.vertices.items() if c.tree_boundary]
    assert len(cuts) == 1                       # capped at one insert
    g_tau = partition(flow)
    assert len(g_tau.trees) == 3                # src-tree | cut-tree | agg...


def test_boundary_insert_refuses_without_streaming():
    flow, _ = _cut_flow()
    big = ComponentStats(rows_in=4000, rows_out=4000, busy_time=0.5, calls=4,
                         out_bytes=64 * 1024 * 1024)
    opt = CostBasedOptimizer(flow, _stats(flow, lk=big, e1=big),
                             streaming=False)
    assert "insert-boundary" not in [r.rule for r in opt.optimize()]


def test_boundary_insert_refuses_chunk_sensitive_source():
    flow, _ = _cut_flow(src_cls=_ChunkySource)
    opt = CostBasedOptimizer(flow, _stats(flow), streaming=True)
    ok, reason = opt.can_cut("lk", "e1")
    assert not ok and "chunk-sensitive" in reason


def test_boundary_insert_refuses_tree_rooting_target():
    flow, _ = _cut_flow()
    opt = CostBasedOptimizer(flow, _stats(flow), streaming=True)
    ok, reason = opt.can_cut("e1", "agg")       # agg already roots a tree
    assert not ok and "roots a tree" in reason


def test_boundary_insert_refuses_order_sensitive_downstream():
    src = ArraySource("src", _table())
    lk = Lookup("lk", _dim(), "k", {"pay": "pay"})
    e1 = Expression("e1", "a", lambda c, r: c.col("v")[r] * 2, reads=["v"])
    e1.order_sensitive = True
    sink = CollectSink("sink")
    flow = _chain_flow(src, lk, e1, sink)
    opt = CostBasedOptimizer(flow, _stats(flow), streaming=True)
    ok, reason = opt.can_cut("lk", "e1")
    assert not ok and "order-sensitive" in reason


def test_boundary_removed_when_bytes_small():
    src = ArraySource("src", _table(100))
    cut = StageBoundary("cut")
    e1 = Expression("e1", "a", lambda c, r: c.col("v")[r] * 2, reads=["v"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, cut, e1, sink)
    tiny = ComponentStats(rows_in=100, rows_out=100, busy_time=1e-5, calls=1,
                          out_bytes=2400)       # << MIN_STREAM_BYTES
    stats = _stats(flow, src=tiny)
    opt = CostBasedOptimizer(flow, stats, streaming=True)
    rewrites = opt.optimize()
    assert [r.rule for r in rewrites] == ["remove-boundary"]
    assert "cut" not in flow.vertices
    flow.validate()


def test_boundary_kept_when_bytes_justify_streaming():
    src = ArraySource("src", _table(100))
    cut = StageBoundary("cut")
    e1 = Expression("e1", "a", lambda c, r: c.col("v")[r] * 2, reads=["v"])
    sink = CollectSink("sink")
    flow = _chain_flow(src, cut, e1, sink)
    big = ComponentStats(rows_in=100, rows_out=100, busy_time=1e-3, calls=1,
                         out_bytes=64 * 1024 * 1024)
    opt = CostBasedOptimizer(flow, _stats(flow, src=big), streaming=True)
    assert "remove-boundary" not in [r.rule for r in opt.optimize()]
    assert "cut" in flow.vertices


# ---------------------------------------------------------------------------
#  measured re-planning
# ---------------------------------------------------------------------------
def test_measured_edge_bytes_uses_observations():
    flow, _ = _cut_flow()
    stats = run_calibration(flow, sample_rows=1000)
    g_tau = partition(flow)
    eb = measured_edge_bytes(flow, g_tau, stats)
    assert set(eb.keys()) == set(g_tau.edges)
    # the lookup widened the rows: observed bytes on the src->agg transition
    # reflect the attenuated-but-widened measured stream, not the source size
    assert all(v > 0 for v in eb.values())


def test_measured_edge_bytes_inherits_for_fresh_components():
    flow, _ = _cut_flow()
    stats = run_calibration(flow, sample_rows=1000)
    flow.insert_between("lk", "e1", StageBoundary("cut"))   # unseen by stats
    g_tau = partition(flow)
    eb = measured_edge_bytes(flow, g_tau, stats)
    cut_tree = g_tau.tree_of["cut"]
    src_tree = g_tau.tree_of["lk"]
    # the edge fed by the fresh boundary inherits its predecessor's bytes
    assert eb[(src_tree, cut_tree)] == stats.get("lk").out_bytes


def test_suggest_pipeline_degree_bounds():
    flow, _ = _cut_flow()
    stats = run_calibration(flow, sample_rows=1000)
    m = suggest_pipeline_degree(stats, num_splits=8)
    assert 1 <= m <= 8
    # degenerate statistics: explicit fallback, not a crash
    empty = FlowStatistics(sample_rows=0)
    assert suggest_pipeline_degree(empty, num_splits=4) == 4


# ---------------------------------------------------------------------------
#  engine integration + metadata records
# ---------------------------------------------------------------------------
def test_optimize_level2_records_before_after():
    flow, sink = _cut_flow()
    md = MetadataStore()
    run = StreamingEngine(flow, OptimizeOptions(num_splits=4,
                                                optimize_level=2,
                                                calibration_rows=512),
                          metadata=md).run()
    rec = md.adaptive[flow.name]
    assert {"statistics", "rewrites", "before", "after"} <= set(rec)
    assert rec["before"]["plan"]["pool_width"] >= 1
    assert rec["after"]["plan"]["pool_width"] >= 1
    assert md.statistics[flow.name]["sample_rows"] == 512
    assert run.rewrites == rec["rewrites"]
    # JSON round-trip keeps the adaptive record
    md2 = MetadataStore.from_json(md.to_json())
    assert md2.adaptive[flow.name] == rec
    assert sink.result()["s"].shape[0] == 4     # 4 groups survived the run


def test_optimize_level2_does_not_mutate_options():
    flow, _ = _cut_flow()
    opts = OptimizeOptions(num_splits=4, optimize_level=2)
    StreamingEngine(flow, opts).run()
    assert opts.pipeline_degree is None


# ---------------------------------------------------------------------------
#  regressions: edge ORDER is semantic (per-port splitter routing)
# ---------------------------------------------------------------------------
def test_remove_passthrough_preserves_fanout_port_order():
    """The reconnect edge must take the removed edge's position: appending
    it would flip a splitter's hi/lo port routing."""
    from repro.core import Dataflow
    from repro.etl.components import Splitter
    flow = Dataflow("ports")
    src = flow.add(ArraySource("src", _table()))
    sp = flow.add(Splitter("sp", lambda c, r: c.col("v")[r] < 50))
    cut = flow.add(StageBoundary("cut"))
    s_hi = flow.add(CollectSink("s_hi"))
    s_lo = flow.add(CollectSink("s_lo"))
    flow.connect(src, sp)
    flow.connect(sp, cut)        # port 0 (hi) -> cut -> s_hi
    flow.connect(sp, s_lo)       # port 1 (lo) -> s_lo
    flow.connect(cut, s_hi)
    assert flow.succ("sp") == ["cut", "s_lo"]
    flow.remove_passthrough("cut")
    assert flow.succ("sp") == ["s_hi", "s_lo"]   # port order intact


def test_fusion_preserves_fanout_port_order():
    from repro.core import Dataflow
    from repro.etl.components import Splitter
    flow = Dataflow("ports-fuse")
    src = flow.add(ArraySource("src", _table()))
    sp = flow.add(Splitter("sp", lambda c, r: c.col("v")[r] < 50))
    e1 = flow.add(Expression("e1", "a", lambda c, r: c.col("v")[r] * 2,
                             reads=["v"]))
    e2 = flow.add(Expression("e2", "b", lambda c, r: c.col("a")[r] + 1,
                             reads=["a"]))
    s_hi = flow.add(CollectSink("s_hi"))
    s_lo = flow.add(CollectSink("s_lo"))
    flow.connect(src, sp)
    flow.connect(sp, e1)         # port 0 (hi) -> e1 -> e2 -> s_hi
    flow.connect(sp, s_lo)       # port 1 (lo) -> s_lo
    flow.connect(e1, e2)
    flow.connect(e2, s_hi)
    opt = CostBasedOptimizer(flow, _stats(flow))
    assert [r.rule for r in opt.optimize()] == ["fuse-expressions"]
    fused_name = [n for n in flow.vertices if n.startswith("fused(")][0]
    # the fused chain still hangs off port 0, the lo sink off port 1
    assert flow.succ("sp") == [fused_name, "s_lo"]
    assert flow.succ(fused_name) == ["s_hi"]


def test_suggest_pipeline_degree_not_double_scaled():
    """Calibration statistics are already extrapolated to the full input;
    build_plan must not scale them AGAIN.  Theorem 1 grows m* ~ sqrt(rows)
    for fixed per-row cost, so quadrupling the extrapolation factor may at
    most double the degree — the historical double-scaling bug made it
    grow linearly (4x) and pin the degree at the cap."""
    def stats_at(scale):
        st = FlowStatistics(sample_rows=1000, scale=scale)
        for i in range(3):
            st.components[f"a{i}"] = ComponentStats(
                rows_in=int(1000 * scale), rows_out=int(1000 * scale),
                busy_time=0.5 * scale, calls=4,
                out_bytes=int(24_000 * scale))
        return st
    m1 = suggest_pipeline_degree(stats_at(1.0), num_splits=128, cores=128)
    m4 = suggest_pipeline_degree(stats_at(4.0), num_splits=128, cores=128)
    assert m1 >= 2                        # the model sees real work
    assert m1 <= m4 <= int(2.5 * m1)      # sqrt growth, not linear


def test_commute_refuses_column_producing_row_dropper():
    """A row-dropping component that ALSO adds columns must never commute
    (its new upstream might need what it produces)."""
    flow, _ = _commute_flow(reads=["v"])

    class _FlaggingFilter(Filter):
        def produced_columns(self):
            return frozenset({"kept_flag"})

    ff = _FlaggingFilter("ff", lambda c, r: c.col("v")[r] < 30, reads=["v"])
    flow2 = _chain_flow(ArraySource("src", _table()),
                        Lookup("lk", _dim(), "k", {"pay": "pay"}),
                        ff, CollectSink("sink"), name="flagged")
    opt = CostBasedOptimizer(flow2, _stats(flow2, ff=ComponentStats(
        rows_in=1000, rows_out=300, busy_time=1e-4, calls=4, out_bytes=100)))
    ok, reason = opt.can_commute("lk", "ff")
    assert not ok and "not a pure filter" in reason
