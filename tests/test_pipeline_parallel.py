"""GPipe-over-shard_map (Algorithm 2 on the device mesh): correctness vs the
sequential stage composition, run in a subprocess with 8 host devices (the
main test process keeps the default single device)."""
import subprocess
import sys
import textwrap

import pytest

from repro.train.pipeline_parallel import plan_microbatches


def test_plan_microbatches_theorem1():
    # total net 10s over 4 stages; t0 = 0.01 -> m* = sqrt((10-2.5)/0.01)~27
    m = plan_microbatches(10.0, 4, 0.01, m_max=64)
    assert 20 <= m <= 32
    # huge overhead -> degenerate to 1
    assert plan_microbatches(1.0, 4, 10.0) == 1
    # clamped by m_max
    assert plan_microbatches(1000.0, 2, 1e-6, m_max=16) == 16


GPIPE_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.train.pipeline_parallel import gpipe_spmd, stack_stage_params

    n_stages, m, mb, d = 4, 6, 2, 16
    from repro.launch.jax_compat import axis_types_kwargs
    mesh = jax.make_mesh((n_stages,), ("stage",),
                         devices=jax.devices()[:n_stages],
                         **axis_types_kwargs(1))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    key = jax.random.PRNGKey(0)
    ws = [jax.random.normal(jax.random.fold_in(key, i), (d, d)) * 0.5
          for i in range(n_stages)]
    stacked = stack_stage_params(ws)
    xs = jax.random.normal(jax.random.fold_in(key, 99), (m, mb, d))

    pipelined = gpipe_spmd(stage_fn, mesh, n_stages, m, axis="stage")
    from repro.launch.jax_compat import set_mesh
    with set_mesh(mesh):
        got = jax.jit(pipelined)(stacked, xs)

    # reference: sequential stage composition per microbatch
    ref = xs
    for w in ws:
        ref = jax.vmap(lambda h: stage_fn(w, h))(ref)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 1e-5, err
    print("GPIPE_OK", err)
""")


def test_gpipe_matches_sequential_subprocess():
    r = subprocess.run([sys.executable, "-c", GPIPE_PROG],
                       capture_output=True, text=True, timeout=300,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert "GPIPE_OK" in r.stdout, r.stdout + r.stderr
