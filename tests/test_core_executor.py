"""Streaming executor: shared worker pool semantics (size bound, managed
blocking), bounded inter-tree channels (backpressure, close), scheduler
failure paths (prompt cancel + re-raise, cycle detection), and ordinary /
optimized / streaming engine equivalence incl. row order."""
import threading
import time

import numpy as np
import pytest

from repro.core import (Dataflow, OptimizedEngine, OptimizeOptions,
                        OrdinaryEngine, StageBoundary, StreamingEngine,
                        partition, plan_schedule, run_tree_graph)
from repro.core.component import Component
from repro.core.executor import (CLOSED, ChannelGroup, ExecutionAborted,
                                 RunAbort, SharedWorkerPool)
from repro.core.partitioner import ExecutionTreeGraph
from repro.core.planner import (choose_channel_depth, choose_pool_width,
                                estimate_edge_bytes, plan_runtime)
from repro.etl import BUILDERS
from repro.etl.components import ArraySource, CollectSink, Filter


# ---------------------------------------------------------------------------
#  SharedWorkerPool
# ---------------------------------------------------------------------------
def test_pool_bounds_runnable_concurrency():
    pool = SharedWorkerPool(width=3)
    active, peak = [0], [0]
    lock = threading.Lock()

    def task():
        with lock:
            active[0] += 1
            peak[0] = max(peak[0], active[0])
        time.sleep(0.02)
        with lock:
            active[0] -= 1

    futs = [pool.submit(task) for _ in range(12)]
    for f in futs:
        f.result()
    pool.shutdown()
    assert peak[0] <= 3


def test_pool_managed_blocking_avoids_deadlock_at_width_one():
    """A width-1 pool whose only worker blocks on a child future must spawn
    a compensation worker instead of deadlocking (ManagedBlocker style)."""
    pool = SharedWorkerPool(width=1)

    def child():
        return 21

    def parent():
        return pool.submit(child).result() * 2   # joins inside a pool task

    assert pool.submit(parent).result(timeout=10) == 42
    pool.shutdown()


def test_pool_future_propagates_exception():
    pool = SharedWorkerPool(width=2)

    def boom():
        raise ValueError("kapow")

    fut = pool.submit(boom)
    with pytest.raises(ValueError, match="kapow"):
        fut.result(timeout=10)
    pool.shutdown()


# ---------------------------------------------------------------------------
#  Bounded channels
# ---------------------------------------------------------------------------
def test_channel_backpressure_blocks_until_consumed():
    grp = ChannelGroup()
    grp.add_edge((0, 1), capacity=2)
    grp.put((0, 1), (0, 0, "x", None))
    grp.put((0, 1), (0, 1, "x", None))
    third_in = threading.Event()

    def producer():
        grp.put((0, 1), (0, 2, "x", None))    # blocks: buffer full
        third_in.set()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not third_in.is_set()              # backpressure holds
    assert grp.get()[1] == 0                  # consumer frees a slot
    t.join(timeout=5)
    assert third_in.is_set()
    assert grp.get()[1] == 1
    assert grp.get()[1] == 2
    grp.close((0, 1))
    assert grp.get() is CLOSED


def test_channel_close_ends_iteration():
    grp = ChannelGroup()
    grp.add_edge((0, 1), capacity=4)
    for i in range(3):
        grp.put((0, 1), (0, i, "x", None))
    grp.close((0, 1))
    assert [item[1] for item in grp] == [0, 1, 2]


def test_abort_wakes_blocked_producer():
    abort = RunAbort()
    grp = ChannelGroup(abort=abort)
    grp.add_edge((0, 1), capacity=1)
    grp.put((0, 1), (0, 0, "x", None))
    raised = threading.Event()

    def producer():
        try:
            grp.put((0, 1), (0, 1, "x", None))   # blocks forever without abort
        except ExecutionAborted:
            raised.set()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.05)
    abort.trip(RuntimeError("stop"))
    t.join(timeout=5)
    assert raised.is_set()


# ---------------------------------------------------------------------------
#  Scheduler failure paths
# ---------------------------------------------------------------------------
def _two_tree_graph():
    """flow: src -> boundary tree (so g_tau has 2 trees, edge 0->1)."""
    flow = Dataflow("two")
    src = flow.add(ArraySource("src", {"x": np.arange(100, dtype=np.int64)}))
    cut = flow.add(StageBoundary("cut"))
    sink = flow.add(CollectSink("sink"))
    flow.connect(src, cut)
    flow.connect(cut, sink)
    return partition(flow)


def test_tree_error_cancels_run_and_reraises():
    """The first failing tree task aborts the whole run promptly and the
    ORIGINAL exception surfaces; downstream trees never start."""
    g = _two_tree_graph()
    ran = []

    def run_tree(tree):
        if tree.tree_id == 0:
            raise RuntimeError("tree zero exploded")
        ran.append(tree.tree_id)

    t0 = time.perf_counter()
    with pytest.raises(RuntimeError, match="tree zero exploded"):
        run_tree_graph(g, run_tree, concurrent=True)
    assert time.perf_counter() - t0 < 5.0
    assert ran == []                       # downstream cancelled, never ran


def test_plan_schedule_raises_on_cycle():
    flow = Dataflow("cyc")
    g = ExecutionTreeGraph(flow)
    g.new_tree("a")
    g.new_tree("b")
    g.add_edge(0, 1)
    g.add_edge(1, 0)
    with pytest.raises(ValueError, match="cycle"):
        plan_schedule(g)


def test_plan_schedule_waves_ok():
    g = _two_tree_graph()
    assert plan_schedule(g) == [[0], [1]]


# ---------------------------------------------------------------------------
#  Runtime planner
# ---------------------------------------------------------------------------
def test_choose_channel_depth_caps_by_memory_and_m_prime():
    # tiny splits: depth = m'
    assert choose_channel_depth(1024, num_splits=8, m_prime=8) == 8
    # huge splits: depth clamps toward 2 under the budget
    assert choose_channel_depth(8 * (1 << 30), num_splits=8, m_prime=8,
                                memory_budget_bytes=1 << 30) == 2
    assert choose_channel_depth(0, num_splits=8, m_prime=6) == 6


def test_choose_pool_width_scales_with_wave_and_mt():
    assert choose_pool_width(3, m_prime=8, wave_width=1) == 8
    assert choose_pool_width(3, m_prime=8, wave_width=2) == 16
    assert choose_pool_width(3, m_prime=2,
                             mt_threads={"lookup": 6}) == 6
    assert choose_pool_width(3, m_prime=8, cores=4) == 4
    assert choose_pool_width(3, m_prime=1000, wave_width=1, cap=64) == 64
    # concurrency can never exceed the tree count
    assert choose_pool_width(2, m_prime=4, wave_width=10, cap=64) == 8


def test_plan_runtime_widens_pool_for_streamed_boundaries(ssb_tiny):
    qf = BUILDERS["Q4.1s"](ssb_tiny)
    g = partition(qf.flow)
    gated = plan_runtime(qf.flow, g, num_splits=4, m_prime=4)
    streamed = plan_runtime(qf.flow, g, num_splits=4, m_prime=4,
                            streaming=True)
    assert streamed.pool_width > gated.pool_width


def test_estimate_edge_bytes_propagates_source_size(ssb_tiny):
    qf = BUILDERS["Q4.1s"](ssb_tiny)
    g = partition(qf.flow)
    eb = estimate_edge_bytes(qf.flow, g)
    assert set(eb) == set(g.edges)
    src_bytes = qf.flow.component("lineorder").est_output_bytes()
    assert all(0 < b <= src_bytes for b in eb.values())
    rt = plan_runtime(qf.flow, g, num_splits=4, m_prime=4)
    assert rt.pool_width >= 2
    assert set(rt.channel_depth) == set(g.edges)
    assert all(d >= 1 for d in rt.channel_depth.values())


# ---------------------------------------------------------------------------
#  Engine equivalence incl. row order (the --smoke contract, as a test)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qname", ["Q2.1", "Q4.1", "Q4.1s"])
def test_streaming_engine_matches_ordinary_rows_in_order(qname, ssb_tiny):
    qf = BUILDERS[qname](ssb_tiny)
    OrdinaryEngine(qf.flow, chunk_rows=1024).run()
    baseline = qf.sink.result()

    qf2 = BUILDERS[qname](ssb_tiny)
    r = StreamingEngine(qf2.flow, OptimizeOptions(num_splits=4)).run()
    got = qf2.sink.result()
    assert r.engine == "streaming"
    assert set(got.keys()) == set(baseline.keys())
    for k in baseline:
        np.testing.assert_array_equal(got[k], baseline[k])


def test_streaming_overlaps_row_synchronized_boundary(ssb_tiny):
    """Q4.1s has a row-sync tree boundary; the streaming engine must
    actually stream it (streamed_edges non-empty), and the non-streaming
    planner must not."""
    qf = BUILDERS["Q4.1s"](ssb_tiny)
    r_stream = StreamingEngine(qf.flow, OptimizeOptions(num_splits=4)).run()
    assert len(r_stream.streamed_edges) == 1

    qf2 = BUILDERS["Q4.1s"](ssb_tiny)
    r_plan = OptimizedEngine(qf2.flow, OptimizeOptions(num_splits=4)).run()
    assert r_plan.streamed_edges == []
    assert r_plan.copies == r_stream.copies


def test_streaming_preserves_order_on_pure_rowsync_staged_flow():
    rows = 20_000
    flow = Dataflow("staged")
    src = flow.add(ArraySource("src", {"x": np.arange(rows, dtype=np.int64)}))
    f1 = flow.add(Filter("keep_even", lambda c, r: c.col("x")[r] % 2 == 0, reads=["x"]))
    cut = flow.add(StageBoundary("cut"))
    f2 = flow.add(Filter("keep_div4", lambda c, r: c.col("x")[r] % 4 == 0, reads=["x"]))
    sink = flow.add(CollectSink("sink"))
    flow.connect(src, f1)
    flow.connect(f1, cut)
    flow.connect(cut, f2)
    flow.connect(f2, sink)
    r = StreamingEngine(flow, OptimizeOptions(num_splits=8)).run()
    np.testing.assert_array_equal(sink.result()["x"], np.arange(0, rows, 4))
    assert len(r.streamed_edges) == 1


def test_order_sensitive_member_disables_streaming_not_correctness():
    """A streamed tree may receive splits out of order; an order_sensitive
    member must force the ordered-drain fallback instead of risking the
    admission gate filling with later splits (deadlock)."""
    rows = 20_000

    class OrderedProbe(Component):
        order_sensitive = True

        def __init__(self, name):
            super().__init__(name)
            self.seen = []

        def _run(self, cache):
            self.seen.append(cache.split_index)
            return [cache]

    flow = Dataflow("ordered")
    src = flow.add(ArraySource("src", {"x": np.arange(rows, dtype=np.int64)}))
    cut = flow.add(StageBoundary("cut"))
    probe = flow.add(OrderedProbe("probe"))
    sink = flow.add(CollectSink("sink"))
    flow.connect(src, cut)
    flow.connect(cut, probe)
    flow.connect(probe, sink)
    # shards=1: split indices renumber per pass in a sharded run, so the
    # cross-pass monotonicity asserted below is a single-pass property
    r = StreamingEngine(flow, OptimizeOptions(num_splits=8, shards=1)).run()
    assert r.streamed_edges == []               # fell back to ordered drain
    assert probe.seen == sorted(probe.seen)
    np.testing.assert_array_equal(sink.result()["x"], np.arange(rows))


def test_engine_registers_metadata_when_given_a_store(ssb_tiny):
    from repro.core import MetadataStore

    store = MetadataStore()
    qf = BUILDERS["Q4.1s"](ssb_tiny)
    StreamingEngine(qf.flow, OptimizeOptions(num_splits=4),
                    metadata=store).run()
    assert qf.flow.name in store.partitions
    plan = store.runtime_plans[qf.flow.name]
    assert plan["pool_width"] >= 2
    assert len(plan["channels"]) == len(store.partitions[qf.flow.name]["edges"])
    # survives the JSON round-trip
    assert MetadataStore.from_json(store.to_json()).runtime_plans \
        == store.runtime_plans


def test_error_in_downstream_tree_cancels_blocked_producer():
    """Producer blocked on a bounded channel must not hang when the consumer
    tree dies — the abort wakes it and the original error re-raises."""
    rows = 50_000

    class Boom(Component):
        def _run(self, cache):
            raise RuntimeError("downstream boom")

    flow = Dataflow("err")
    src = flow.add(ArraySource("src", {"x": np.arange(rows, dtype=np.int64)}))
    cut = flow.add(StageBoundary("cut"))
    boom = flow.add(Boom("boom"))
    sink = flow.add(CollectSink("sink"))
    flow.connect(src, cut)
    flow.connect(cut, boom)
    flow.connect(boom, sink)
    t0 = time.perf_counter()
    with pytest.raises(RuntimeError, match="downstream boom"):
        StreamingEngine(flow, OptimizeOptions(
            num_splits=16, channel_capacity=1)).run()
    assert time.perf_counter() - t0 < 10.0


def test_shared_sink_across_trees_receives_all_rows():
    """A sink fed by its own source tree AND another tree (cross-tree
    delivery to a non-root member) — previously unsupported."""
    from repro.etl.components import Aggregate

    flow = Dataflow("shared-sink")
    s1 = flow.add(ArraySource("s1", {"k": np.zeros(10, dtype=np.int64),
                                     "v": np.arange(10, dtype=np.float64)}))
    s2 = flow.add(ArraySource("s2", {"k": np.ones(6, dtype=np.int64),
                                     "v": np.ones(6, dtype=np.float64)}))
    agg = flow.add(Aggregate("agg", ["k"], {"v": ("v", "sum")}))
    sink = flow.add(CollectSink("sink"))
    flow.connect(s1, sink)
    flow.connect(s2, agg)
    flow.connect(agg, sink)
    for engine_cls in (OptimizedEngine, StreamingEngine):
        sink.clear()
        r = engine_cls(flow, OptimizeOptions(num_splits=2)).run()
        got = sink.result()
        # 10 rows from s1 directly + 1 aggregated row from the s2->agg tree
        assert len(got["v"]) == 11, r.engine
        assert got["v"].sum() == pytest.approx(np.arange(10).sum() + 6.0)


def test_error_in_upstream_tree_reraises_via_streaming():
    class Boom(Component):
        def _run(self, cache):
            raise RuntimeError("upstream boom")

    flow = Dataflow("err-up")
    src = flow.add(ArraySource("src", {"x": np.arange(1000, dtype=np.int64)}))
    boom = flow.add(Boom("boom"))
    cut = flow.add(StageBoundary("cut"))
    sink = flow.add(CollectSink("sink"))
    flow.connect(src, boom)
    flow.connect(boom, cut)
    flow.connect(cut, sink)
    with pytest.raises(RuntimeError, match="upstream boom"):
        StreamingEngine(flow, OptimizeOptions(num_splits=4)).run()
