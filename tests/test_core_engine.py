"""Engine equivalence: ordinary vs optimized vs Kettle-like on all SSB
queries against independent oracles; copy-count accounting."""
import numpy as np
import pytest

from repro.core import (GLOBAL_CACHE_STATS, OptimizedEngine, OptimizeOptions,
                        OrdinaryEngine, get_default_backend, partition)
from repro.etl import BUILDERS, KettleEngine


def _assert_result(got, expect, qname, engine):
    assert set(got.keys()) == set(expect.keys()), (qname, engine)
    # oracle tolerance is per-backend: the float64 numpy reference is exact
    # to 1e-9, device backends accumulate in float32
    rtol = get_default_backend().oracle_rtol
    for k in expect:
        np.testing.assert_allclose(
            got[k], expect[k], rtol=rtol,
            err_msg=f"{qname} {engine} column {k}")


@pytest.mark.parametrize("qname", list(BUILDERS))
def test_engines_match_oracle(qname, ssb_small):
    expect = BUILDERS[qname](ssb_small).oracle(ssb_small)

    qf = BUILDERS[qname](ssb_small)
    OrdinaryEngine(qf.flow, chunk_rows=16_384).run()
    _assert_result(qf.sink.result(), expect, qname, "ordinary")

    qf = BUILDERS[qname](ssb_small)
    OptimizedEngine(qf.flow, OptimizeOptions(num_splits=6)).run()
    _assert_result(qf.sink.result(), expect, qname, "optimized")

    qf = BUILDERS[qname](ssb_small)
    KettleEngine(qf.flow, chunk_rows=16_384).run()
    _assert_result(qf.sink.result(), expect, qname, "kettle")


@pytest.mark.parametrize("num_splits", [1, 2, 3, 5, 8])
def test_optimized_any_split_count(num_splits, ssb_small):
    qf = BUILDERS["Q4.1"](ssb_small)
    expect = qf.oracle(ssb_small)
    OptimizedEngine(qf.flow, OptimizeOptions(num_splits=num_splits)).run()
    _assert_result(qf.sink.result(), expect, "Q4.1",
                   f"optimized-m{num_splits}")


def test_shared_caching_removes_copies(ssb_small):
    """The paper's §3 claim: shared caching eliminates the per-edge copy.
    Optimized copies only on tree->tree edges; ordinary copies everywhere."""
    qf1 = BUILDERS["Q4.1"](ssb_small)
    r_ord = OrdinaryEngine(qf1.flow, chunk_rows=8192).run()
    qf2 = BUILDERS["Q4.1"](ssb_small)
    r_opt = OptimizedEngine(qf2.flow, OptimizeOptions(num_splits=6)).run()
    assert r_opt.copies < r_ord.copies / 3
    assert r_opt.bytes_copied < r_ord.bytes_copied


def test_shared_vs_separate_cache_same_result(ssb_small):
    qf1 = BUILDERS["Q3.1"](ssb_small)
    OptimizedEngine(qf1.flow, OptimizeOptions(num_splits=4,
                                              shared_cache=True)).run()
    a = qf1.sink.result()
    qf2 = BUILDERS["Q3.1"](ssb_small)
    OptimizedEngine(qf2.flow, OptimizeOptions(num_splits=4,
                                              shared_cache=False)).run()
    b = qf2.sink.result()
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-12)


def test_concurrent_trees_match_sequential_trees(ssb_small):
    qf1 = BUILDERS["Q2.1"](ssb_small)
    OptimizedEngine(qf1.flow, OptimizeOptions(num_splits=4,
                                              concurrent_trees=True)).run()
    a = qf1.sink.result()
    qf2 = BUILDERS["Q2.1"](ssb_small)
    OptimizedEngine(qf2.flow, OptimizeOptions(num_splits=4,
                                              concurrent_trees=False)).run()
    b = qf2.sink.result()
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-12)


def test_inside_component_multithreading_same_result(ssb_small):
    """§4.3: per-component row-range threads + row-order synchronizer."""
    qf1 = BUILDERS["Q4.1"](ssb_small)
    expect = qf1.oracle(ssb_small)
    mt = {"lookup_customer": 4, "lookup_supplier": 4, "filter_unmatched": 4}
    OptimizedEngine(qf1.flow, OptimizeOptions(num_splits=4,
                                              mt_threads=mt)).run()
    _assert_result(qf1.sink.result(), expect, "Q4.1", "optimized-mt")

    qf2 = BUILDERS["Q4.1"](ssb_small)
    KettleEngine(qf2.flow, chunk_rows=16_384, mt_threads=mt).run()
    _assert_result(qf2.sink.result(), expect, "Q4.1", "kettle-mt")


def test_engine_run_reports(ssb_tiny):
    qf = BUILDERS["Q1.1"](ssb_tiny)
    run = OptimizedEngine(qf.flow, OptimizeOptions(num_splits=2)).run()
    assert run.engine == "optimized"
    assert run.wall_time > 0
    assert run.trees is not None and len(run.trees) == 2
    assert "lookup_date" in run.activity_times
