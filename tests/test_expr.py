"""Column-expression DSL: semantics, provenance, dtype handling, backend
compile parity, component integration, the Session front end, and the typed
config module."""
import warnings

import numpy as np
import pytest

import repro
from repro import Session, col, lit, where
from repro.core import config
from repro.core.backend import get_backend, resolve_backend
from repro.core.expr import ColumnsView, expr_reads
from repro.core.optimizer import CostBasedOptimizer, run_calibration
from repro.core.planner import infer_schema
from repro.core.shared_cache import SharedCache
from repro.etl.components import (Aggregate, ArraySource, CollectSink,
                                  DimTable, Expression, Filter, Project, Sort)
from repro.etl.queries import build_q4
from repro.etl.ssb import generate


COLS = {
    "a": np.array([0, 1, 2, 3, 4], dtype=np.int64),
    "b": np.array([2, 2, 0, 2, 2], dtype=np.int64),
    "f": np.array([0.5, -1.5, 2.0, -2.5, 3.0], dtype=np.float64),
    "i32": np.array([1, 2, 3, 4, 5], dtype=np.int32),
}


def ev(expr, cols=None):
    return expr.eval_columns(cols or COLS)


# ---------------------------------------------------------------------------
#  semantics
# ---------------------------------------------------------------------------
def test_arithmetic_matches_numpy():
    np.testing.assert_array_equal(ev(col("a") + col("b")), COLS["a"] + COLS["b"])
    np.testing.assert_array_equal(ev(col("a") - 1), COLS["a"] - 1)
    np.testing.assert_array_equal(ev(2 * col("a")), 2 * COLS["a"])
    np.testing.assert_array_equal(ev(col("a") // 2), COLS["a"] // 2)
    np.testing.assert_array_equal(ev(col("a") % 3), COLS["a"] % 3)
    np.testing.assert_array_equal(ev(col("f") / 2), COLS["f"] / 2)
    np.testing.assert_array_equal(ev(-col("f")), -COLS["f"])
    np.testing.assert_array_equal(ev(abs(col("f"))), np.abs(COLS["f"]))
    np.testing.assert_array_equal(ev(10 - col("a")), 10 - COLS["a"])


def test_comparisons_and_boolean_ops():
    np.testing.assert_array_equal(ev(col("a") == 2), COLS["a"] == 2)
    np.testing.assert_array_equal(ev(col("a") != 2), COLS["a"] != 2)
    np.testing.assert_array_equal(ev((col("a") > 1) & (col("b") == 2)),
                                  (COLS["a"] > 1) & (COLS["b"] == 2))
    np.testing.assert_array_equal(ev((col("a") < 1) | (col("b") < 1)),
                                  (COLS["a"] < 1) | (COLS["b"] < 1))
    np.testing.assert_array_equal(ev(~(col("a") >= 3)), ~(COLS["a"] >= 3))
    np.testing.assert_array_equal(ev((col("a") > 1) ^ (col("b") > 1)),
                                  (COLS["a"] > 1) ^ (COLS["b"] > 1))


def test_between_isin_where_cast():
    np.testing.assert_array_equal(ev(col("a").between(1, 3)),
                                  (COLS["a"] >= 1) & (COLS["a"] <= 3))
    np.testing.assert_array_equal(ev(col("a").isin([0, 4])),
                                  (COLS["a"] == 0) | (COLS["a"] == 4))
    np.testing.assert_array_equal(
        ev(where(col("f") > 0, col("f"), lit(0.0))),
        np.where(COLS["f"] > 0, COLS["f"], 0.0))
    out = ev(col("a").cast(np.float32))
    assert out.dtype == np.float32
    out = ev((col("a") * col("b")).astype(np.int16))
    assert out.dtype == np.int16
    np.testing.assert_array_equal(out, (COLS["a"] * COLS["b"]).astype(np.int16))


def test_dtype_promotion_follows_numpy():
    assert ev(col("i32") + col("a")).dtype == (COLS["i32"] + COLS["a"]).dtype
    assert ev(col("i32") + col("f")).dtype == (COLS["i32"] + COLS["f"]).dtype
    assert ev(col("a") / 2).dtype == (COLS["a"] / 2).dtype         # true div
    assert ev(col("a") > 1).dtype == np.bool_


def test_rows_slicing_matches_legacy_callable_convention():
    e = (col("a") + col("b")) * 2
    view = ColumnsView(COLS)
    np.testing.assert_array_equal(e(view, slice(1, 4)),
                                  (COLS["a"][1:4] + COLS["b"][1:4]) * 2)


def test_bool_of_expr_raises():
    with pytest.raises(TypeError, match="truth value"):
        bool(col("a") == 1)
    with pytest.raises(TypeError):
        if col("a"):              # the `and`/`or` misuse path
            pass


def test_lit_rejects_arrays_and_isin_empty():
    with pytest.raises(TypeError, match="scalars only"):
        lit(np.arange(4))
    with pytest.raises(ValueError):
        col("a").isin([])
    assert lit(np.int64(7)).value == 7      # 0-d/np scalars unwrap


def test_unknown_column_names_offender():
    with pytest.raises(KeyError, match="no_such"):
        ev(col("no_such"))


# ---------------------------------------------------------------------------
#  provenance
# ---------------------------------------------------------------------------
def test_columns_derived_exactly():
    assert col("a").columns() == frozenset({"a"})
    assert (col("a") + 1).columns() == frozenset({"a"})
    e = where(col("c") > 0, col("a") * col("b"), lit(0)).cast(np.int32)
    assert e.columns() == frozenset({"a", "b", "c"})
    assert (col("a").between(1, 2) & col("b").isin([1, 2])).columns() \
        == frozenset({"a", "b"})
    assert expr_reads(col("a") + col("b")) == frozenset({"a", "b"})
    assert expr_reads(lambda c, r: c.col("a")[r]) is None


def test_repr_round_trips_structure():
    e = (col("a") >= 1) & (col("b") == lit(2))
    assert "col('a')" in repr(e) and ">=" in repr(e) and "&" in repr(e)


# ---------------------------------------------------------------------------
#  component integration
# ---------------------------------------------------------------------------
def test_filter_expression_derive_reads_from_ast():
    f = Filter("f", (col("a") > 1) & (col("b") == 2))
    assert f.consumed_columns() == frozenset({"a", "b"})
    assert f.produced_columns() == frozenset()
    e = Expression("e", "out", col("a") * col("f"))
    assert e.consumed_columns() == frozenset({"a", "f"})
    assert e.produced_columns() == frozenset({"out"})
    # segment ops carry the exact per-op reads
    assert f.segment_ops()[0][2] == frozenset({"a", "b"})
    assert e.segment_ops()[0][3] == frozenset({"a", "f"})


def test_conflicting_manual_reads_raises():
    with pytest.raises(ValueError, match="conflicts"):
        Filter("f", col("a") > 1, reads=["a", "b"])
    with pytest.raises(ValueError, match="conflicts"):
        Expression("e", "o", col("a") + 1, reads=["b"])


def test_constant_predicate_raises():
    with pytest.raises(ValueError, match="reads no columns"):
        Filter("f", lit(1) == lit(1))
    with pytest.raises(ValueError, match="reads no columns"):
        Expression("e", "c", lit(5))


def test_legacy_callable_without_reads_warns_deprecation():
    with pytest.warns(DeprecationWarning, match="repro.col"):
        f = Filter("f", lambda c, r: c.col("a")[r] > 1)
    assert f.consumed_columns() is None
    with pytest.warns(DeprecationWarning):
        e = Expression("e", "o", lambda c, r: c.col("a")[r] + 1)
    assert e.consumed_columns() is None
    # declared reads stay warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        Filter("f2", lambda c, r: c.col("a")[r] > 1, reads=["a"])
        Expression("e2", "o", lambda c, r: c.col("a")[r] + 1, reads=["a"])


def test_col_references_accepted_for_column_arguments():
    agg = Aggregate("g", [col("a")], {"s": (col("f"), "sum")})
    assert agg.group_by == ["a"] and agg.aggs == {"s": ("f", "sum")}
    assert agg.consumed_columns() == frozenset({"a", "f"})
    assert agg.produced_columns() == frozenset({"a", "s"})
    assert Sort("s", [col("a")]).by == ["a"]
    assert Project("p", [col("a"), "b"]).keep == ["a", "b"]
    with pytest.raises(TypeError, match="composite"):
        Aggregate("g", [], {"s": (col("a") + 1, "sum")})


def test_filter_runs_identically_from_expr_and_lambda():
    for backend in ("numpy", "jax"):
        bk = get_backend(backend)
        cache_e = SharedCache({k: v.copy() for k, v in COLS.items()}, 5)
        cache_l = SharedCache({k: v.copy() for k, v in COLS.items()}, 5)
        fe = Filter("fe", (col("a") > 1) & (col("b") == 2))
        fl = Filter("fl", lambda c, r: (c.col("a")[r] > 1)
                    & (c.col("b")[r] == 2), reads=["a", "b"])
        fe.backend = fl.backend = bk
        fe.process(cache_e)
        fl.process(cache_l)
        for k in COLS:
            np.testing.assert_array_equal(
                np.asarray(cache_e.col(k)), np.asarray(cache_l.col(k)),
                err_msg=f"{backend}:{k}")


# ---------------------------------------------------------------------------
#  jax-vs-numpy compile parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("expr", [
    col("a") + col("b") * 2,
    (col("a") >= 1) & (col("b") == 2),
    col("a").between(1, 3) | ~(col("i32") > 3),
    where(col("f") > 0, col("f") * 2, lit(-1.0)),
    ((col("a") - col("b")) % 3).cast(np.int32),
    abs(-col("f")) / 2,
], ids=lambda e: repr(e)[:48])
def test_jax_compile_parity(expr):
    """The SAME AST evaluated eagerly on numpy and through the jax backend's
    jitted expression runner must agree in value (dtypes modulo the device
    canonicalization: x64-off jax stores 64-bit as 32-bit)."""
    jbk = get_backend("jax")
    host = ev(expr)
    cache = SharedCache({k: v.copy() for k, v in COLS.items()}, 5)
    dev = np.asarray(jbk.eval_expression(expr, cache, slice(0, 5)))
    if np.asarray(host).dtype == np.bool_:
        np.testing.assert_array_equal(dev.astype(bool), host)
    else:
        np.testing.assert_allclose(dev, host, rtol=1e-6)


def test_jax_runner_is_cached_and_traces_once_per_shape():
    e = col("a") * 2 + col("b")
    jbk = get_backend("jax")
    cache = SharedCache({k: v.copy() for k, v in COLS.items()}, 5)
    jbk.eval_expression(e, cache, slice(0, 5))
    names, fn = e.__dict__["_jax_compiled"]
    assert names == ["a", "b"]
    jbk.eval_expression(e, cache, slice(0, 5))
    assert e.__dict__["_jax_compiled"][1] is fn       # same compiled runner


# ---------------------------------------------------------------------------
#  schema inference
# ---------------------------------------------------------------------------
def _mini_flow(pred, with_sink=True):
    from repro.core import Dataflow
    flow = Dataflow("mini")
    comps = [ArraySource("src", {k: v.copy() for k, v in COLS.items()}),
             Expression("e", "d", col("a") + col("b")),
             Filter("f", pred),
             Project("p", ["d", "f"])]
    sink = CollectSink("sink")
    if with_sink:
        comps.append(sink)
    flow.chain(*comps)
    return flow, sink


def test_infer_schema_exact_with_dsl():
    flow, _ = _mini_flow(col("d") > 2)
    schemas = infer_schema(flow, strict=True)
    assert schemas["e"] == frozenset(COLS) | {"d"}
    assert schemas["p"] == frozenset({"d", "f"})
    assert schemas["sink"] == frozenset({"d", "f"})


def test_infer_schema_strict_catches_bad_read():
    flow, _ = _mini_flow(col("typo") > 2)
    with pytest.raises(ValueError, match="typo"):
        infer_schema(flow, strict=True)


def test_infer_schema_fan_in_intersects_branch_schemas():
    """A column produced on only ONE input branch of a fan-in is not safely
    readable downstream — the merged input schema is the intersection, so
    strict mode rejects a read that a union would have silently passed."""
    from repro.core import Dataflow
    from repro.etl.components import Splitter, Union
    flow = Dataflow("diamond")
    src = ArraySource("src", dict(COLS))
    split = Splitter("split", lambda c, r: c.col("a")[r] % 2 == 0)
    ea = Expression("ea", "x", col("a") + 1)        # only branch A adds 'x'
    union = Union("union")
    filt = Filter("filt", col("x") > 0)
    sink = CollectSink("sink")
    for comp in (src, split, ea, union, filt, sink):
        flow.add(comp)
    flow.connect("src", "split")
    flow.connect("split", "ea")
    flow.connect("split", "union")                  # branch B: no 'x'
    flow.connect("ea", "union")
    flow.connect("union", "filt")
    flow.connect("filt", "sink")
    schemas = infer_schema(flow)
    assert schemas["union"] == frozenset(COLS)      # 'x' intersected away
    with pytest.raises(ValueError, match="'x'"):
        infer_schema(flow, strict=True)


def test_session_options_backend_not_clobbered(ssb):
    """Session(options=OptimizeOptions(backend=...)) must survive run()
    with no per-call backend override."""
    from repro.core import OptimizeOptions
    f = (repro.flow("mini").source(ssb.lineorder)
         .filter(col("lo_quantity") < 25).sink())
    session = Session(options=OptimizeOptions(backend="jax"))
    res = session.run(f, engine="streaming", num_splits=2)
    assert res.run.backend == "jax"
    res = session.run(f, engine="streaming", num_splits=2, backend="numpy")
    assert res.run.backend == "numpy"              # per-call still wins


def test_infer_schema_unknown_lambda_poisons_downstream():
    with pytest.warns(DeprecationWarning):
        flow, _ = _mini_flow(lambda c, r: c.col("d")[r] > 2)
    schemas = infer_schema(flow, strict=True)   # no raise: unknown, not wrong
    assert schemas["e"] is not None
    assert schemas["f"] is not None             # Filter propagates its input
    # a component with UNKNOWN output schema (generic FnComponent) poisons
    # everything downstream of it
    from repro.core import Dataflow, FnComponent
    flow2 = Dataflow("mini2")
    flow2.chain(ArraySource("src", dict(COLS)),
                FnComponent("fn", lambda cache: None),
                CollectSink("sink"))
    schemas2 = infer_schema(flow2, strict=True)
    assert schemas2["src"] == frozenset(COLS)
    assert schemas2["fn"] is None and schemas2["sink"] is None


# ---------------------------------------------------------------------------
#  optimizer: zero undeclared-read refusals on DSL flows
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def ssb():
    return generate(lineorder_rows=4000, customers=300, suppliers=50,
                    parts=200, seed=11)


def _undeclared(refusals):
    return [r for r in refusals if "undeclared" in r.detail]


def test_dsl_flow_has_zero_undeclared_refusals(ssb):
    qf = build_q4(ssb, use_dsl=True)
    bk = resolve_backend("numpy")
    stats = run_calibration(qf.flow, sample_rows=512, backend=bk)
    opt = CostBasedOptimizer(qf.flow, stats, streaming=True)
    opt.optimize()
    assert _undeclared(opt.refusals) == []


def test_undeclared_lambda_flow_reports_refusal(ssb):
    from repro.core import Dataflow
    flow = Dataflow("undeclared")
    with pytest.warns(DeprecationWarning):
        comps = [ArraySource("src", ssb.lineorder),
                 Expression("e", "d", col("lo_revenue") + 1),
                 Filter("f", lambda c, r: c.col("lo_quantity")[r] < 25),
                 CollectSink("sink")]
    flow.chain(*comps)
    stats = run_calibration(flow, sample_rows=512,
                            backend=resolve_backend("numpy"))
    opt = CostBasedOptimizer(flow, stats, streaming=True)
    opt.optimize()
    bad = _undeclared(opt.refusals)
    assert bad and bad[0].rule == "filter-commute"


# ---------------------------------------------------------------------------
#  Session front end
# ---------------------------------------------------------------------------
def test_session_flowbuilder_end_to_end(ssb):
    date = DimTable(ssb.date["d_datekey"], {"d_year": ssb.date["d_year"]})
    f = (repro.flow("q1-mini")
         .source(ssb.lineorder, name="lineorder")
         .lookup(date, "lo_orderdate", {"d_year": "d_year"},
                 matched_flag="d_ok")
         .filter(col("d_ok") & (col("d_year") == 1993)
                 & col("lo_discount").between(1, 3)
                 & (col("lo_quantity") < 25))
         .derive("rev", col("lo_extendedprice") * col("lo_discount"))
         .aggregate([], {"revenue": ("rev", "sum")})
         .sink())
    assert f.schema == frozenset({"revenue"})

    from repro.etl.queries import build_q1
    expect = build_q1(ssb).oracle(ssb)
    # the engines follow REPRO_BACKEND: float32 device accumulation cannot
    # hit the float64 oracle exactly, so use the backend's tolerance
    rtol = resolve_backend(None).oracle_rtol
    session = Session()
    results = {}
    for engine in Session.ENGINES:
        res = session.run(f, engine=engine, num_splits=2) \
            if engine in ("optimized", "streaming") else session.run(f, engine=engine)
        np.testing.assert_allclose(res.table["revenue"], expect["revenue"],
                                   rtol=rtol)
        results[engine] = res
    # copy-everywhere baselines record more copies than shared caching
    assert results["streaming"].run.copies < results["ordinary"].run.copies
    # adaptive + fused re-run stays correct and records its rewrites
    res = session.run(f, engine="streaming", optimize=2, fuse=True,
                      num_splits=2, calibration_rows=512)
    np.testing.assert_allclose(res.table["revenue"], expect["revenue"],
                               rtol=rtol)
    assert any(r["rule"] == "fuse-segment" for r in res.run.rewrites)
    assert not [r for r in res.run.refusals if "undeclared" in r["detail"]]
    stats = session.calibrate(f, sample_rows=256)
    assert stats.sample_rows == 256


def test_session_rejects_bad_usage(ssb):
    f = (repro.flow("mini").source(ssb.lineorder)
         .filter(col("lo_quantity") < 25).sink())
    session = Session()
    with pytest.raises(ValueError, match="unknown engine"):
        session.run(f, engine="warp")
    with pytest.raises(ValueError, match="baseline"):
        session.run(f, engine="ordinary", optimize=2)
    with pytest.raises(TypeError, match="num_splits"):
        session.run(f, engine="kettle", num_splits=4)
    with pytest.raises(TypeError, match="cannot run"):
        session.run(42)


def test_flowbuilder_guards():
    with pytest.raises(ValueError, match="must start with .source"):
        repro.flow("x").filter(col("a") > 0)
    b = repro.flow("x").source({"a": np.arange(4)})
    with pytest.raises(ValueError, match="already has a source"):
        b.source({"b": np.arange(4)})
    flow_obj = b.filter(col("a") > 1).sink()
    with pytest.raises(ValueError, match="sealed"):
        b.filter(col("a") > 2)
    # build-time read validation
    with pytest.raises(ValueError, match="not in its input schema"):
        (repro.flow("y").source({"a": np.arange(4)})
         .derive("d", col("missing") + 1).sink())
    assert flow_obj.schema == frozenset({"a"})


def test_session_runs_queryflow_objects(ssb):
    qf = build_q4(ssb)
    expect = qf.oracle(ssb)
    res = Session().run(qf, engine="streaming", num_splits=2)
    rtol = resolve_backend(None).oracle_rtol
    for k in expect:
        np.testing.assert_allclose(res.table[k], expect[k], rtol=rtol)


# ---------------------------------------------------------------------------
#  typed config accessors
# ---------------------------------------------------------------------------
def test_config_typed_accessors(monkeypatch):
    monkeypatch.delenv(config.ENV_BACKEND, raising=False)
    assert config.backend_name() is None
    monkeypatch.setenv(config.ENV_BACKEND, " jax ")
    assert config.backend_name() == "jax"
    monkeypatch.setenv(config.ENV_FUSION, "1")
    assert config.fusion_default() is True
    monkeypatch.setenv(config.ENV_FUSION, "0")
    assert config.fusion_default() is False
    monkeypatch.setenv(config.ENV_ARENA, "0")
    assert config.arena_enabled() is False
    monkeypatch.setenv(config.ENV_ARENA_MAX_MB, "64")
    assert config.arena_max_bytes() == 64 << 20
    monkeypatch.setenv(config.ENV_CACHE_GUARD, "1")
    assert config.cache_guard_enabled() is True
    monkeypatch.setenv(config.ENV_OPTEQ_EXAMPLES, "7")
    assert config.opteq_examples() == 7
    monkeypatch.setenv(config.ENV_FLOW_STYLE, "lambda")
    assert config.flow_style() == "lambda"
    monkeypatch.setenv(config.ENV_FLOW_STYLE, "nope")
    with pytest.raises(ValueError, match="REPRO_FLOW_STYLE"):
        config.flow_style()
    monkeypatch.setenv(config.ENV_FLOW_STYLE, "dsl")
    snap = config.snapshot()
    assert snap["arena_max_bytes"] == 64 << 20
    assert snap["flow_style"] == "dsl"


def test_flow_style_switches_builders(ssb, monkeypatch):
    monkeypatch.setenv(config.ENV_FLOW_STYLE, "lambda")
    assert build_q4(ssb).style == "lambda"
    monkeypatch.delenv(config.ENV_FLOW_STYLE, raising=False)
    assert build_q4(ssb).style == "dsl"
