"""End-to-end system behaviour: the full stack wired together."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import OptimizedEngine, OptimizeOptions, OrdinaryEngine
from repro.etl import BUILDERS
from repro.launch.train import train_loop


def test_paper_quickstart_path(ssb_small):
    """Ordinary vs optimized on Q4.1: same result, far fewer copies —
    the paper's §3 shared-caching claim, end to end."""
    qf1 = BUILDERS["Q4.1"](ssb_small)
    r1 = OrdinaryEngine(qf1.flow).run()
    a = qf1.sink.result()
    qf2 = BUILDERS["Q4.1"](ssb_small)
    r2 = OptimizedEngine(qf2.flow, OptimizeOptions(num_splits=8)).run()
    b = qf2.sink.result()
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-9)
    # shared caching copies only on tree->tree edges (aggregated rows):
    # orders of magnitude fewer bytes moved regardless of chunk/split counts
    assert r2.bytes_copied < r1.bytes_copied / 10


def test_etl_feeds_training_loss_decreases():
    """ETL input pipeline -> jit'd train loop: loss drops on a small LM."""
    cfg = get_config("stablelm-3b", smoke=True).replace(grad_accum=2)
    res = train_loop(cfg, steps=25, batch=8, seq_len=64, log_every=100)
    assert res["steps_done"] == 25
    assert np.mean(res["losses"][-5:]) < res["losses"][0]
    assert res["tokens_per_s"] > 0


def test_moe_arch_trains_via_driver():
    cfg = get_config("mixtral-8x7b", smoke=True).replace(grad_accum=1)
    res = train_loop(cfg, steps=8, batch=4, seq_len=32, log_every=100)
    assert np.isfinite(res["losses"]).all()


def test_ssm_arch_trains_via_driver():
    cfg = get_config("falcon-mamba-7b", smoke=True).replace(grad_accum=1)
    res = train_loop(cfg, steps=8, batch=4, seq_len=32, log_every=100)
    assert np.isfinite(res["losses"]).all()


def test_generation_deterministic_and_shaped():
    from repro.models import init_params
    from repro.train.serve_step import generate
    cfg = get_config("granite-20b", smoke=True)     # MQA decode path
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 2,
                                 cfg.vocab_size)
    out1 = generate(params, cfg, prompts, max_new_tokens=8)
    out2 = generate(params, cfg, prompts, max_new_tokens=8)
    assert out1.shape == (3, 8)
    np.testing.assert_array_equal(np.array(out1), np.array(out2))
