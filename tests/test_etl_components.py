"""Unit + property tests for the ETL component library."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover — env without the `test` extra
    from _hypothesis_compat import given, settings, st

from repro.core.shared_cache import SharedCache, concat_caches
from repro.etl.components import (Aggregate, ArraySource, CollectSink,
                                  Converter, DimTable, Expression, Filter,
                                  Lookup, Merge, Project, Sort, Splitter,
                                  Union)


def _cache(**cols):
    return SharedCache({k: np.asarray(v) for k, v in cols.items()})


# ---------------------------------------------------------------- row sync
def test_filter_compacts_in_place():
    c = _cache(x=np.arange(10, dtype=np.int64))
    buf = c.columns["x"]
    Filter("f", lambda ca, r: ca.col("x")[r] % 2 == 0,
           reads=["x"]).process(c)
    assert c.n == 5
    np.testing.assert_array_equal(c.col("x"), [0, 2, 4, 6, 8])
    assert c.columns["x"] is buf           # same buffer: shared caching


def test_filter_multithreaded_ranges_equal_single():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, 1000)
    f = Filter("f", lambda ca, r: ca.col("x")[r] > 50, reads=["x"])
    c1 = _cache(x=x.copy())
    f.process(c1)
    c2 = _cache(x=x.copy())
    ranges = c2.row_ranges(4)
    parts = [f.process_range(c2, r) for r in ranges]
    f.merge_ranges(c2, ranges, parts)
    np.testing.assert_array_equal(c1.col("x"), c2.col("x"))


def test_lookup_matched_and_unmatched():
    dim = DimTable(np.array([1, 2, 3]), {"v": np.array([10, 20, 30])})
    c = _cache(k=np.array([2, 9, 1, 3]))
    Lookup("lk", dim, "k", {"v": "v"}).process(c)
    np.testing.assert_array_equal(c.col("v"), [20, -1, 10, 30])


def test_lookup_row_filter_marks_unqualified():
    dim = DimTable(np.array([1, 2, 3]), {"v": np.array([10, 20, 30])},
                   row_filter=np.array([True, False, True]))
    c = _cache(k=np.array([1, 2, 3]))
    Lookup("lk", dim, "k", {"v": "v"}).process(c)
    np.testing.assert_array_equal(c.col("v"), [10, -1, 30])


def test_expression_and_project_and_converter():
    c = _cache(a=np.array([1, 2]), b=np.array([10, 20]))
    Expression("e", "s", lambda ca, r: ca.col("a")[r] + ca.col("b")[r],
               reads=["a", "b"]).process(c)
    np.testing.assert_array_equal(c.col("s"), [11, 22])
    Converter("cv", {"s": np.float32}).process(c)
    assert c.col("s").dtype == np.float32
    Project("p", ["s"]).process(c)
    assert c.names == ["s"]


def test_splitter_two_ports():
    c = _cache(x=np.arange(10))
    outs = Splitter("sp", lambda ca, r: ca.col("x")[r] < 5).process(c)
    np.testing.assert_array_equal(outs[0].col("x"), np.arange(5))
    np.testing.assert_array_equal(outs[1].col("x"), np.arange(5, 10))


# ------------------------------------------------------------------- block
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(-100, 100)),
                min_size=1, max_size=300))
@settings(max_examples=50, deadline=None)
def test_aggregate_matches_numpy(pairs):
    keys = np.array([p[0] for p in pairs], dtype=np.int64)
    vals = np.array([p[1] for p in pairs], dtype=np.int64)
    agg = Aggregate("a", ["k"], {"s": ("v", "sum"), "mn": ("v", "min"),
                                 "mx": ("v", "max"), "av": ("v", "avg"),
                                 "ct": ("v", "count")})
    out = agg.finish([_cache(k=keys, v=vals)])
    for i, k in enumerate(out.col("k")):
        sel = vals[keys == k]
        assert out.col("s")[i] == sel.sum()
        assert out.col("mn")[i] == sel.min()
        assert out.col("mx")[i] == sel.max()
        assert out.col("av")[i] == pytest.approx(sel.mean())
        assert out.col("ct")[i] == len(sel)
    assert sorted(set(keys.tolist())) == out.col("k").tolist()


def test_aggregate_global_no_groups():
    out = Aggregate("a", [], {"s": ("v", "sum")}).finish(
        [_cache(v=np.array([1.0, 2.0, 3.0]))])
    assert out.n == 1
    assert out.col("s")[0] == 6.0


def test_aggregate_accumulates_multiple_caches():
    agg = Aggregate("a", ["k"], {"s": ("v", "sum")})
    state = agg.new_state()
    agg.accumulate(state, _cache(k=np.array([0, 1]), v=np.array([1, 2])))
    agg.accumulate(state, _cache(k=np.array([1, 0]), v=np.array([3, 4])))
    out = agg.finish(state)
    np.testing.assert_array_equal(out.col("s"), [5.0, 5.0])


@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_sort_matches_numpy(xs):
    arr = np.array(xs, dtype=np.int64)
    out = Sort("s", ["x"]).finish([_cache(x=arr.copy())])
    np.testing.assert_array_equal(out.col("x"), np.sort(arr))


def test_sort_descending_and_multikey():
    c = _cache(a=np.array([1, 0, 1, 0]), b=np.array([5, 6, 7, 8]))
    out = Sort("s", ["a", "b"]).finish([c])
    np.testing.assert_array_equal(out.col("a"), [0, 0, 1, 1])
    np.testing.assert_array_equal(out.col("b"), [6, 8, 5, 7])


# --------------------------------------------------------------- semi-block
def test_union_concats_all_upstreams():
    out = Union("u").finish([_cache(x=np.array([1, 2])),
                             _cache(x=np.array([3]))])
    assert sorted(out.col("x").tolist()) == [1, 2, 3]


def test_merge_sorts_by_key():
    out = Merge("m", ["x"]).finish([_cache(x=np.array([5, 1])),
                                    _cache(x=np.array([3]))])
    np.testing.assert_array_equal(out.col("x"), [1, 3, 5])


# ---------------------------------------------------------------- caches
def test_shared_cache_split_is_zero_copy_views():
    c = _cache(x=np.arange(100))
    splits = c.split(4)
    assert [s.n for s in splits] == [25, 25, 25, 25]
    splits[0].columns["x"][0] = 999
    assert c.col("x")[0] == 999            # view, not copy


def test_concat_restores_split_order():
    a = SharedCache({"x": np.array([3, 4])}, split_index=1)
    b = SharedCache({"x": np.array([1, 2])}, split_index=0)
    out = concat_caches([a, b], ordered=True)
    np.testing.assert_array_equal(out.col("x"), [1, 2, 3, 4])


def test_source_chunking_covers_all_rows(ssb_tiny):
    src = ArraySource("lo", ssb_tiny.lineorder)
    total = sum(c.n for c in src.chunks(1024))
    assert total == src.total_rows()
