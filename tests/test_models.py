"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + finiteness assertions) and model-level equivalences."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_shapes
from repro.models import init_params, forward_train, param_count
from repro.models.transformer import (decode_step, forward_prefill,
                                      grow_cache, make_cache_shapes)
from repro.models.layers import NO_RULES


def _batch(cfg, B=2, S=32, seed=1):
    key = jax.random.PRNGKey(seed)
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "labels": jax.random.randint(key, (B, S), 0,
                                             cfg.vocab_size)}
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["vision"] = jax.random.normal(key, (B, cfg.n_vision_tokens,
                                              cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch_id):
    """Instantiate the reduced config, run one forward + one train step,
    assert output shapes and no NaNs (the per-arch smoke requirement)."""
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_step import make_train_step

    cfg = get_config(arch_id, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=2, S=32)
    loss, metrics = jax.jit(
        lambda p, b: forward_train(p, b, cfg))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch_id

    opt = init_opt_state(params, cfg)
    step = make_train_step(cfg, OptConfig(total_steps=10))
    new_params, new_opt, m2 = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m2["loss"]))
    assert int(new_opt["step"]) == 1
    # params actually changed
    deltas = [float(np.max(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b_, np.float32))))
              for a, b_ in zip(jax.tree.leaves(params),
                               jax.tree.leaves(new_params))]
    assert max(deltas) > 0.0


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if "hubert" not in a])
def test_arch_decode_matches_teacher_forcing(arch_id):
    """prefill(prefix) + decode_step(tokens one by one) == prefill(longer)."""
    cfg = get_config(arch_id, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    batch8 = {"tokens": toks[:, :8]}
    batch12 = {"tokens": toks}
    if cfg.family == "vlm":
        vis = jax.random.normal(jax.random.PRNGKey(3),
                                (2, cfg.n_vision_tokens, cfg.d_model)) * 0.02
        batch8["vision"] = vis
        batch12["vision"] = vis
    lg, cache = forward_prefill(params, batch8, cfg)
    cache = grow_cache(cache, cfg, 12)
    for t in range(8, 12):
        lg, cache = decode_step(params, cache, {"tokens": toks[:, t:t + 1]},
                                cfg)
    lg_ref, _ = forward_prefill(params, batch12, cfg)
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(lg_ref[:, 0], np.float32),
                               rtol=0.05, atol=0.05)


def test_param_count_matches_defs():
    """configs.base analytic count == actual init tree size."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id, smoke=True)
        assert param_count(cfg) == cfg.param_count(), arch_id


def test_full_config_param_counts_sane():
    """Full-size param counts are within the advertised ballpark."""
    expect = {"falcon-mamba-7b": (6e9, 9e9),
              "grok-1-314b": (290e9, 340e9),
              "mixtral-8x7b": (42e9, 52e9),
              "qwen2.5-32b": (30e9, 36e9),
              "granite-20b": (18e9, 23e9),
              "stablelm-3b": (2.5e9, 3.5e9),
              "qwen2-72b": (68e9, 78e9),
              "jamba-1.5-large-398b": (370e9, 420e9),
              "hubert-xlarge": (0.8e9, 1.3e9),
              "llama-3.2-vision-11b": (9e9, 12e9)}
    for arch_id, (lo, hi) in expect.items():
        n = get_config(arch_id).param_count()
        assert lo <= n <= hi, (arch_id, n)


def test_kv_repeat_identity():
    cfg = get_config("qwen2-72b", smoke=True)          # kh=2, h=4
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    l1, _ = forward_train(params, b, cfg)
    l2, _ = forward_train(params, b, cfg.replace(kv_repeat=2))
    assert abs(float(l1) - float(l2)) < 1e-5


def test_perf_flags_are_semantics_preserving():
    """seq_shard / expert_parallel / ssm_fused_ref / grad accumulation dtype
    are sharding-or-numerics knobs, not model changes (§Perf levers)."""
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    l0, _ = forward_train(params, b, cfg)
    for kw in ({"expert_parallel": True}, {"seq_shard": True},
               {"ssm_fused_ref": True}):
        l1, _ = forward_train(params, b, cfg.replace(**kw))
        assert float(l0) == pytest.approx(float(l1), abs=1e-6), kw


def test_sliding_window_wider_than_seq_equals_full():
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg, S=16)
    l_full, _ = forward_train(params, b, cfg.replace(sliding_window=0))
    l_win, _ = forward_train(params, b, cfg.replace(sliding_window=64))
    assert abs(float(l_full) - float(l_win)) < 1e-5


def test_attn_q_chunk_equals_unchunked():
    cfg = get_config("qwen2.5-32b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg, S=64)
    l1, _ = forward_train(params, b, cfg.replace(attn_q_chunk=0))
    l2, _ = forward_train(params, b, cfg.replace(attn_q_chunk=16))
    assert abs(float(l1) - float(l2)) < 2e-3


def test_scan_vs_unrolled_layers():
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    l1, _ = forward_train(params, b, cfg.replace(scan_layers=True))
    l2, _ = forward_train(params, b, cfg.replace(scan_layers=False))
    assert abs(float(l1) - float(l2)) < 1e-4


def test_remat_does_not_change_loss_or_grads():
    cfg = get_config("stablelm-3b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    g1 = jax.grad(lambda p: forward_train(p, b, cfg)[0])(params)
    g2 = jax.grad(lambda p: forward_train(
        p, b, cfg.replace(remat_policy="none"))[0])(params)
    for a, b_ in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=1e-3, atol=1e-5)


def test_shape_cells_cover_assignment():
    """40 nominal cells; principled skips documented in DESIGN §5."""
    cells = [(a, s) for a in ARCH_IDS for s in get_shapes(a)]
    n_by_arch = {a: len(get_shapes(a)) for a in ARCH_IDS}
    assert n_by_arch["falcon-mamba-7b"] == 4       # runs long_500k (SSM)
    assert n_by_arch["mixtral-8x7b"] == 4          # SWA bounded KV
    assert n_by_arch["jamba-1.5-large-398b"] == 4  # hybrid
    assert n_by_arch["hubert-xlarge"] == 2         # encoder: no decode
    assert n_by_arch["grok-1-314b"] == 3           # full attn: no long
    assert len(cells) == 32
