"""Theorem 1 + Algorithm 3: the analytic optimum actually minimizes the cost
model, and build_plan recovers parameters from synthetic measurements."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover — env without the `test` extra
    from _hypothesis_compat import given, settings, st

from repro.core.planner import (PipelinePlan, build_plan, choose_degree,
                                theorem1_m_star)


def T_p(m, c, lam, N, t0, n):
    """Paper §4.2: T_p = (c - lam*N)/m + t0*m + lam*N + (n-1)*t0."""
    return (c - lam * N) / m + t0 * m + lam * N + (n - 1) * t0


@given(st.floats(0.5, 500.0),      # c: total net time
       st.floats(1e-6, 1e-3),      # lam
       st.integers(100, 100_000),  # N
       st.floats(1e-4, 0.5),       # t0
       st.integers(2, 12))         # n activities
@settings(max_examples=200, deadline=None)
def test_theorem1_minimizes_cost(c, lam, N, t0, n):
    # keep the model well-posed: net time at the staggering activity
    # cannot exceed the total net time
    if lam * N >= c:
        c = lam * N * 1.5
    m_star = theorem1_m_star(c, lam, N, t0)
    best_grid = min(T_p(m, c, lam, N, t0, n) for m in range(1, 2001))
    got = T_p(max(m_star, 1.0), c, lam, N, t0, n)
    # continuous optimum is never worse than 1.001x the best integer m
    assert got <= best_grid * 1.001


def test_theorem1_closed_form():
    # hand-checked: c=100, lam*N=10, t0=0.1 -> m* = sqrt(90/0.1) = 30
    assert theorem1_m_star(100.0, 0.1, 100, 0.1) == pytest.approx(30.0)


def test_theorem1_clamps():
    # huge inner term -> clamped to m_max
    assert theorem1_m_star(100.0, 0.1, 10, 1e-9, m_max=64) == 64.0
    # c <= lam*N -> inner clamps to 0 -> m = 1
    assert theorem1_m_star(0.0, 1.0, 100, 1.0) == 1.0


def test_build_plan_recovers_parameters():
    """Synthesize Algorithm-3 measurements from known (c, lam, t0) and check
    the plan reproduces them."""
    n = 5
    t0 = 0.01
    lam = 2e-5
    rows = 200_000
    m_prime = 4
    # activity i net time: staggering activity is index 2
    nets = [0.5, 0.8, lam * rows, 0.6, 0.3]
    times = {f"a{i}": nets[i] + t0 for i in range(n)}
    plan = build_plan(times, misc_total=n * t0, sample_rows=rows,
                      full_rows=rows, m_prime=m_prime)
    assert plan.staggering == "a2"
    assert plan.t0 == pytest.approx(t0)
    assert plan.c == pytest.approx(sum(nets), rel=1e-6)
    # lambda from the per-split staggering time: t_j/m' = t0 + lam*N/m'
    lam_hat = plan.lam
    assert lam_hat == pytest.approx((nets[2] + t0) / m_prime - t0, rel=0.1) \
        or lam_hat * plan.N == pytest.approx(lam * rows, rel=0.35)
    # the plan's m* matches the closed form on its own parameters
    assert plan.m_star == pytest.approx(
        theorem1_m_star(plan.c, plan.lam, plan.N, plan.t0,
                        m_max=rows))


def test_predicted_speedup_shape():
    plan = PipelinePlan(n=4, t0=0.01, c=10.0, lam=1e-5, N=100_000,
                        staggering="a1", m_star=30.0)
    s1 = plan.predict_speedup(1)
    s8 = plan.predict_speedup(8)
    s_star = plan.predict_speedup(plan.m_star)
    assert s1 == pytest.approx(1.0, rel=1e-6)
    assert s8 > s1
    assert s_star >= s8 * 0.99


def test_choose_degree_caps():
    plan = PipelinePlan(n=4, t0=1e-4, c=100.0, lam=1e-9, N=10,
                        staggering="a0", m_star=1000.0)
    assert choose_degree(plan, cores=8) == 8
    assert choose_degree(plan, cores=None, cap=64) == 64


# ---------------------------------------------------------------------------
#  degenerate calibration statistics: explicit fallbacks, not div-by-zero
# ---------------------------------------------------------------------------
def test_theorem1_degenerate_inputs():
    # non-finite measurements -> serial fallback
    assert theorem1_m_star(float("nan"), 0.1, 100, 0.1) == 1.0
    assert theorem1_m_star(float("inf"), 0.1, 100, 0.1) == 1.0
    assert theorem1_m_star(100.0, float("nan"), 100, 0.1) == 1.0
    # zero per-activity time with NO net work -> serial
    assert theorem1_m_star(0.0, 0.0, 0, 0.0) == 1.0
    assert theorem1_m_star(1.0, 1.0, 10, 0.0) == 1.0      # c <= lam*N
    # zero per-activity time WITH net work -> as parallel as allowed
    assert theorem1_m_star(10.0, 0.0, 0, 0.0, m_max=16) == 16.0
    assert theorem1_m_star(10.0, 0.0, 0, 0.0) == 1.0      # no m_max given


def test_build_plan_empty_activities():
    plan = build_plan({}, misc_total=0.0, sample_rows=0, full_rows=0,
                      m_prime=0)
    assert plan.n == 0
    assert plan.m_star == 1.0
    assert plan.predict_T_s() == 0.0


def test_build_plan_zero_rows_and_single_split():
    # zero-row sample / zero activity time / m'=1: finite plan, no crash
    plan = build_plan({"a": 0.0, "b": 0.0}, misc_total=0.0, sample_rows=0,
                      full_rows=0, m_prime=1)
    assert plan.m_star >= 1.0
    assert np.isfinite(plan.m_star)
    assert choose_degree(plan, cores=4) >= 1


def test_choose_degree_non_finite_m_star():
    plan = PipelinePlan(n=2, t0=0.0, c=float("inf"), lam=0.0, N=0,
                        staggering="a", m_star=float("inf"))
    assert choose_degree(plan) == 1
    plan_nan = PipelinePlan(n=2, t0=0.0, c=0.0, lam=0.0, N=0,
                            staggering="a", m_star=float("nan"))
    assert choose_degree(plan_nan) == 1


def test_choose_degree_zero_split_bytes():
    plan = PipelinePlan(n=2, t0=0.01, c=10.0, lam=1e-6, N=100,
                        staggering="a", m_star=8.0)
    # zero split_bytes must not divide by zero; budget cap simply inactive
    assert choose_degree(plan, split_bytes=0,
                         memory_budget_bytes=1 << 20) == 8
