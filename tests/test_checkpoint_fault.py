"""Checkpoint/restore (atomic, async, keep-k, resharding restore), elastic
restart and straggler detection."""
import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train.checkpoint import (CheckpointManager, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.fault import (ElasticRunner, StragglerWatchdog,
                               with_retries)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(str(tmp_path), 7, st)
    got, meta = restore_checkpoint(str(tmp_path), st)
    assert meta["step"] == 7
    np.testing.assert_allclose(np.array(got["params"]["w"]),
                               np.array(st["params"]["w"]))


def test_restore_with_sharding_placement(tmp_path):
    st = _state()
    save_checkpoint(str(tmp_path), 1, st)
    # "reshard" onto the current (single-device) mesh — the elastic-restart
    # path: restore takes target shardings and device_puts accordingly
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.jax_compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    got, _ = restore_checkpoint(str(tmp_path), st, shardings=sh)
    assert got["params"]["w"].sharding == NamedSharding(mesh, P())


def test_latest_step_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every_steps=1, keep=2,
                            async_save=False)
    for s in range(1, 6):
        mgr.maybe_save(s, _state())
    assert latest_step(str(tmp_path)) == 5
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000004", "step_00000005"]


def test_async_save_snapshots_before_donation(tmp_path):
    """The manager must host-snapshot before returning: mutating the live
    state after maybe_save must not corrupt the checkpoint."""
    mgr = CheckpointManager(str(tmp_path), every_steps=1, keep=3,
                            async_save=True)
    st = {"w": jnp.ones((1000,))}
    mgr.maybe_save(1, st)
    st["w"] = st["w"] * 0          # simulate donated-buffer reuse
    mgr.wait()
    got, _ = restore_checkpoint(str(tmp_path), {"w": jnp.zeros((1000,))})
    np.testing.assert_allclose(np.array(got["w"]), np.ones(1000))


def test_atomic_save_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 3, _state())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_elastic_runner_restores_and_continues():
    calls = {"n": 0}

    def restore():
        return ({"restored": True}, 5)

    def loop(state, start):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("node lost")
        return (state, start)

    runner = ElasticRunner(restore, max_restarts=5)
    state, step = runner.run(loop, {"restored": False}, 0)
    assert state["restored"] and step == 5
    assert runner.restarts == 2


def test_elastic_runner_gives_up():
    runner = ElasticRunner(lambda: ({}, 0), max_restarts=1)
    with pytest.raises(RuntimeError):
        runner.run(lambda s, t: (_ for _ in ()).throw(RuntimeError("x")),
                   {}, 0)


def test_with_retries_backoff():
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert with_retries(flaky, max_retries=4, backoff=0.001)() == "ok"
    assert attempts["n"] == 3


def test_straggler_watchdog_detects_persistent_slowdown():
    events = []
    wd = StragglerWatchdog(window=16, threshold=2.0, patience=3,
                           on_straggler=events.append)
    for s in range(10):
        wd.observe(s, 0.1)
    for s in range(10, 14):
        wd.observe(s, 0.5)          # 5x median, persistent
    assert len(events) >= 1
    assert events[0].ratio > 2.0


def test_straggler_watchdog_ignores_one_off_spike():
    wd = StragglerWatchdog(window=16, threshold=2.0, patience=3)
    for s in range(10):
        wd.observe(s, 0.1)
    wd.observe(10, 1.0)             # single spike
    for s in range(11, 20):
        wd.observe(s, 0.1)
    assert wd.events == []


def test_train_resume_from_checkpoint(tmp_path):
    from repro.configs import get_config
    from repro.launch.train import train_loop
    cfg = get_config("stablelm-3b", smoke=True).replace(grad_accum=1)
    r1 = train_loop(cfg, steps=6, batch=4, seq_len=32,
                    ckpt_dir=str(tmp_path), ckpt_every=3, log_every=100)
    assert latest_step(str(tmp_path)) == 6
    r2 = train_loop(cfg, steps=10, batch=4, seq_len=32,
                    ckpt_dir=str(tmp_path), resume=True, log_every=100)
    assert r2["steps_done"] == 4          # resumed from step 6
