"""Algorithm 2 semantics: busy/wait/notify serialization, split-order
enforcement, BlockingQueue admission bound, pipeline == sequential results."""
import threading
import time

import numpy as np
import pytest

from repro.core import Dataflow, OptimizedEngine, OptimizeOptions
from repro.core.component import Component, SinkComponent, SourceComponent
from repro.core.pipeline import BlockingQueue, TreePipeline
from repro.core.partitioner import partition
from repro.core.shared_cache import SharedCache
from repro.etl.components import ArraySource, CollectSink


class ConcurrencyProbe(Component):
    """Row-sync component that records its concurrent-entry count."""

    def __init__(self, name, delay=0.001):
        super().__init__(name)
        self.delay = delay
        self._active = 0
        self._max_active = 0
        self._lock = threading.Lock()
        self.seen_splits = []

    def _run(self, cache):
        with self._lock:
            self._active += 1
            self._max_active = max(self._max_active, self._active)
            self.seen_splits.append(cache.split_index)
        time.sleep(self.delay)
        with self._lock:
            self._active -= 1
        return [cache]


def _flow(n_stages=3, rows=4000, order_sensitive=False):
    flow = Dataflow("probe")
    src = ArraySource("src", {"x": np.arange(rows, dtype=np.int64)})
    flow.add(src)
    prev = src
    probes = []
    for i in range(n_stages):
        p = ConcurrencyProbe(f"p{i}")
        p.order_sensitive = order_sensitive
        flow.add(p)
        flow.connect(prev, p)
        probes.append(p)
        prev = p
    sink = CollectSink("sink")
    flow.add(sink)
    flow.connect(prev, sink)
    return flow, probes, sink


def test_activity_never_concurrent():
    """Paper lines 6-11: one shared cache at a time per activity."""
    flow, probes, sink = _flow()
    OptimizedEngine(flow, OptimizeOptions(num_splits=8)).run()
    for p in probes:
        assert p._max_active == 1, p.name
    got = np.sort(sink.result()["x"])
    np.testing.assert_array_equal(got, np.arange(4000))


def test_order_sensitive_components_see_splits_in_order():
    flow, probes, sink = _flow(order_sensitive=True)
    # shards=1: split indices renumber per pass in a sharded run, so the
    # cross-pass monotonicity asserted below is a single-pass property
    OptimizedEngine(flow, OptimizeOptions(num_splits=8, shards=1)).run()
    for p in probes:
        assert p.seen_splits == sorted(p.seen_splits), p.name


def test_pipeline_equals_sequential():
    flow1, _, sink1 = _flow()
    OptimizedEngine(flow1, OptimizeOptions(num_splits=8,
                                           pipelined=True)).run()
    flow2, _, sink2 = _flow()
    OptimizedEngine(flow2, OptimizeOptions(num_splits=8,
                                           pipelined=False)).run()
    a = np.sort(sink1.result()["x"])
    b = np.sort(sink2.result()["x"])
    np.testing.assert_array_equal(a, b)


def test_blocking_queue_bounds_inflight():
    """BlockingQueue(m') blocks admission while m' threads are live."""
    bq = BlockingQueue(2)
    release = threading.Event()
    threads = [threading.Thread(target=release.wait, daemon=True)
               for _ in range(3)]
    bq.add(threads[0]); threads[0].start()
    bq.add(threads[1]); threads[1].start()
    admitted_third = threading.Event()

    def try_add():
        bq.add(threads[2])
        admitted_third.set()

    t = threading.Thread(target=try_add, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not admitted_third.is_set()      # full: blocked
    release.set()                           # threads finish
    time.sleep(0.05)
    bq.reap()                               # housekeeping frees slots
    t.join(timeout=2)
    assert admitted_third.is_set()


def test_pipeline_degree_one_is_sequential_order():
    """m'=1 degenerates to non-pipeline fashion (paper §4.2)."""
    flow, probes, sink = _flow(n_stages=2, rows=1000)
    # shards=1: split indices renumber per pass in a sharded run, so the
    # cross-pass monotonicity asserted below is a single-pass property
    OptimizedEngine(flow, OptimizeOptions(num_splits=4,
                                          pipeline_degree=1,
                                          shards=1)).run()
    for p in probes:
        assert p.seen_splits == sorted(p.seen_splits)
    assert len(sink.result()["x"]) == 1000


def test_error_in_activity_propagates():
    flow = Dataflow("err")
    src = flow.add(ArraySource("src", {"x": np.arange(100, dtype=np.int64)}))

    class Boom(Component):
        def _run(self, cache):
            raise RuntimeError("boom")

    b = flow.add(Boom("boom"))
    flow.connect(src, b)
    sink = flow.add(CollectSink("sink"))
    flow.connect(b, sink)
    with pytest.raises(RuntimeError, match="boom"):
        OptimizedEngine(flow, OptimizeOptions(num_splits=2)).run()


def test_intra_tree_fanout_branches_see_unmutated_input():
    """Fan-out inside one tree: a compacting Filter on one branch must not
    drop rows from a sibling branch's input — every other successor's copy
    is snapshotted BEFORE the in-place walk (streaming == ordinary)."""
    from repro.core import OrdinaryEngine, StreamingEngine
    from repro.etl.components import Expression, Filter

    def build():
        r = np.random.RandomState(7)
        flow = Dataflow("fanout")
        src = ArraySource("src", {"v": r.randint(0, 100, 1000).astype(np.int64)})
        filt = Filter("filt", lambda c, rows: c.col("v")[rows] % 2 == 0,
                      reads=["v"])
        expr = Expression("expr", "w", lambda c, rows: c.col("v")[rows] + 1,
                          reads=["v"])
        s1, s2 = CollectSink("s1"), CollectSink("s2")
        for comp in (src, filt, expr, s1, s2):
            flow.add(comp)
        flow.connect(src, filt)
        flow.connect(src, expr)        # second branch: must see ALL rows
        flow.connect(filt, s1)
        flow.connect(expr, s2)
        return flow, s1, s2

    flow_o, o1, o2 = build()
    OrdinaryEngine(flow_o, chunk_rows=256).run()
    flow_s, g1, g2 = build()
    StreamingEngine(flow_s, OptimizeOptions(num_splits=4)).run()
    for sink_o, sink_s in ((o1, g1), (o2, g2)):
        expect, got = sink_o.result(), sink_s.result()
        assert set(expect) == set(got)
        for k in expect:
            np.testing.assert_array_equal(got[k], expect[k], err_msg=k)
