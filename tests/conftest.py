import numpy as np
import pytest

from repro.etl.ssb import generate


@pytest.fixture(scope="session")
def ssb_small():
    """Small SSB dataset shared across engine tests."""
    return generate(lineorder_rows=60_000, customers=2_000, suppliers=300,
                    parts=1_500, seed=7)


@pytest.fixture(scope="session")
def ssb_tiny():
    return generate(lineorder_rows=5_000, customers=300, suppliers=50,
                    parts=200, seed=11)
