"""Sharded execution subsystem (core/shard): partitioner, planner,
partial→merge aggregation, replay, degradations and engine wiring.

Byte-identity of sharded vs serial runs over *random* flows lives in
test_optimizer_equivalence.py (test_sharded_flow_equivalence); this file
covers the subsystem's unit behavior and its failure/fallback edges.
"""
import numpy as np
import pytest

from repro.core import (MetadataStore, OptimizeOptions, ServingEngine,
                        StreamingEngine, cache_stats_scope, config, faults,
                        partition, plan_runtime, plan_shards, resolve_backend)
from repro.core.engine import _assign_backend
from repro.core.shard import ShardRunner, choose_shards
from repro.core.shard.partitioner import (hash_shard_ids, range_bounds,
                                          shard_tables, table_rows)
from repro.core.shard.planner import MAX_AUTO_SHARDS, MIN_SHARD_ROWS
from repro.etl import BUILDERS
from repro.etl.components import Aggregate, ArraySource, CollectSink

ROWS = 12_000


def _table(seed=0, rows=ROWS):
    r = np.random.RandomState(seed)
    return {"g": r.randint(0, 7, rows).astype(np.int64),
            "h": r.randint(0, 3, rows).astype(np.int64),
            "v": r.randint(-1000, 1000, rows).astype(np.int64),
            "f": r.uniform(-1.0, 1.0, rows)}


def _agg_flow(ops, group=("g",), seed=0, rows=ROWS, name="aggflow"):
    """src -> Aggregate(group, ops) -> sink, picklable (no lambdas)."""
    from repro.core import Dataflow
    flow = Dataflow(name)
    sink = CollectSink("sink")
    flow.chain(ArraySource("src", _table(seed, rows)),
               Aggregate("agg", list(group), dict(ops)),
               sink)
    return flow, sink


def _run(flow, sink, **opt_kw):
    run = StreamingEngine(flow, OptimizeOptions(num_splits=4, **opt_kw)).run()
    return run, sink.result()


def _assert_tables_equal(got, want, label=""):
    assert set(got) == set(want), label
    for k in want:
        assert got[k].dtype == want[k].dtype, f"{label}: dtype of {k}"
        np.testing.assert_array_equal(got[k], want[k],
                                      err_msg=f"{label}: column {k}")


# ---------------------------------------------------------------- partitioner
def test_range_bounds_cover_exactly():
    for rows, shards in [(0, 3), (1, 4), (10, 3), (12_000, 7)]:
        b = range_bounds(rows, shards)
        assert b[0] == 0 and b[-1] == rows
        assert (np.diff(b) >= 0).all()
    with pytest.raises(ValueError):
        range_bounds(10, 0)


def test_hash_shard_ids_deterministic_and_bounded():
    r = np.random.RandomState(3)
    a = r.randint(0, 1 << 40, 50_000).astype(np.int64)
    b = r.randint(-5, 5, 50_000).astype(np.int64)
    ids = hash_shard_ids([a, b], 5)
    assert ids.min() >= 0 and ids.max() < 5
    np.testing.assert_array_equal(ids, hash_shard_ids([a, b], 5))
    # chained mixing: key order matters
    assert not np.array_equal(ids, hash_shard_ids([b, a], 5))
    # splitmix64 spreads even low-cardinality keys across all shards
    assert len(np.unique(ids)) == 5


def test_hash_partition_is_exact_disjoint_cover():
    src = _table(seed=1)
    parts = shard_tables({"src": src}, 4, "hash", key=("g", "h"))
    assert sum(table_rows(p["src"]) for p in parts) == ROWS
    # same key tuple always lands on the same shard => group-disjoint
    seen = {}
    for k, p in enumerate(parts):
        for pair in zip(p["src"]["g"].tolist(), p["src"]["h"].tolist()):
            assert seen.setdefault(pair, k) == k
    # per-shard relative order of v is a subsequence of the original
    cat = np.concatenate([p["src"]["v"] for p in parts])
    assert sorted(cat.tolist()) == sorted(src["v"].tolist())


def test_range_partition_is_contiguous():
    src = _table(seed=2)
    parts = shard_tables({"src": src}, 3, "range")
    cat = np.concatenate([p["src"]["v"] for p in parts])
    np.testing.assert_array_equal(cat, src["v"])


# -------------------------------------------------------------------- planner
def test_choose_shards_bounds():
    assert choose_shards(100, 4, cores=8) == 1          # rows floor
    assert choose_shards(MIN_SHARD_ROWS * 100, 4, cores=8) == 4
    assert choose_shards(MIN_SHARD_ROWS * 100, 64, cores=64) == MAX_AUTO_SHARDS
    assert choose_shards(0, 0, cores=1) == 1


def test_plan_shards_serial_and_degradations():
    flow, _ = _agg_flow([("s", ("v", "sum"))])
    bk = resolve_backend("numpy")
    _assign_backend(flow, bk)
    g_tau = partition(flow)
    opts = OptimizeOptions(num_splits=4)
    assert plan_shards(flow, g_tau, 1, "auto", opts, bk) is None

    plan = plan_shards(flow, g_tau, 3, "auto", opts, bk)
    assert plan is not None and plan.shards == 3 and plan.impl == "inline"
    assert plan.mode == "hash" and plan.key == ("g",)

    with pytest.raises(ValueError):
        plan_shards(flow, g_tau, 2, "threads", opts, bk)

    # a chunk-sensitive source cannot be re-partitioned: serial + recorded
    flow.component("src").chunk_sensitive = True
    with faults.fault_recorder() as frec:
        assert plan_shards(flow, g_tau, 2, "auto", opts, bk) is None
    assert any(d.kind == "shard_plan" for d in frec.degradations)


def test_plan_shards_global_agg_takes_range_mode():
    flow, _ = _agg_flow([("s", ("v", "sum"))], group=())
    bk = resolve_backend("numpy")
    _assign_backend(flow, bk)
    plan = plan_shards(flow, partition(flow), 2, "inline",
                       OptimizeOptions(num_splits=4), bk)
    assert plan is not None and plan.mode == "range" and plan.key == ()


# ------------------------------------------------------- partial→merge ops
@pytest.mark.parametrize("op", ["sum", "min", "max", "count", "avg"])
def test_partial_merge_every_agg_op(op):
    ops = [("a", ("v", op)), ("b", ("f", op))]
    flow_s, sink_s = _agg_flow(ops, group=("g", "h"))
    _, serial = _run(flow_s, sink_s, shards=1)
    for shards in (2, 3):
        flow_n, sink_n = _agg_flow(ops, group=("g", "h"))
        run, got = _run(flow_n, sink_n, shards=shards, shard_impl="inline")
        assert run.shards == shards
        _assert_tables_equal(got, serial, f"op={op} shards={shards}")


def test_mesh_route_on_jax_backend():
    pytest.importorskip("jax")
    ops = [("s", ("v", "sum")), ("m", ("v", "min")),
           ("x", ("f", "max")), ("a", ("f", "avg"))]
    flow_s, sink_s = _agg_flow(ops)
    _, serial = _run(flow_s, sink_s, shards=1, backend="jax")
    flow_n, sink_n = _agg_flow(ops)
    run, got = _run(flow_n, sink_n, shards=2, shard_impl="mesh",
                    backend="jax")
    assert run.shards == 2
    _assert_tables_equal(got, serial, "mesh route")


def test_global_aggregate_sharded():
    # avg over the INTEGER column: exact partial sums → the one division
    # rounds identically on the serial and the partial→merge path; a float
    # avg reduced on-device (jax runs float32) is only ulp-close across
    # different chunkings, checked separately below
    ops = [("s", ("v", "sum")), ("m", ("f", "min")),
           ("c", ("v", "count")), ("a", ("v", "avg"))]
    flow_s, sink_s = _agg_flow(ops, group=())
    _, serial = _run(flow_s, sink_s, shards=1)
    flow_n, sink_n = _agg_flow(ops, group=())
    run, got = _run(flow_n, sink_n, shards=3, shard_impl="inline")
    assert run.shards == 3
    _assert_tables_equal(got, serial, "global agg")


def test_global_float_avg_sharded_ulp_close():
    # device backends reduce float sums in their native dtype, so serial
    # (one kernel over all rows) and sharded (per-shard kernels + host
    # merge) round differently — agreement is to ulp, not byte-identity
    ops = [("a", ("f", "avg"))]
    flow_s, sink_s = _agg_flow(ops, group=())
    _, serial = _run(flow_s, sink_s, shards=1)
    flow_n, sink_n = _agg_flow(ops, group=())
    _, got = _run(flow_n, sink_n, shards=3, shard_impl="inline")
    np.testing.assert_allclose(got["a"], serial["a"], rtol=1e-5)


def test_empty_shards_more_shards_than_groups():
    # one distinct key tuple => hash mode puts every row on ONE shard;
    # the other shards run empty passes and must not perturb the merge
    rows = 5_000
    cols = {"g": np.ones(rows, dtype=np.int64),
            "v": np.arange(rows, dtype=np.int64)}
    from repro.core import Dataflow

    def build():
        flow = Dataflow("onekey")
        sink = CollectSink("sink")
        flow.chain(ArraySource("src", dict(cols)),
                   Aggregate("agg", ["g"], {"s": ("v", "sum")}), sink)
        return flow, sink

    flow_s, sink_s = build()
    _, serial = _run(flow_s, sink_s, shards=1)
    flow_n, sink_n = build()
    run, got = _run(flow_n, sink_n, shards=4, shard_impl="inline")
    assert run.shards == 4
    assert sorted(run.shard_rows) == [0, 0, 0, rows]
    _assert_tables_equal(got, serial, "one-key hash")


# ----------------------------------------------------------- fault replay
def test_shard_failure_replays_to_identical_output(monkeypatch):
    monkeypatch.setenv("REPRO_RETRY_BACKOFF", "0.001")
    monkeypatch.setenv("REPRO_CACHE_GUARD", "1")
    monkeypatch.delenv(config.ENV_FAULTS, raising=False)
    flow_s, sink_s = _agg_flow([("s", ("v", "sum")), ("a", ("f", "avg"))])
    _, serial = _run(flow_s, sink_s, shards=1)
    plan = faults.FaultPlan(
        [faults.FaultRule(site="shard", kind="transient", count=2)],
        seed=5)
    flow_n, sink_n = _agg_flow([("s", ("v", "sum")), ("a", ("f", "avg"))])
    with faults.fault_scope(plan):
        run, got = _run(flow_n, sink_n, shards=3, shard_impl="inline")
    assert plan.injected == 2
    assert run.faults_injected == 2
    assert run.retries >= 2                        # whole-shard replays
    _assert_tables_equal(got, serial, "shard replay")


def test_merge_pass_failure_replays(monkeypatch):
    monkeypatch.setenv("REPRO_RETRY_BACKOFF", "0.001")
    monkeypatch.delenv(config.ENV_FAULTS, raising=False)
    flow_s, sink_s = _agg_flow([("s", ("v", "sum"))])
    _, serial = _run(flow_s, sink_s, shards=1)
    # the merge attempt injects with split=None only after every shard
    # pass took its own injection, so a rule skipping the first
    # ``shards`` matching calls targets the coordinator merge exactly
    plan = faults.FaultPlan(
        [faults.FaultRule(site="shard", kind="transient", count=1,
                          after=2)], seed=1)
    flow_n, sink_n = _agg_flow([("s", ("v", "sum"))])
    with faults.fault_scope(plan):
        run, got = _run(flow_n, sink_n, shards=2, shard_impl="inline")
    assert plan.injected == 1 and run.retries >= 1
    _assert_tables_equal(got, serial, "merge replay")


# ------------------------------------------------------- degrade / refuse
def test_process_route_degrades_under_fault_scope():
    # scoped fault plans cannot cross a process boundary: the runner must
    # fall back to inline (recorded) rather than silently lose injections
    plan = faults.FaultPlan([faults.FaultRule(site="chunk", count=0)], seed=1)
    flow, sink = _agg_flow([("s", ("v", "sum"))])
    with faults.fault_scope(plan):
        run, got = _run(flow, sink, shards=2, shard_impl="process")
    assert run.shards == 2
    assert any(d["kind"] == "shard_impl" and d["dst"] == "inline"
               for d in run.degradation_events)
    flow_s, sink_s = _agg_flow([("s", ("v", "sum"))])
    _, serial = _run(flow_s, sink_s, shards=1)
    _assert_tables_equal(got, serial, "process degrade")


def test_unpicklable_flow_degrades_to_inline():
    from repro.etl.components import Filter
    from repro.core import Dataflow
    flow = Dataflow("unpick")
    sink = CollectSink("sink")
    flow.chain(ArraySource("src", _table()),
               Filter("keep", lambda c, rows: c.col("v")[rows] > 0,
                      reads=["v"]),
               sink)
    run = StreamingEngine(flow, OptimizeOptions(
        num_splits=4, shards=2, shard_impl="process")).run()
    assert run.shards == 2
    assert any(d["kind"] == "shard_impl" for d in run.degradation_events)
    got = sink.result()["v"]
    src = _table()["v"]
    np.testing.assert_array_equal(got, src[src > 0])


@pytest.mark.parametrize("shards", [2, 3])
def test_sharded_staged_flow_preserves_row_order(shards):
    # a semi-block cut feeds a row-sync tail: the sink harvests streamed
    # shard-pass caches whose arrival order is scheduler-dependent, so
    # reassembly must restore (shard, split) order — regression for the
    # shard-major renumber erasing split_index before sorting
    from repro.core import Dataflow, StageBoundary
    from repro.etl.components import Filter
    rows = 20_000
    flow = Dataflow("staged_order")
    sink = CollectSink("sink")
    flow.chain(ArraySource("src", {"x": np.arange(rows, dtype=np.int64)}),
               Filter("keep_even", lambda c, r: c.col("x")[r] % 2 == 0,
                      reads=["x"]),
               StageBoundary("cut"),
               Filter("keep_div4", lambda c, r: c.col("x")[r] % 4 == 0,
                      reads=["x"]),
               sink)
    run = StreamingEngine(flow, OptimizeOptions(
        num_splits=8, shards=shards, shard_impl="inline")).run()
    assert run.shards == shards
    np.testing.assert_array_equal(sink.result()["x"],
                                  np.arange(0, rows, 4))


def test_serving_engine_refuses_shards():
    flow, _ = _agg_flow([("s", ("v", "sum"))])
    eng = ServingEngine(flow, OptimizeOptions(num_splits=2, shards=2))
    with pytest.raises(ValueError, match="shard"):
        eng.tick()


# --------------------------------------------------- counters and metadata
def test_per_shard_counters_sum_to_run_total():
    flow, sink = _agg_flow([("s", ("v", "sum"))])
    bk = resolve_backend("numpy")
    _assign_backend(flow, bk)
    g_tau = partition(flow)
    opts = OptimizeOptions(num_splits=4, shards=3, shard_impl="inline")
    rplan = plan_runtime(flow, g_tau, num_splits=4, m_prime=4, backend=bk)
    plan = plan_shards(flow, g_tau, 3, "inline", opts, bk)
    assert plan is not None
    with cache_stats_scope() as stats:
        res = ShardRunner(flow, g_tau, opts, rplan, plan).execute()
    total = stats.snapshot()
    by_parts = {}
    for snap in res.shard_stats + [res.merge_stats]:
        for k, v in snap.items():
            by_parts[k] = by_parts.get(k, 0) + v
    assert len(res.shard_stats) == 3
    for k in ("copies", "bytes_copied", "h2d_bytes", "d2h_bytes",
              "arena_hits", "arena_misses"):
        assert by_parts[k] == total[k], \
            f"{k}: per-shard {by_parts[k]} != run total {total[k]}"
    assert res.shuffle_bytes > 0
    assert res.scatter_bytes <= res.source_bytes
    assert sum(res.shard_rows) == ROWS
    assert sink.result()  # merge delivered


def test_env_vars_drive_shards(monkeypatch):
    monkeypatch.setenv(config.ENV_SHARDS, "2")
    monkeypatch.setenv(config.ENV_SHARD_IMPL, "inline")
    flow, sink = _agg_flow([("s", ("v", "sum"))])
    run, _ = _run(flow, sink)
    assert run.shards == 2 and len(run.shard_rows) == 2
    assert "shards=2" in run.summary()


def test_explicit_opts_override_env(monkeypatch):
    monkeypatch.setenv(config.ENV_SHARDS, "4")
    flow, sink = _agg_flow([("s", ("v", "sum"))])
    run, _ = _run(flow, sink, shards=1)
    assert run.shards == 1 and run.shard_rows == []


def test_metadata_records_shard_layout_xml_roundtrip():
    store = MetadataStore()
    flow, sink = _agg_flow([("s", ("v", "sum"))])
    StreamingEngine(flow, OptimizeOptions(num_splits=4, shards=2,
                                          shard_impl="inline"),
                    metadata=store).run()
    spec = store.runs[flow.name]
    assert spec["shards"] == 2 and len(spec["shard_rows"]) == 2
    back = MetadataStore.from_xml(store.to_xml()).runs[flow.name]
    assert back["shards"] == 2
    assert back["shard_rows"] == spec["shard_rows"]


# ------------------------------------------------------------ tracing path
def test_sharded_run_emits_shard_and_merge_spans():
    from repro.obs import trace as obs_trace
    tr = obs_trace.Tracer(name="shardtrace")
    flow, sink = _agg_flow([("s", ("v", "sum"))])
    with obs_trace.trace_scope(tr):
        StreamingEngine(flow, OptimizeOptions(num_splits=4, shards=2,
                                              shard_impl="inline")).run()
    names = [e.get("name") for e in tr.events]
    assert "shard-merge" in names
    assert "shard-0" in names and "shard-1" in names


def test_shard_runner_attaches_per_shard_subtracers():
    from repro.obs import trace as obs_trace
    flow, sink = _agg_flow([("s", ("v", "sum"))])
    bk = resolve_backend("numpy")
    _assign_backend(flow, bk)
    g_tau = partition(flow)
    opts = OptimizeOptions(num_splits=4, shards=2, shard_impl="inline")
    rplan = plan_runtime(flow, g_tau, num_splits=4, m_prime=4, backend=bk)
    plan = plan_shards(flow, g_tau, 2, "inline", opts, bk)
    tr = obs_trace.Tracer(name="shardtrace", measuring=False)
    tr.meta = {"flow": flow.name}
    with obs_trace.trace_scope(tr):
        ShardRunner(flow, g_tau, opts, rplan, plan, tracer=tr).execute()
    # each shard pass exports as its own shard-tagged sub-tracer (own
    # Perfetto pid, see obs.trace._TraceFile.add_and_flush)
    assert len(tr.shard_tracers) == 2
    for k, sub in enumerate(tr.shard_tracers):
        assert sub.meta["shard"] == k
        assert sub.meta["flow"] == f"{flow.name}[shard{k}]"
        assert any(e.get("name") == f"shard-{k}" for e in sub.events)


def test_q41_sharded_process_route_byte_identical(ssb_tiny):
    """The acceptance query: Q4.1 at shards=2 over the process route must
    be byte-identical to serial (and actually fan out, not degrade)."""
    qf = BUILDERS["Q4.1"](ssb_tiny)
    StreamingEngine(qf.flow, OptimizeOptions(num_splits=2)).run()
    serial = qf.sink.result()

    qf2 = BUILDERS["Q4.1"](ssb_tiny)
    run = StreamingEngine(qf2.flow, OptimizeOptions(
        num_splits=2, shards=2, shard_impl="process")).run()
    assert run.shards == 2
    assert not any(d["kind"] == "shard_impl"
                   for d in run.degradation_events), "process route degraded"
    _assert_tables_equal(qf2.sink.result(), serial, "Q4.1 process")
