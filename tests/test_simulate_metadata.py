"""Discrete-event simulator sanity + metadata store round trips."""
import numpy as np
import pytest

from repro.core import (Dataflow, MetadataStore, partition, plan_schedule,
                        simulate_tree, speedup_curve)
from repro.core.simulate import cpu_usage_curve, multithreading_curve
from repro.etl.queries import build_q4
from repro.etl.ssb import generate


def test_simulator_m1_equals_sequential():
    costs = np.array([[1.0], [2.0], [0.5]])
    res = simulate_tree(costs, cores=8, m_prime=1)
    assert res.makespan == pytest.approx(3.5)
    assert res.speedup == pytest.approx(1.0)


def test_simulator_pipeline_bound_by_staggering_activity():
    """The staggering activity serializes: makespan >= its total time, and
    pipelining still beats sequential (paper §4.2 cost model)."""
    n, m = 3, 8
    per = np.array([0.1, 0.4, 0.1])
    costs = np.tile((per / m)[:, None], (1, m))
    res = simulate_tree(costs, cores=8)
    lower = per[1] / m * m            # staggering activity total
    assert res.makespan >= lower
    assert res.makespan <= per.sum() / m + per[1] + 0.2
    assert res.speedup >= 1.2


def test_simulator_speedup_capped_by_cores():
    per = [1.0] * 4
    curve = speedup_curve(per, total_rows=1000, degrees=[1, 2, 4, 8, 16],
                          cores=2, t0=0.0)
    assert curve[1] == pytest.approx(1.0, rel=0.01)
    for m, s in curve.items():
        assert s <= 2.001 + 1e-6       # never beats the core count


def test_simulator_overthreading_penalty():
    """Paper Fig 12: speedup declines when pipelines exceed the cores."""
    per = [1.0] * 4
    c_no = speedup_curve(per, 1000, [16], cores=8, t0=0.01)[16]
    c_pen = speedup_curve(per, 1000, [16], cores=8, t0=0.01,
                          switch_cost=0.01)[16]
    assert c_pen < c_no
    # and the penalized curve peaks at/below the core count
    curve = speedup_curve(per, 1000, [4, 8, 32], cores=8, t0=0.01,
                          switch_cost=0.01)
    assert curve[32] < curve[8]


def test_cpu_usage_increases_with_degree():
    per = [1.0] * 4
    usage = cpu_usage_curve(per, degrees=[1, 4, 8], cores=8, t0=0.01)
    assert usage[1] < usage[4] <= 1.0
    assert usage[4] <= usage[8] + 0.05


def test_multithreading_curve_peaks_at_cores():
    curve = multithreading_curve(bottleneck_cost=8.0, other_cost=2.0,
                                 thread_counts=[1, 2, 4, 8, 16],
                                 cores=8, switch_cost=0.02)
    assert curve[1] == pytest.approx(1.0, rel=0.05)
    assert curve[8] > curve[2]
    assert curve[16] < curve[8]        # paper Fig 14: decline past cores


def test_plan_schedule_waves():
    data = generate(lineorder_rows=200, customers=50, suppliers=20,
                    parts=30)
    qf = build_q4(data)
    g = partition(qf.flow)
    waves = plan_schedule(g)
    assert waves[0] == [0]             # source tree first
    assert sum(len(w) for w in waves) == len(g.trees)


def test_metadata_xml_json_roundtrip():
    data = generate(lineorder_rows=200, customers=50, suppliers=20,
                    parts=30)
    qf = build_q4(data)
    store = MetadataStore()
    store.register_flow(qf.flow)
    store.register_partitioning(qf.flow, partition(qf.flow))
    assert store.type_of("groupby_sum") == "block"
    assert store.type_of("lookup_date") == "row-synchronized"

    x = MetadataStore.from_xml(store.to_xml())
    assert x.type_of("groupby_sum") == "block"
    assert x.partitions["ssb-q4.1"]["trees"][0]["members"]

    j = MetadataStore.from_json(store.to_json())
    assert j.dataflows["ssb-q4.1"]["edges"] == \
        store.dataflows["ssb-q4.1"]["edges"]


def test_metadata_run_roundtrip_xml_and_json():
    """EngineRun records — including run identity, refusals and the obs
    metric snapshot — survive BOTH serializations."""
    from repro.core import OptimizedEngine, OptimizeOptions
    from repro.obs import trace as obs_trace

    data = generate(lineorder_rows=2000, customers=50, suppliers=20,
                    parts=30)
    qf = build_q4(data)
    store = MetadataStore()
    with obs_trace.trace_scope():      # populate run.metrics
        run = OptimizedEngine(qf.flow, OptimizeOptions(num_splits=2),
                              metadata=store).run()
    run.refusals = [{"rule": "filter-hop", "reason": "undeclared reads"}]
    store.register_run(qf.flow, run)   # re-register with the refusal
    spec = store.runs["ssb-q4.1"]
    assert spec["run_id"] == run.run_id
    assert spec["metrics"]["counters"]["dispatch_calls"] == \
        run.dispatch_calls

    for restored in (MetadataStore.from_xml(store.to_xml()),
                     MetadataStore.from_json(store.to_json())):
        got = restored.runs["ssb-q4.1"]
        assert got["run_id"] == run.run_id
        assert got["created"] == run.created
        assert got["git_sha"] == run.git_sha
        assert got["engine"] == run.engine
        assert got["backend"] == run.backend
        assert got["wall_time"] == pytest.approx(run.wall_time)
        for field in ("copies", "bytes_copied", "h2d_transfers",
                      "d2h_transfers", "dispatch_calls", "arena_hits",
                      "arena_misses", "arena_bytes_reused"):
            assert got[field] == getattr(run, field), field
        assert got["refusals"] == run.refusals
        assert got["metrics"]["counters"]["dispatch_calls"] == \
            run.dispatch_calls
