"""Shared model building blocks (pure-jnp reference path).

All functions are pure; parameters are nested dicts of jnp arrays.  Sharding
is injected via `Rules` (logical-axis -> mesh-axis mapping) so the same model
code runs unsharded on one CPU device (smoke tests) and SPMD-sharded on the
production mesh (dry-run / launch).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
#  Sharding rules
# ---------------------------------------------------------------------------
class Rules:
    """Maps logical axis names to mesh axis names (or None).  With an empty
    mapping every constraint is a no-op (single-device paths)."""

    def __init__(self, mapping: Optional[Dict[str, Any]] = None):
        self.mapping = mapping or {}

    def spec(self, *axes: Optional[str]) -> P:
        return P(*(self.mapping.get(a) if a else None for a in axes))

    def cons(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        if not self.mapping:
            return x
        return jax.lax.with_sharding_constraint(x, self.spec(*axes))


NO_RULES = Rules()


def dt(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
#  Normalization
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
#  Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]                 # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
#  Attention (GQA, causal / bidirectional / sliding-window / cross)
# ---------------------------------------------------------------------------
def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(scores / cap)
    return scores


def attention_scores_mask(q_pos: jax.Array, kv_pos: jax.Array, causal: bool,
                          window: int, kv_valid: Optional[jax.Array] = None
                          ) -> jax.Array:
    """Boolean [.., Sq, Skv] mask of allowed attention pairs."""
    rel = q_pos[..., :, None] - kv_pos[..., None, :]
    mask = jnp.ones(rel.shape, dtype=bool)
    if causal:
        mask &= rel >= 0
    if window and window > 0:
        mask &= rel < window
    if kv_valid is not None:
        mask &= kv_valid[..., None, :]
    return mask


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
         softcap: float = 0.0) -> jax.Array:
    """Grouped-query scaled dot-product attention.

    q: [B, Sq, Kh, G, hd]   (G = query heads per kv head)
    k,v: [B, Skv, Kh, hd]
    mask: broadcastable to [B, Kh, G, Sq, Skv]
    returns [B, Sq, Kh, G, hd]
    """
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def chunked_sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
                 q_pos: jax.Array, kv_pos: jax.Array,
                 causal: bool, window: int, softcap: float,
                 q_chunk: int = 1024) -> jax.Array:
    """Flash-style chunked attention (pure jnp): iterate q in chunks so the
    [Sq, Skv] score matrix never fully materializes.  Used for long
    sequences; numerically identical to sdpa (fp32 softmax)."""
    B, Sq, Kh, G, hd = q.shape
    n_chunks = -(-Sq // q_chunk)
    pad = n_chunks * q_chunk - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, pad),), constant_values=-1)
    qs = q.reshape(B, n_chunks, q_chunk, Kh, G, hd)
    qp = q_pos.reshape(n_chunks, q_chunk)

    def one_chunk(args):
        qc, qpc = args
        mask = attention_scores_mask(qpc, kv_pos, causal, window)
        return sdpa(qc, k, v, mask[None, None, None], softcap)

    out = jax.lax.map(one_chunk, (jnp.moveaxis(qs, 1, 0), qp))
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * q_chunk, Kh, G, hd)
    return out[:, :Sq]


def attn_block(x: jax.Array, kv_src: jax.Array, p: Dict[str, jax.Array],
               cfg, rules: Rules,
               q_pos: jax.Array, kv_pos: jax.Array,
               causal: bool, window: int = 0,
               use_rope: bool = True,
               kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
               cache_pos: Optional[jax.Array] = None,
               attn_impl: Optional[str] = None,
               ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full attention sub-block: projections + RoPE + SDPA + out-proj.

    If ``kv_cache`` is given (decode), (k_cache, v_cache) are updated at
    ``cache_pos`` and attention runs over the cache.
    kv_src == x for self-attention; vision embeddings for cross-attention.
    Returns (out, updated_cache).
    """
    B, Sq, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = h // kh
    cdt = dt(cfg.compute_dtype)
    if attn_impl is None:
        attn_impl = getattr(cfg, "attn_impl", "reference")

    wq = p["wq"].astype(cdt)
    wk = p["wk"].astype(cdt)
    wv = p["wv"].astype(cdt)
    wo = p["wo"].astype(cdt)
    q = jnp.einsum("bsd,dn->bsn", x.astype(cdt), wq)
    k = jnp.einsum("bsd,dn->bsn", kv_src.astype(cdt), wk)
    v = jnp.einsum("bsd,dn->bsn", kv_src.astype(cdt), wv)
    if cfg.attn_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = q.reshape(B, Sq, kh, G, hd)
    k = k.reshape(B, kv_src.shape[1], kh, hd)
    v = v.reshape(B, kv_src.shape[1], kh, hd)
    q = rules.cons(q, "batch", None, "kv_heads_act", None, None)
    k = rules.cons(k, "batch", None, "kv_heads_act", None)
    v = rules.cons(v, "batch", None, "kv_heads_act", None)

    if use_rope:
        q = apply_rope(q.reshape(B, Sq, kh * G, hd), q_pos, cfg.rope_theta
                       ).reshape(B, Sq, kh, G, hd)
        k = apply_rope(k, kv_pos, cfg.rope_theta)

    r = getattr(cfg, "kv_repeat", 1)
    if r > 1:
        # TP kv-head replication: kh*r heads (each kv head repeated r times,
        # queries regrouped) — mathematically identical GQA, but the head
        # dim now divides the 'model' axis so scores/cache shard evenly.
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
        q = q.reshape(B, Sq, kh * r, G // r, hd)
        k = rules.cons(k, "batch", None, "kv_heads_act", None)
        v = rules.cons(v, "batch", None, "kv_heads_act", None)
        q = rules.cons(q, "batch", None, "kv_heads_act", None, None)

    if kv_cache is not None:
        # decode: insert new k/v at cache_pos, attend over the whole cache
        k_cache, v_cache = kv_cache
        S_cache = k_cache.shape[1]
        if window and window > 0:
            slot = cache_pos % window
        else:
            slot = cache_pos
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
        idx = jnp.arange(S_cache)
        if window and window > 0:
            # ring buffer: entry i holds absolute position matching slot layout
            n_wrap = (cache_pos // window) * window
            abs_pos = jnp.where(idx <= slot, n_wrap + idx,
                                n_wrap - window + idx)
            kv_valid = (abs_pos >= 0) & (abs_pos <= cache_pos)
            kv_p = abs_pos
        else:
            kv_valid = idx <= cache_pos
            kv_p = idx
        mask = attention_scores_mask(q_pos, kv_p, causal, window, kv_valid)
        out = sdpa(q, k_cache.astype(cdt), v_cache.astype(cdt),
                   mask[None, None, None], cfg.logit_softcap)
        new_cache = (k_cache, v_cache)
    else:
        qc = getattr(cfg, "attn_q_chunk", 0)
        if attn_impl in ("pallas", "interpret"):
            # TPU flash-attention kernel (kernels/flash_attention); the
            # 'interpret' impl runs the same kernel body on CPU for tests.
            from ..kernels.flash_attention import flash_attention
            out = flash_attention(q, k, v, causal=causal, window=window,
                                  softcap=cfg.logit_softcap, impl=attn_impl)
        elif (qc and Sq > qc) or (not qc and Sq >= 8192):
            out = chunked_sdpa(q, k, v, q_pos, kv_pos, causal, window,
                               cfg.logit_softcap, q_chunk=qc or 1024)
        else:
            mask = attention_scores_mask(q_pos, kv_pos, causal, window)
            out = sdpa(q, k, v, mask[None, None, None], cfg.logit_softcap)
        new_cache = (k, v)   # prefill: return computed k/v for cache building

    out = out.reshape(B, Sq, h * hd)
    out = jnp.einsum("bsn,nd->bsd", out, wo)
    out = rules.cons(out, "batch", None, None)
    return out, new_cache


# ---------------------------------------------------------------------------
#  Dense FFN
# ---------------------------------------------------------------------------
def mlp_block(x: jax.Array, p: Dict[str, jax.Array], cfg, rules: Rules
              ) -> jax.Array:
    cdt = dt(cfg.compute_dtype)
    xc = x.astype(cdt)
    if cfg.mlp_kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", xc, p["wg"].astype(cdt))
        u = jnp.einsum("bsd,df->bsf", xc, p["wu"].astype(cdt))
        hcts = jax.nn.silu(g) * u
    else:  # gelu
        u = jnp.einsum("bsd,df->bsf", xc, p["wu"].astype(cdt))
        hcts = jax.nn.gelu(u)
    hcts = rules.cons(hcts, "batch", None, "d_ff")
    out = jnp.einsum("bsf,fd->bsd", hcts, p["wd"].astype(cdt))
    return rules.cons(out, "batch", None, None)


# ---------------------------------------------------------------------------
#  Initializers
# ---------------------------------------------------------------------------
def normal_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
