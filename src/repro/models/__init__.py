from .layers import NO_RULES, Rules
from .transformer import (backbone, decode_step, forward_prefill,
                          forward_train, init_params, make_cache_shapes,
                          n_periods, param_count, param_shapes, param_specs,
                          period)

__all__ = ["NO_RULES", "Rules", "backbone", "decode_step", "forward_prefill",
           "forward_train", "init_params", "make_cache_shapes", "n_periods",
           "param_count", "param_shapes", "param_specs", "period"]
