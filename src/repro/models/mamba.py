"""Mamba-1 (selective SSM) block — pure-jnp reference path.

Recurrence (per channel c, state n):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t
with input-dependent dt (softplus), B, C from x_proj.

Training uses a two-level scan: outer `lax.scan` over sequence chunks
(carry = h at chunk boundary, saved for backward) and a remat'd inner scan
over time steps within the chunk — bounding activation memory to
O(seq/chunk) carries + one recomputed chunk (see DESIGN §6).

Decode is a single recurrence step on carried (conv_state, h).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Rules, dt


def _ssm_chunk_scan(h0: jax.Array, dA: jax.Array, dBx: jax.Array,
                    C: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Scan one chunk.  h0: [B, di, N]; dA, dBx: [B, T, di, N]; C: [B, T, N].
    Returns (h_T, y [B, T, di])."""

    def step(h, inp):
        dA_t, dBx_t, C_t = inp
        h = dA_t * h + dBx_t                       # [B, di, N]
        y_t = jnp.einsum("bdn,bn->bd", h, C_t)     # [B, di]
        return h, y_t

    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0),
          jnp.moveaxis(C, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    return hT, jnp.moveaxis(ys, 0, 1)


def _ssm_chunk_scan_fused(h0: jax.Array, delta: jax.Array, x: jax.Array,
                          Bm: jax.Array, C: jax.Array, A: jax.Array,
                          unroll: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Fused variant: the [B, di, N] outer products dA/dBx are computed
    INSIDE the step from the small per-step slices (delta/x [B, di],
    B/C [B, N]) — never materializing [B, T, di, N] in HBM.  This is the
    pure-jnp analogue of the Pallas kernel's VMEM fusion (DESIGN §4) and
    the hillclimb lever for the memory-bound SSM cells."""

    def step(h, inp):
        d_t, x_t, b_t, c_t = inp                   # [B,di],[B,di],[B,N],[B,N]
        dA_t = jnp.exp(d_t[..., None] * A)         # [B, di, N] (VREG-fused)
        dBx_t = d_t[..., None] * b_t[:, None, :] * x_t[..., None]
        h = dA_t * h + dBx_t
        y_t = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y_t

    xs = (jnp.moveaxis(delta, 1, 0), jnp.moveaxis(x, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(C, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs, unroll=max(1, unroll))
    return hT, jnp.moveaxis(ys, 0, 1)


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv1d.  x: [B, T, di]; w: [K, di].
    ``state``: [B, K-1, di] carried inputs for decode."""
    K = w.shape[0]
    if state is not None:
        x = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        pad = 0
    else:
        pad = K - 1
    out = jax.lax.conv_general_dilated(
        x, w[:, None, :],                 # [K, 1, di] (HIO for depthwise)
        window_strides=(1,), padding=[(pad, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1])
    return out


def mamba_block(x: jax.Array, p: Dict[str, jax.Array], cfg, rules: Rules,
                state: Optional[Tuple[jax.Array, jax.Array]] = None
                ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """x: [B, T, d].  ``state`` = (conv_state [B, K-1, di], h [B, di, N]) for
    decode (T==1); None for training/prefill.  Returns (out, new_state)."""
    B, T, d = x.shape
    di, N, dtr, K = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.d_conv
    cdt = dt(cfg.compute_dtype)
    xc = x.astype(cdt)

    xz = jnp.einsum("btd,de->bte", xc, p["in_proj"].astype(cdt))
    xin, z = jnp.split(xz, 2, axis=-1)                # [B, T, di] each
    xin = rules.cons(xin, "batch", None, "d_inner")

    conv_w = p["conv_w"].astype(cdt)                  # [K, di]
    if state is not None:
        conv_state, h0 = state
        conv_in = xin
        xconv = _causal_conv(conv_in, conv_w, state=conv_state)
        new_conv_state = jnp.concatenate([conv_state[:, 1:],
                                          xin.astype(conv_state.dtype)], axis=1)
    else:
        xconv = _causal_conv(xin, conv_w)
        h0 = jnp.zeros((B, di, N), jnp.float32)
        new_conv_state = xin[:, -(K - 1):]            # for prefill -> decode
    xconv = jax.nn.silu(xconv + p["conv_b"].astype(cdt))

    # input-dependent dt, B, C
    dbc = jnp.einsum("btd,de->bte", xconv, p["x_proj"].astype(cdt))
    dt_in, B_in, C_in = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_in, p["dt_proj"].astype(cdt))
        + p["dt_bias"].astype(cdt))                   # [B, T, di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))      # [di, N]

    delta32 = delta.astype(jnp.float32)
    B32 = B_in.astype(jnp.float32)
    x32 = xconv.astype(jnp.float32)

    if T == 1:
        dA = jnp.exp(delta32[:, 0, :, None] * A)      # [B, di, N]
        dBx = (delta32[:, 0, :, None] * B32[:, 0, None, :]
               * x32[:, 0, :, None])
        h = dA * h0 + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_in[:, 0].astype(jnp.float32))[:, None]
        hT = h
    elif getattr(cfg, "ssm_impl", "reference") in ("pallas", "interpret"):
        # fused Pallas selective-scan kernel (kernels/mamba_scan)
        from ..kernels.mamba_scan import mamba_scan
        y, hT = mamba_scan(delta32, x32, B32, C_in.astype(jnp.float32),
                           A, h0, impl=cfg.ssm_impl, chunk=cfg.ssm_chunk)
    else:
        # chunked two-level scan
        ch = min(cfg.ssm_chunk, T)
        n_chunks = -(-T // ch)
        pad = n_chunks * ch - T
        if pad:
            delta32 = jnp.pad(delta32, ((0, 0), (0, pad), (0, 0)))
            B32 = jnp.pad(B32, ((0, 0), (0, pad), (0, 0)))
            x32 = jnp.pad(x32, ((0, 0), (0, pad), (0, 0)))
            C_pad = jnp.pad(C_in, ((0, 0), (0, pad), (0, 0)))
        else:
            C_pad = C_in

        fused = getattr(cfg, "ssm_fused_ref", False)

        def chunk_body(h, inp):
            dl, Bc, xck, Cc = inp                     # [B, ch, ...]
            if fused:
                return _ssm_chunk_scan_fused(
                    h, dl, xck, Bc, Cc.astype(jnp.float32), A,
                    unroll=getattr(cfg, "ssm_unroll", 1))
            dA = jnp.exp(dl[..., None] * A)           # [B, ch, di, N]
            dBx = dl[..., None] * Bc[:, :, None, :] * xck[..., None]
            return _ssm_chunk_scan(h, dA, dBx, Cc.astype(jnp.float32))

        chunk_body = jax.checkpoint(chunk_body)       # remat inner chunk
        resh = lambda a: jnp.moveaxis(
            a.reshape(B, n_chunks, ch, *a.shape[2:]), 1, 0)
        hT, ys = jax.lax.scan(chunk_body, h0,
                              (resh(delta32), resh(B32), resh(x32),
                               resh(C_pad)))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, n_chunks * ch, di)[:, :T]

    y = y.astype(cdt) + x32.astype(cdt) * p["D"].astype(cdt)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(cdt))
    out = rules.cons(out, "batch", None, None)
    new_state = (new_conv_state, hT) if (state is not None or T > 1) else None
    return out, new_state
