"""Top-2 Mixture-of-Experts FFN — GShard-style grouped dispatch with static
capacity (pure-jnp, shardable under GSPMD).

Tokens are reshaped into groups of ``cfg.moe_group_size``; per group each
token's top-k experts get a capacity slot (rank = cumsum of the expert mask;
slot-2 tokens rank after slot-1).  Dispatch/combine are one-hot einsums —
MXU-friendly on TPU and ~1-3% of expert-FFN FLOPs at our sizes.  Overflowed
tokens are dropped (standard capacity-factor semantics).

Returns the load-balancing auxiliary loss (Switch/GShard form) alongside the
output.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import Rules, dt


def _capacity(group_size: int, k: int, n_experts: int, factor: float) -> int:
    c = int(round(group_size * k * factor / n_experts))
    return max(8, -(-c // 8) * 8)          # >=8, multiple of 8 (TPU lanes)


def moe_block(x: jax.Array, p: Dict[str, jax.Array], cfg, rules: Rules
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    cdt = dt(cfg.compute_dtype)

    T = B * S
    Gs = min(cfg.moe_group_size, T)
    pad = (-T) % Gs
    xt = x.reshape(T, d)
    valid = jnp.ones((T,), bool)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad),))
    Gn = xt.shape[0] // Gs
    xg = xt.reshape(Gn, Gs, d)
    vg = valid.reshape(Gn, Gs)
    xg = rules.cons(xg, "batch", None, None)

    # ---- router (fp32) ----
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)            # [Gn, Gs, E]

    topv, topi = jax.lax.top_k(probs, k)               # [Gn, Gs, k]
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)  # renormalize

    C = _capacity(Gs, k, E, cfg.capacity_factor)

    combine = jnp.zeros((Gn, Gs, E, C), jnp.float32)
    prev_counts = jnp.zeros((Gn, 1, E), jnp.int32)
    for slot in range(k):
        mask = jax.nn.one_hot(topi[..., slot], E, dtype=jnp.int32)  # [Gn,Gs,E]
        mask = mask * vg[..., None].astype(jnp.int32)
        pos = jnp.cumsum(mask, axis=1) - 1 + prev_counts            # rank in expert
        prev_counts = prev_counts + mask.sum(axis=1, keepdims=True)
        keep = (pos < C) & (mask > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, -1), C, dtype=jnp.float32)
        combine = combine + (topv[..., slot][..., None, None]
                             * mask[..., None].astype(jnp.float32) * pos_oh)

    dispatch = (combine > 0).astype(cdt)               # [Gn, Gs, E, C]
    combine = combine.astype(cdt)

    # ---- dispatch -> expert FFN -> combine ----
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg.astype(cdt))  # [Gn,E,C,d]
    xe = rules.cons(xe, "batch", "experts", None, None)
    wg = p["wg"].astype(cdt)
    wu = p["wu"].astype(cdt)
    wd = p["wd"].astype(cdt)
    if cfg.mlp_kind == "swiglu":
        g = jnp.einsum("gecd,edf->gecf", xe, wg)
        u = jnp.einsum("gecd,edf->gecf", xe, wu)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, wu))
    h = rules.cons(h, "batch", "experts", None, "expert_ff")
    ye = jnp.einsum("gecf,efd->gecd", h, wd)
    out = jnp.einsum("gsec,gecd->gsd", combine, ye)    # [Gn, Gs, d]

    out = out.reshape(Gn * Gs, d)[:T].reshape(B, S, d).astype(x.dtype)
    out = rules.cons(out, "batch", None, None)

    # ---- load-balance aux loss (mean over groups): E * sum_e f_e * P_e ----
    me = probs.mean(axis=1)                            # [Gn, E] mean router prob
    top1 = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32)
    fe = (top1 * vg[..., None]).mean(axis=1)           # [Gn, E] dispatch frac
    aux = (E * (fe * me).sum(-1)).mean()
    return out, aux
