"""Unified model covering all 10 assigned architectures.

One period-structured decoder/encoder: layers are grouped into structural
periods (dense/moe/ssm: P=1; jamba: P=8 with attention at offset 4 and MoE
every 2nd layer; llama-vision: P=5 with cross-attention at offset 3) and
`lax.scan` runs over periods with stacked parameters — small HLO, fast
compiles, remat per period.

Entry points:
  init_params / param_shapes / param_specs
  forward_train(params, batch)        -> (loss, aux)
  forward_prefill(params, batch)      -> (logits, cache)
  decode_step(params, cache, tokens)  -> (logits, cache)
  make_cache_shapes(cfg, B, S)        -> cache ShapeDtypeStruct tree
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import (NO_RULES, Rules, attn_block, dt, mlp_block, normal_init,
                     rms_norm)
from .mamba import mamba_block
from .moe import moe_block

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
#  Structure
# ---------------------------------------------------------------------------
def period(cfg) -> int:
    p = 1
    if cfg.family == "hybrid":
        p = math.lcm(cfg.attn_layer_period, cfg.moe_layer_period)
    elif cfg.family == "vlm" and cfg.cross_attn_period:
        p = cfg.cross_attn_period
    elif cfg.n_experts and cfg.moe_layer_period > 1:
        p = cfg.moe_layer_period
    assert cfg.n_layers % p == 0, (cfg.name, cfg.n_layers, p)
    return p


def n_periods(cfg) -> int:
    return cfg.n_layers // period(cfg)


# ---------------------------------------------------------------------------
#  Parameter definitions: (path, shape, logical_axes, init_scale)
# ---------------------------------------------------------------------------
def _layer_defs(cfg, pos: int) -> List[Tuple[str, tuple, tuple, float]]:
    """Definitions for the layer at in-period position ``pos`` (shapes
    WITHOUT the leading n_periods stack dim)."""
    d, f = cfg.d_model, cfg.d_ff
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    defs: List[Tuple[str, tuple, tuple, float]] = []
    kind = cfg.layer_kind(pos)
    defs.append(("ln1", (d,), (None,), 1.0))
    if kind == "attn":
        defs += [
            ("attn.wq", (d, h * hd), ("embed", "heads"), 0.02),
            ("attn.wk", (d, kh * hd), ("embed", "kv_heads"), 0.02),
            ("attn.wv", (d, kh * hd), ("embed", "kv_heads"), 0.02),
            ("attn.wo", (h * hd, d), ("heads", "embed"), out_scale),
        ]
        if cfg.attn_bias:
            defs += [("attn.bq", (h * hd,), ("heads",), 0.0),
                     ("attn.bk", (kh * hd,), ("kv_heads",), 0.0),
                     ("attn.bv", (kh * hd,), ("kv_heads",), 0.0)]
    else:  # mamba
        di, N, dtr, K = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.d_conv
        defs += [
            ("mamba.in_proj", (d, 2 * di), ("embed", "d_inner"), 0.02),
            ("mamba.conv_w", (K, di), (None, "d_inner"), 0.02),
            ("mamba.conv_b", (di,), ("d_inner",), 0.0),
            ("mamba.x_proj", (di, dtr + 2 * N), ("d_inner", None), 0.02),
            ("mamba.dt_proj", (dtr, di), (None, "d_inner"), 0.02),
            ("mamba.dt_bias", (di,), ("d_inner",), 0.0),
            ("mamba.A_log", (di, N), ("d_inner", None), 1.0),
            ("mamba.D", (di,), ("d_inner",), 1.0),
            ("mamba.out_proj", (di, d), ("d_inner", "embed"), out_scale),
        ]
    if cfg.has_cross_attn(pos):
        defs += [
            ("ln_x", (d,), (None,), 1.0),
            ("xattn.wq", (d, h * hd), ("embed", "heads"), 0.02),
            ("xattn.wk", (d, kh * hd), ("embed", "kv_heads"), 0.02),
            ("xattn.wv", (d, kh * hd), ("embed", "kv_heads"), 0.02),
            ("xattn.wo", (h * hd, d), ("heads", "embed"), out_scale),
            ("xattn.gate", (1,), (None,), 0.0),
        ]
    if cfg.d_ff > 0:
        defs.append(("ln2", (d,), (None,), 1.0))
        if cfg.ffn_kind(pos) == "moe":
            E = cfg.n_experts
            # 'experts'/'expert_ff' resolve per sharding profile: baseline
            # experts=None + expert_ff='model' (TP over d_ff); EP mode
            # experts='model' + expert_ff=None (each device owns E/16
            # whole experts — no per-use weight all-gather)
            defs += [
                ("moe.router", (d, E), ("embed", None), 0.02),
                ("moe.wg", (E, d, f), ("experts", "embed", "expert_ff"), 0.02),
                ("moe.wu", (E, d, f), ("experts", "embed", "expert_ff"), 0.02),
                ("moe.wd", (E, f, d), ("experts", "expert_ff", "embed"),
                 out_scale),
            ]
        else:
            if cfg.mlp_kind == "swiglu":
                defs.append(("mlp.wg", (d, f), ("embed", "d_ff"), 0.02))
            defs += [("mlp.wu", (d, f), ("embed", "d_ff"), 0.02),
                     ("mlp.wd", (f, d), ("d_ff", "embed"), out_scale)]
    return defs


def _top_defs(cfg) -> List[Tuple[str, tuple, tuple, float]]:
    d, V = cfg.d_model, cfg.vocab_size
    defs: List[Tuple[str, tuple, tuple, float]] = []
    if cfg.family == "audio":
        defs += [("in_proj_w", (d, d), ("embed", None), 0.02),
                 ("in_proj_b", (d,), (None,), 0.0),
                 ("in_ln", (d,), (None,), 1.0)]
    else:
        defs.append(("tok_embed", (V, d), ("vocab", "embed"), 0.02))
    defs += [("final_ln", (d,), (None,), 1.0),
             ("head_w", (d, V), ("embed", "vocab"), 0.02)]
    return defs


def _assign(tree: dict, path: str, val) -> None:
    parts = path.split(".")
    for p_ in parts[:-1]:
        tree = tree.setdefault(p_, {})
    tree[parts[-1]] = val


def _build(cfg, leaf_fn) -> Params:
    """Build the param tree; ``leaf_fn(path, shape, axes, scale, stacked)``
    produces each leaf.  Layer params get a leading n_periods dim."""
    np_ = n_periods(cfg)
    tree: Params = {"blocks": {}}
    for path, shape, axes, scale in _top_defs(cfg):
        _assign(tree, path, leaf_fn(path, shape, axes, scale, False))
    for pos in range(period(cfg)):
        sub: Params = {}
        for path, shape, axes, scale in _layer_defs(cfg, pos):
            stacked_shape = (np_,) + shape
            stacked_axes = ("layers",) + axes
            _assign(sub, path, leaf_fn(f"blocks.pos{pos}.{path}",
                                       stacked_shape, stacked_axes, scale, True))
        tree["blocks"][f"pos{pos}"] = sub
    return tree


def init_params(cfg, key) -> Params:
    pdt = dt(cfg.param_dtype)
    counter = [0]

    def leaf(path, shape, axes, scale, stacked):
        counter[0] += 1
        k = jax.random.fold_in(key, counter[0])
        if path.endswith("A_log"):
            # mamba: A init = -(1..N) per state dim, log-parameterized
            N = shape[-1]
            a = jnp.tile(jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)),
                         shape[:-1] + (1,))
            return a.astype(pdt)
        if path.endswith((".D", "ln1", "ln2", "ln_x", "final_ln", "in_ln")) \
                or ".D" == path[-2:]:
            return jnp.ones(shape, pdt)
        if path.endswith("dt_bias"):
            return jnp.full(shape, -4.6, pdt)   # softplus^-1(0.01)
        if scale == 0.0:
            return jnp.zeros(shape, pdt)
        return normal_init(k, shape, scale, pdt)

    return _build(cfg, leaf)


def param_shapes(cfg) -> Params:
    pdt = dt(cfg.param_dtype)
    return _build(cfg, lambda path, shape, axes, scale, stacked:
                  jax.ShapeDtypeStruct(shape, pdt))


def param_specs(cfg, rules: Rules) -> Params:
    return _build(cfg, lambda path, shape, axes, scale, stacked:
                  rules.spec(*axes))


def param_count(cfg) -> int:
    leaves = jax.tree.leaves(param_shapes(cfg))
    return sum(int(jnp.prod(jnp.array(l.shape))) for l in leaves)


# ---------------------------------------------------------------------------
#  Decode cache
# ---------------------------------------------------------------------------
def cache_len(cfg, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def make_cache_shapes(cfg, batch: int, seq_len: int, rules: Rules,
                      as_spec: bool = False):
    """ShapeDtypeStructs (or PartitionSpecs) for the decode cache."""
    np_ = n_periods(cfg)
    kh, hd = cfg.kh_eff, cfg.hd      # kv heads after TP replication
    cdt = dt(cfg.compute_dtype)
    Sc = cache_len(cfg, seq_len)
    tree: Dict[str, Any] = {}
    for pos in range(period(cfg)):
        sub: Dict[str, Any] = {}
        if cfg.layer_kind(pos) == "attn":
            shp = (np_, batch, Sc, kh, hd)
            axes = ("layers", "batch", "kv_seq", "kv_heads_cache", None)
            sub["k"] = (rules.spec(*axes) if as_spec
                        else jax.ShapeDtypeStruct(shp, cdt))
            sub["v"] = (rules.spec(*axes) if as_spec
                        else jax.ShapeDtypeStruct(shp, cdt))
        else:
            di, N, K = cfg.d_inner, cfg.ssm_state, cfg.d_conv
            c_shp = (np_, batch, K - 1, di)
            h_shp = (np_, batch, di, N)
            sub["conv"] = (rules.spec("layers", "batch", None, "d_inner")
                           if as_spec else jax.ShapeDtypeStruct(c_shp, cdt))
            sub["h"] = (rules.spec("layers", "batch", "d_inner", None)
                        if as_spec else jax.ShapeDtypeStruct(h_shp, jnp.float32))
        if cfg.has_cross_attn(pos):
            vshp = (np_, batch, cfg.n_vision_tokens, kh, hd)
            vaxes = ("layers", "batch", None, "kv_heads_cache", None)
            sub["xk"] = (rules.spec(*vaxes) if as_spec
                         else jax.ShapeDtypeStruct(vshp, cdt))
            sub["xv"] = (rules.spec(*vaxes) if as_spec
                         else jax.ShapeDtypeStruct(vshp, cdt))
        tree[f"pos{pos}"] = sub
    tree["pos_idx"] = (rules.spec() if as_spec
                       else jax.ShapeDtypeStruct((), jnp.int32))
    return tree


# ---------------------------------------------------------------------------
#  Layer application
# ---------------------------------------------------------------------------
def _apply_layer(h, sub, cfg, rules, pos, q_pos, kv_pos, vision,
                 cache, cache_pos, mode):
    """One layer at in-period position ``pos``.  Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    kind = cfg.layer_kind(pos)
    hin = rms_norm(h, sub["ln1"], cfg.norm_eps)
    hin = rules.cons(hin, "batch", "seq_act", None)   # SP: norm runs sharded
    if kind == "attn":
        kv_cache = ((cache["k"], cache["v"])
                    if (cache is not None and mode == "decode") else None)
        out, kv = attn_block(
            hin, hin, sub["attn"], cfg, rules, q_pos, kv_pos,
            causal=cfg.causal, window=cfg.sliding_window,
            kv_cache=kv_cache, cache_pos=cache_pos)
        if mode in ("decode", "prefill") and kv is not None:
            k_, v_ = kv
            if mode == "prefill" and cfg.sliding_window:
                W = cache_len(cfg, k_.shape[1])
                k_, v_ = k_[:, -W:], v_[:, -W:]
            new_cache["k"], new_cache["v"] = k_, v_
        h = h + out
    else:
        st = ((cache["conv"], cache["h"])
              if (cache is not None and mode == "decode") else None)
        out, st_new = mamba_block(hin, sub["mamba"], cfg, rules, state=st)
        if mode in ("decode", "prefill") and st_new is not None:
            new_cache["conv"], new_cache["h"] = st_new
        h = h + out
    if cfg.has_cross_attn(pos):
        use_cached_vision = (mode == "decode" and cache is not None
                             and "xk" in cache)
        if use_cached_vision or vision is not None:
            hx = rms_norm(h, sub["ln_x"], cfg.norm_eps)
            if use_cached_vision:
                # decode cross-attn: reuse cached vision K/V (no recompute)
                xk, xv = cache["xk"], cache["xv"]
                out = _cross_with_cache(hx, xk, xv, sub["xattn"], cfg, rules)
                new_cache["xk"], new_cache["xv"] = xk, xv
            else:
                out, kv = attn_block(
                    hx, vision, sub["xattn"], cfg, rules, q_pos,
                    jnp.arange(vision.shape[1]), causal=False,
                    use_rope=False)
                if mode == "prefill":
                    new_cache["xk"], new_cache["xv"] = kv
            h = h + jnp.tanh(sub["xattn"]["gate"].astype(h.dtype)) * out
    if cfg.d_ff > 0:
        hin2 = rms_norm(h, sub["ln2"], cfg.norm_eps)
        hin2 = rules.cons(hin2, "batch", "seq_act", None)
        if cfg.ffn_kind(pos) == "moe":
            out, aux = moe_block(hin2, sub["moe"], cfg, rules)
        else:
            out = mlp_block(hin2, sub["mlp"], cfg, rules)
        h = h + out
    # sequence parallelism: park the residual stream seq-sharded over the
    # TP axis between blocks (no-op unless cfg.seq_shard) — GSPMD then
    # lowers the per-block TP sync as reduce-scatter + all-gather instead
    # of a full all-reduce (half the wire bytes)
    h = rules.cons(h, "batch", "seq_act", None)
    return h, new_cache, aux


def _cross_with_cache(hx, xk, xv, p, cfg, rules):
    """Cross-attention against cached vision K/V (decode path).  The cache
    holds kh_eff heads (TP kv replication applied at prefill)."""
    from .layers import sdpa
    B, Sq, d = hx.shape
    h_, kh, hd = cfg.n_heads, cfg.kh_eff, cfg.hd
    G = h_ // kh
    cdt = dt(cfg.compute_dtype)
    q = jnp.einsum("bsd,dn->bsn", hx.astype(cdt), p["wq"].astype(cdt))
    q = q.reshape(B, Sq, kh, G, hd)
    mask = jnp.ones((1, 1, 1, Sq, xk.shape[1]), bool)
    out = sdpa(q, xk.astype(cdt), xv.astype(cdt), mask, 0.0)
    out = out.reshape(B, Sq, h_ * hd)
    return jnp.einsum("bsn,nd->bsd", out, p["wo"].astype(cdt))


# ---------------------------------------------------------------------------
#  Backbone (scan over periods)
# ---------------------------------------------------------------------------
def backbone(params, h, cfg, rules: Rules, mode: str,
             q_pos, kv_pos, vision=None, cache=None, cache_pos=None):
    """h: [B, S, d] -> (h, new_cache_or_None, aux_loss)."""
    P_ = period(cfg)

    def body(carry, xs):
        hh, aux = carry
        bp, cc = xs
        new_cc: Dict[str, Any] = {}
        for pos in range(P_):
            sub = bp[f"pos{pos}"]
            c = cc[f"pos{pos}"] if cc is not None else None
            hh, nc, a = _apply_layer(hh, sub, cfg, rules, pos, q_pos, kv_pos,
                                     vision, c, cache_pos, mode)
            new_cc[f"pos{pos}"] = nc
            aux = aux + a
        return (hh, aux), new_cc

    # remat only matters when a backward pass will run
    if cfg.remat_policy == "full" and mode == "train":
        body = jax.checkpoint(body)

    blocks = params["blocks"]
    layer_cache = ({k: v for k, v in cache.items() if k != "pos_idx"}
                   if cache is not None else None)
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.scan_layers and mode == "decode" and layer_cache is not None:
        # Decode: keep the stacked cache in the scan CARRY and update each
        # layer's slice in place.  Emitting the updated cache as scan ys
        # would double-buffer it (xs+ys copies of a multi-GB cache) and XLA
        # then round-trips it through f32; the carry-DUS form aliases the
        # donated input cache buffer (shared caching scheme at HBM level).
        def body_carry(carry, xs_i):
            hh, aux, cc_all = carry
            bp, i = xs_i
            cc = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                       keepdims=False),
                cc_all)
            (hh, aux), new_cc = body((hh, aux), (bp, cc))
            cc_new = jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), i, 0),
                cc_all, new_cc)
            return (hh, aux, cc_new), None

        idx = jnp.arange(n_periods(cfg))
        (h, aux, new_cache), _ = jax.lax.scan(
            body_carry, (h, aux0, layer_cache), (blocks, idx))
    elif cfg.scan_layers:
        xs = (blocks, layer_cache)
        (h, aux), new_cache = jax.lax.scan(body, (h, aux0), xs)
    else:
        new_caches = []
        carry = (h, aux0)
        for i in range(n_periods(cfg)):
            bp = jax.tree.map(lambda a: a[i], blocks)
            cc = (jax.tree.map(lambda a: a[i], layer_cache)
                  if layer_cache is not None else None)
            carry, nc = body(carry, (bp, cc))
            new_caches.append(nc)
        h, aux = carry
        new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
                     if new_caches and new_caches[0] else None)
    return h, new_cache, aux


# ---------------------------------------------------------------------------
#  Entry points
# ---------------------------------------------------------------------------
def _embed(params, batch, cfg, rules: Rules):
    cdt = dt(cfg.compute_dtype)
    if cfg.family == "audio":
        x = batch["frames"].astype(cdt)                  # [B, T, d] stub frontend
        x = jnp.einsum("btd,de->bte", x, params["in_proj_w"].astype(cdt))
        x = x + params["in_proj_b"].astype(cdt)
        x = rms_norm(x, params["in_ln"], cfg.norm_eps)
    else:
        tok = batch["tokens"]
        x = params["tok_embed"].astype(cdt)[tok]         # gather [B, S, d]
    return rules.cons(x, "batch", None, None)


def _logits(params, h, cfg, rules: Rules):
    cdt = dt(cfg.compute_dtype)
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h.astype(cdt),
                        params["head_w"].astype(cdt))
    return rules.cons(logits, "batch", None, "vocab")


def forward_train(params, batch, cfg, rules: Rules = NO_RULES):
    """-> (scalar loss, dict metrics).  batch: tokens [B,S] (+ vision /
    frames / labels per family)."""
    x = _embed(params, batch, cfg, rules)
    S = x.shape[1]
    pos = jnp.arange(S)
    vision = batch.get("vision")
    h, _, aux = backbone(params, x, cfg, rules, "train", pos, pos,
                         vision=vision)
    logits = _logits(params, h, cfg, rules).astype(jnp.float32)
    if cfg.family == "audio":
        labels = batch["labels"]                         # [B, T]
        tgt = labels
        lg = logits
    else:
        tgt = batch["tokens"][:, 1:]
        lg = logits[:, :-1]
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


def forward_prefill(params, batch, cfg, rules: Rules = NO_RULES):
    """Full forward over the prompt -> (last-position logits, cache)."""
    x = _embed(params, batch, cfg, rules)
    S = x.shape[1]
    pos = jnp.arange(S)
    vision = batch.get("vision")
    h, cache, _ = backbone(params, x, cfg, rules, "prefill", pos, pos,
                           vision=vision)
    logits = _logits(params, h[:, -1:], cfg, rules)
    if cache is not None:
        cache["pos_idx"] = jnp.asarray(S, jnp.int32)
    return logits, cache


def grow_cache(cache, cfg, max_len: int):
    """Pad prefill-built KV caches along the seq axis to ``max_len`` so
    decode has free slots (serving-time cache allocation)."""
    Sc = cache_len(cfg, max_len)

    def pad(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v") and x.ndim == 5 and x.shape[2] < Sc:
            padw = [(0, 0)] * x.ndim
            padw[2] = (0, Sc - x.shape[2])
            return jnp.pad(x, padw)
        return x

    return jax.tree_util.tree_map_with_path(pad, cache)


def decode_step(params, cache, batch, cfg, rules: Rules = NO_RULES):
    """One-token decode against the cache -> (logits [B,1,V], new cache)."""
    x = _embed(params, batch, cfg, rules)                # [B, 1, d]
    pos_idx = cache["pos_idx"]
    q_pos = pos_idx[None]
    h, new_cache, _ = backbone(params, x, cfg, rules, "decode",
                               q_pos, q_pos, vision=None,
                               cache=cache, cache_pos=pos_idx)
    logits = _logits(params, h, cfg, rules)
    new_cache["pos_idx"] = pos_idx + 1
    return logits, new_cache
