"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, QKV bias [arXiv:2407.10671]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, attn_bias=True,
    # adopted from EXPERIMENTS §Perf (it2/it3): sequence parallelism shards
    # the residual stream + remat saves over the TP axis (peak 20.4 -> 8.6
    # GiB/chip — the HBM fit) and bf16 microbatch grad accumulation trims
    # the accumulator (8.6 -> 8.1 GiB).  Both are semantics-preserving.
    seq_shard=True,
    grad_accum_dtype="bfloat16",
    grad_accum=16,
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, grad_accum=2)

SHAPES = lm_shapes(train_accum=16, skip_long=True)  # full attention
