"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attn 7:1 interleave
[arXiv:2403.19887]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab_size=65536, n_experts=16, experts_per_token=2,
    moe_layer_period=2,              # MoE every other layer (jamba paper)
    attn_layer_period=8,             # 1 attention layer per 8 (1:7 ratio)
    attn_layer_offset=4,
    ssm_state=16, expand=2, d_conv=4,
    # 398B params: bf16 params + bf16 moments (DESIGN §6 memory policy)
    param_dtype="bfloat16", opt_state_dtype="bfloat16",
    grad_accum=16,
)

SMOKE_CONFIG = CONFIG.replace(
    name="jamba-smoke", n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, n_experts=4, experts_per_token=2,
    ssm_state=8, ssm_chunk=16, moe_group_size=32,
    param_dtype="float32", opt_state_dtype="float32", grad_accum=2)

# attention only every 8th layer; long-context KV sharded over `data`
SHAPES = lm_shapes(train_accum=16)
