"""stablelm-3b [dense] — 32L d_model=2560 32H (MHA kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b family]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab_size=50304,
    grad_accum=4,
)

SMOKE_CONFIG = CONFIG.replace(
    name="stablelm-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, grad_accum=2)

SHAPES = lm_shapes(train_accum=4, skip_long=True)   # full attention
