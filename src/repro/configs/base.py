"""Model/shape configuration system.

Every assigned architecture gets a `configs/<id>.py` exporting:
  CONFIG        — full-size ModelConfig (exact paper/public numbers)
  SMOKE_CONFIG  — reduced same-family config for CPU smoke tests
  SHAPES        — the shape cells this arch runs (with principled skips)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_layer_period: int = 1        # MoE FFN every k-th layer (jamba: 2)
    moe_group_size: int = 1024       # GShard dispatch group size
    # --- SSM (mamba1) ---
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_chunk: int = 128             # inner sequential-scan chunk (remat unit)
    # --- attention ---
    sliding_window: int = 0          # 0 = full attention
    attn_bias: bool = False          # qwen-style QKV bias
    causal: bool = True              # False -> encoder (hubert)
    attn_layer_period: int = 1       # jamba: attention every k-th layer (8)
    attn_layer_offset: int = 0       # position of attn layer within period
    cross_attn_period: int = 0       # vlm: cross-attn every k-th layer
    cross_attn_offset: int = 0
    n_vision_tokens: int = 0         # vlm stub frontend sequence length
    mlp_kind: str = "swiglu"         # swiglu | gelu
    attn_impl: str = "reference"     # reference | pallas | interpret
    ssm_impl: str = "reference"      # reference | pallas | interpret
    attn_q_chunk: int = 0            # 0 = auto (chunk when Sq >= 8192);
                                     # else chunk q at this size (bounds the
                                     # materialized [q_chunk, Skv] scores)
    kv_repeat: int = 1               # replicate kv heads r-x so kh*r divides
                                     # the TP axis (math-identical GQA; set
                                     # per-mesh by launch/specs.py)
    expert_parallel: bool = False    # EP: shard MoE experts over 'model'
                                     # (needs n_experts % TP == 0); baseline
                                     # replicates experts and TPs d_ff
    seq_shard: bool = False          # Megatron-style sequence parallelism:
                                     # residual stream sharded over 'model'
                                     # on the SEQ dim between TP blocks (the
                                     # per-layer all-reduce becomes
                                     # reduce-scatter + all-gather)
    ssm_fused_ref: bool = False      # compute dA/dBx per step inside the
                                     # scan (no [chunk,d,N] HBM tensors) —
                                     # the pure-jnp analogue of the Pallas
                                     # kernel's VMEM fusion
    ssm_unroll: int = 1              # unroll factor of the inner time-step
                                     # scan: h stays in registers across k
                                     # fused steps (h HBM round-trips / k)
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    logit_softcap: float = 0.0       # grok-style tanh softcap
    # --- numerics / memory policy ---
    param_dtype: str = "float32"     # giant archs use bfloat16 (see DESIGN §6)
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    grad_accum_dtype: str = ""       # microbatch grad accumulator dtype;
                                     # "" = opt_state_dtype.  bf16 halves the
                                     # per-microbatch grad reduce-scatter
                                     # payload (§Perf lever)
    remat_policy: str = "full"       # full | none
    scan_layers: bool = True
    # --- medium-level partitioning (paper: horizontal splits) ---
    grad_accum: int = 1              # microbatches per train step

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def kh_eff(self) -> int:
        """kv-head count after TP replication (see kv_repeat)."""
        return self.n_kv_heads * self.kv_repeat

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def layer_kind(self, i: int) -> str:
        """'attn' or 'mamba' mixer for layer i (hybrid interleave)."""
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid":
            return ("attn" if i % self.attn_layer_period == self.attn_layer_offset
                    else "mamba")
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """'moe' or 'dense' FFN for layer i."""
        if self.n_experts and i % self.moe_layer_period == (self.moe_layer_period - 1):
            return "moe"
        return "dense"

    def has_cross_attn(self, i: int) -> bool:
        return (self.cross_attn_period > 0
                and i % self.cross_attn_period == self.cross_attn_offset)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------- param counting
    def param_count(self) -> int:
        """Total parameters — mirrors models/transformer._layer_defs."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        h, k, hd = self.n_heads, self.n_kv_heads, self.hd
        if self.family == "audio":
            total = d * d + 2 * d          # in_proj_w, in_proj_b, in_ln
        else:
            total = V * d                  # tok_embed
        total += d + d * V                 # final_ln, head_w
        for i in range(self.n_layers):
            total += d                     # ln1
            if self.layer_kind(i) == "attn":
                total += d * h * hd + 2 * d * k * hd + h * hd * d
                if self.attn_bias:
                    total += h * hd + 2 * k * hd
            else:                          # mamba
                di, N, dtr = self.d_inner, self.ssm_state, self.dt_rank
                total += (d * 2 * di + self.d_conv * di + di   # in/conv_w/b
                          + di * (dtr + 2 * N) + dtr * di + di  # x/dt_proj/bias
                          + di * N + di + di * d)               # A_log, D, out
            if self.has_cross_attn(i):
                total += d + d * h * hd + 2 * d * k * hd + h * hd * d + 1
            if f > 0:
                total += d                 # ln2
                nm = 3 if self.mlp_kind == "swiglu" else 2
                if self.ffn_kind(i) == "moe":
                    total += d * self.n_experts               # router
                    total += self.n_experts * nm * d * f
                else:
                    total += nm * d * f
        return total

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: top-k of experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        nm = 3 if self.mlp_kind == "swiglu" else 2
        inactive = 0
        for i in range(self.n_layers):
            if self.ffn_kind(i) == "moe":
                inactive += (self.n_experts - self.experts_per_token) * nm * d * f
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    grad_accum: int = 1              # microbatch count for train shapes

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


def lm_shapes(*, train_accum: int = 8, skip_decode: bool = False,
              skip_long: bool = False) -> Dict[str, ShapeConfig]:
    """The assigned LM shape set with per-arch principled skips."""
    shapes = {
        "train_4k": ShapeConfig("train_4k", 4096, 256, "train",
                                grad_accum=train_accum),
        "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    }
    if not skip_decode:
        shapes["decode_32k"] = ShapeConfig("decode_32k", 32768, 128, "decode")
        if not skip_long:
            shapes["long_500k"] = ShapeConfig("long_500k", 524288, 1, "decode")
    return shapes
