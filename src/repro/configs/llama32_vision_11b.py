"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attn image layers every 5th layer; vision frontend is a
STUB: input_specs() provides precomputed patch embeddings
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256,
    cross_attn_period=5, cross_attn_offset=3,
    n_vision_tokens=1601,            # 1 CLS + 40x40 patches
    grad_accum=8,
)

SMOKE_CONFIG = CONFIG.replace(
    name="llama-vision-smoke", n_layers=5, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, n_vision_tokens=17,
    grad_accum=2)

SHAPES = lm_shapes(train_accum=8, skip_long=True)   # full self-attention
