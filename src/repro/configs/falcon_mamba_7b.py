"""falcon-mamba-7b [ssm] — 64L d_model=4096 attn-free, vocab=65024,
ssm_state=16 (mamba1 arch) [arXiv:2410.05355]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=65024, ssm_state=16, expand=2, d_conv=4,
    mlp_kind="swiglu", grad_accum=8,
)

SMOKE_CONFIG = CONFIG.replace(
    name="falcon-mamba-smoke", n_layers=2, d_model=64, vocab_size=256,
    ssm_state=8, ssm_chunk=16, grad_accum=2)

# attn-free SSM: O(1) decode state — runs every shape including long_500k
SHAPES = lm_shapes(train_accum=8)
