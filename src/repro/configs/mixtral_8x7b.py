"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, sliding-window attention [arXiv:2401.04088]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, n_experts=8, experts_per_token=2,
    sliding_window=4096,
    grad_accum=8,
)

SMOKE_CONFIG = CONFIG.replace(
    name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, n_experts=4, experts_per_token=2,
    sliding_window=32, moe_group_size=32, grad_accum=2)

# SWA -> bounded KV ring buffer: long_500k runs
SHAPES = lm_shapes(train_accum=8)
