"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, llama-arch code model [arXiv:2405.04324]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152,
    # GPT-BigCode-style 4x gelu MLP (2 matrices) — swiglu at d_ff=4d would
    # put the model at ~28B, not the advertised 20B
    mlp_kind="gelu",
    grad_accum=8,
)

SMOKE_CONFIG = CONFIG.replace(
    name="granite-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab_size=256, grad_accum=2)

SHAPES = lm_shapes(train_accum=8, skip_long=True)   # full attention
