"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2 [hf:xai-org/grok-1]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab_size=131072, n_experts=8, experts_per_token=2,
    logit_softcap=30.0,
    # 314B params: bf16 params + bf16 moments (DESIGN §6 memory policy)
    param_dtype="bfloat16", opt_state_dtype="bfloat16",
    grad_accum=16,
)

SMOKE_CONFIG = CONFIG.replace(
    name="grok1-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256, n_experts=4, experts_per_token=2,
    moe_group_size=32, param_dtype="float32", opt_state_dtype="float32",
    grad_accum=2)

# full attention -> long_500k skipped (quadratic prefill / unbounded KV)
SHAPES = lm_shapes(train_accum=16, skip_long=True)
