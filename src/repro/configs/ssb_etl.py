"""The paper's own workload configuration: SSB ETL dataflows (§5).

`--arch ssb-etl` selects the ETL benchmark path rather than an LM; sizes
scale the lineorder fact table (paper used 1-8 GB ~ 13-107M rows)."""
from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class ETLConfig:
    name: str = "ssb-etl"
    lineorder_rows: int = 2_000_000      # ~150 MB columnar; scale up to match paper
    customers: int = 30_000
    suppliers: int = 2_000
    parts: int = 20_000
    num_splits: int = 8                  # m  (paper's best: 8 pipelines)
    pipeline_degree: int = 8             # m'
    chunk_rows: int = 262_144
    #: operator backend for the heavy components ("numpy" reference or "jax"
    #: accelerated — see src/repro/core/backend/); consumed via
    #: ``engine_options()``
    backend: str = "numpy"
    queries: tuple = ("Q1.1", "Q2.1", "Q3.1", "Q4.1")

    def engine_options(self, **overrides):
        """OptimizeOptions preconfigured from this workload config —
        including the operator backend — for OptimizedEngine/StreamingEngine.
        Keyword overrides win."""
        from ..core.engine import OptimizeOptions    # deferred (light module)
        kw = dict(num_splits=self.num_splits,
                  pipeline_degree=self.pipeline_degree,
                  chunk_rows=self.chunk_rows,
                  backend=self.backend)
        kw.update(overrides)
        return OptimizeOptions(**kw)


CONFIG = ETLConfig()
SMOKE_CONFIG = ETLConfig(name="ssb-etl-smoke", lineorder_rows=50_000,
                         customers=2_000, suppliers=200, parts=1_000,
                         num_splits=4, pipeline_degree=4, chunk_rows=16_384)
