"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, QKV bias [hf:Qwen/Qwen2.5-32B]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
    vocab_size=152064, attn_bias=True,
    # 40 heads / kv=8: no kv_repeat makes kh*r divide TP=16 while keeping
    # query groups even (DESIGN §5) -> scores stay head-unsharded; q-chunking
    # bounds the materialized [q_chunk, S] block instead
    attn_q_chunk=1024,
    grad_accum=16,
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen25-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=256, grad_accum=2)

SHAPES = lm_shapes(train_accum=16, skip_long=True)   # full attention
