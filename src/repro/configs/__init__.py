"""Architecture registry: --arch <id> resolves here."""
from importlib import import_module
from typing import Dict

from .base import ModelConfig, ShapeConfig, lm_shapes

_ARCH_MODULES: Dict[str, str] = {
    "falcon-mamba-7b": ".falcon_mamba_7b",
    "grok-1-314b": ".grok1_314b",
    "mixtral-8x7b": ".mixtral_8x7b",
    "qwen2.5-32b": ".qwen25_32b",
    "granite-20b": ".granite_20b",
    "stablelm-3b": ".stablelm_3b",
    "qwen2-72b": ".qwen2_72b",
    "jamba-1.5-large-398b": ".jamba15_large_398b",
    "hubert-xlarge": ".hubert_xlarge",
    "llama-3.2-vision-11b": ".llama32_vision_11b",
}

ARCH_IDS = list(_ARCH_MODULES.keys())


def get_arch(arch_id: str):
    """Returns the arch module with CONFIG / SMOKE_CONFIG / SHAPES."""
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return import_module(_ARCH_MODULES[arch_id], __package__)


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    mod = get_arch(arch_id)
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def get_shapes(arch_id: str) -> Dict[str, ShapeConfig]:
    return dict(get_arch(arch_id).SHAPES)


def all_cells():
    """Every (arch, shape) dry-run cell after principled skips."""
    for arch_id in ARCH_IDS:
        for shape_name, shape in get_shapes(arch_id).items():
            yield arch_id, shape_name, shape


__all__ = ["ModelConfig", "ShapeConfig", "lm_shapes", "ARCH_IDS",
           "get_arch", "get_config", "get_shapes", "all_cells"]
