"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504,
encoder-only (w2v2 arch); modality frontend is a STUB: input_specs()
provides precomputed frame embeddings [arXiv:2106.07447]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504, causal=False, mlp_kind="gelu",
    grad_accum=4,
)

SMOKE_CONFIG = CONFIG.replace(
    name="hubert-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=32, grad_accum=2)

# encoder-only: no decode step -> decode_32k / long_500k skipped
SHAPES = lm_shapes(train_accum=4, skip_decode=True)
