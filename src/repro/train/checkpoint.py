"""Sharded checkpointing with resharding restore (fault tolerance +
elastic scaling substrate).

Layout:  <dir>/step_<N>/
           meta.msgpack          — step, config name, tree structure, dtypes
           arrays.npz            — one entry per flattened tree path

Saves are atomic (tmp dir + rename) and optionally asynchronous (background
thread — training continues while the previous state serializes, double
buffering the host copy).  Restore takes a *target mesh/sharding* so a
checkpoint written on one mesh restarts on another (elastic re-scale):
arrays are loaded on host then `device_put` with the new sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


# ------------------------------------------------------------- tree <-> flat
def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def tree_paths(tree) -> List[str]:
    return [k for k, _ in _flatten_with_paths(tree)]


def _unflatten_like(template, values: Dict[str, np.ndarray]):
    flat = _flatten_with_paths(template)
    leaves = [values[k] for k, _ in flat]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ------------------------------------------------------------------ save
def save_checkpoint(directory: str, step: int, state: Dict[str, Any],
                    extra_meta: Optional[dict] = None) -> str:
    """Synchronous atomic save.  ``state`` is any pytree of arrays."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step,
            "paths": [k for k, _ in flat],
            "shapes": {k: list(a.shape) for k, a in arrays.items()},
            "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
            "time": time.time(),
            "extra": extra_meta or {}}
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``template``.  ``shardings``: optional
    pytree of jax.sharding.Sharding — arrays are placed with it (resharding
    onto whatever mesh the caller is running now: elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    values = {k: data[k] for k in meta["paths"]}
    tree = _unflatten_like(template, values)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, meta


# ----------------------------------------------------------- manager
class CheckpointManager:
    """Periodic, asynchronous, keep-last-k checkpointing."""

    def __init__(self, directory: str, every_steps: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.every_steps = every_steps
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.saves = 0

    def maybe_save(self, step: int, state, extra_meta=None,
                   force: bool = False) -> bool:
        if not force and (step % self.every_steps != 0 or step == 0):
            return False
        # snapshot to host BEFORE handing to the background thread (the
        # device buffers may be donated/overwritten by the next step)
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save, args=(step, host_state, extra_meta),
                daemon=True)
            self._thread.start()
        else:
            self._save(step, host_state, extra_meta)
        return True

    def _save(self, step, host_state, extra_meta) -> None:
        save_checkpoint(self.directory, step, host_state, extra_meta)
        self.saves += 1
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
