"""Fault tolerance for 1000+-node deployments.

Three mechanisms (composable with the CheckpointManager):

1. ``with_retries`` — transient-failure retry with exponential backoff
   (preemptions, flaky interconnect RPCs, data-source hiccups).
2. ``StragglerWatchdog`` — per-step wall-time monitor.  In an SPMD job a
   straggling host stalls every step (collectives are synchronous), so
   persistent step-time inflation IS the straggler signal; the watchdog
   detects it (median × threshold over a sliding window) and fires a policy
   callback (alert / checkpoint-now / request re-shard).  The detection
   logic is hardware-independent and unit-tested with synthetic timings.
3. ``ElasticRunner`` — restart loop: on failure, restore the latest
   checkpoint onto the CURRENT device topology (possibly fewer/more hosts —
   checkpoint.restore reshards) and continue.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from ..core.faults import with_retries as _core_with_retries


def with_retries(fn: Callable, max_retries: int = 3, backoff: float = 0.1,
                 retry_on=(RuntimeError, OSError), on_retry=None):
    """Wrap fn with retry + exponential backoff.

    Thin shim over the generalized ``core.faults.with_retries`` (the
    dataflow engines' retry primitive), keeping this module's historical
    defaults (``retry_on=(RuntimeError, OSError)``)."""
    return _core_with_retries(fn, max_retries=max_retries, backoff=backoff,
                              retry_on=retry_on, on_retry=on_retry)


@dataclass
class StragglerEvent:
    step: int
    step_time: float
    median: float
    ratio: float


class StragglerWatchdog:
    """Sliding-window step-time monitor.

    ``threshold``: a step slower than threshold x running-median is a
    straggler suspicion; ``patience`` consecutive suspicions fire the
    policy (default: record only)."""

    def __init__(self, window: int = 32, threshold: float = 2.0,
                 patience: int = 3,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self.on_straggler = on_straggler
        self.times: collections.deque = collections.deque(maxlen=window)
        self.suspicions = 0
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        med = float(np.median(self.times)) if len(self.times) >= 4 else None
        self.times.append(step_time)
        if med is None or med <= 0:
            return None
        ratio = step_time / med
        if ratio > self.threshold:
            self.suspicions += 1
            if self.suspicions >= self.patience:
                ev = StragglerEvent(step, step_time, med, ratio)
                self.events.append(ev)
                if self.on_straggler is not None:
                    self.on_straggler(ev)
                self.suspicions = 0
                return ev
        else:
            self.suspicions = 0
        return None


class ElasticRunner:
    """Checkpoint-restart loop with topology-change tolerance.

    run(make_state, train_loop) calls ``train_loop(state, start_step)``;
    on an exception from ``recover_on`` it restores the newest checkpoint
    (resharded onto the current mesh by the caller-provided ``restore``)
    and retries, up to ``max_restarts``."""

    def __init__(self, restore: Callable[[], tuple], max_restarts: int = 3,
                 recover_on=(RuntimeError,)):
        self.restore = restore
        self.max_restarts = max_restarts
        self.recover_on = recover_on
        self.restarts = 0

    def run(self, train_loop: Callable[[Any, int], Any], init_state,
            start_step: int = 0):
        state, step = init_state, start_step
        while True:
            try:
                return train_loop(state, step)
            except self.recover_on as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state, step = self.restore()
