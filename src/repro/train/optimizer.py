"""AdamW (own implementation) with dtype policies and warmup+cosine schedule.

Moments are stored in ``cfg.opt_state_dtype`` (bf16 for the giant archs —
DESIGN §6 memory policy); the update math runs in fp32.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr \
        * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, model_cfg) -> Dict[str, Any]:
    odt = jnp.dtype(model_cfg.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, odt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_shapes(params, model_cfg):
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    odt = jnp.dtype(model_cfg.opt_state_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, odt)
    return {"m": jax.tree.map(sds, params),
            "v": jax.tree.map(sds, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_specs(param_specs_tree):
    """PartitionSpecs mirroring the parameter sharding."""
    from jax.sharding import PartitionSpec as P
    return {"m": param_specs_tree, "v": param_specs_tree, "step": P()}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, params, opt_state, ocfg: OptConfig, model_cfg
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"]
    lr = lr_at(step, ocfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.grad_clip / (gnorm + 1e-9)) \
        if ocfg.grad_clip > 0 else jnp.ones(())
    odt = jnp.dtype(model_cfg.opt_state_dtype)
    pdt = jnp.dtype(model_cfg.param_dtype)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m32 / bc1
        vh = v32 / bc2
        p32 = p.astype(jnp.float32)
        wd = ocfg.weight_decay if p.ndim >= 2 else 0.0   # no decay on norms/biases
        step_ = lr * (mh / (jnp.sqrt(vh) + ocfg.eps) + wd * p32)
        return ((p32 - step_).astype(pdt), m32.astype(odt), v32.astype(odt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
