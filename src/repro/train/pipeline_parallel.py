"""Pipeline parallelism: the paper's Algorithm 2 re-expressed on the device
mesh (DESIGN §3 mapping).

The layer stack is partitioned into n stages (the execution trees of the
device dataflow — coarse level); the batch is split into m microbatches (the
horizontal splits — medium level); each microbatch rides through the stages
like a shared cache through activity threads, with `collective_permute`
playing the pipeline hand-off.  The GPipe makespan

    T_p(m) = (m + n - 1) * t_stage + overheads  ~=  c/m + (m-1) t_j + n t0

is the paper's §4.2 cost model with t_j = the staggering (slowest) stage, so
Theorem 1's m* = sqrt((c - lambda N)/t0) chooses the microbatch count — the
same closed form, with t0 = per-microbatch fixed overhead (dispatch +
permute latency).

`gpipe_spmd` builds the schedule inside one shard_map: every device holds
one stage's parameters (P('stage') sharding), steps t = 0..m+n-2 run
lock-step SPMD, and activations rotate stage i -> i+1 between steps.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.planner import theorem1_m_star


def plan_microbatches(total_net_time: float, n_stages: int, t0: float,
                      m_max: int = 64) -> int:
    """Theorem-1 microbatch count for a pipeline of ``n_stages`` whose total
    per-batch net compute is ``total_net_time`` and per-microbatch fixed
    overhead is ``t0``.  In the paper's terms the staggering activity is the
    slowest stage: with even stages lambda*N = total/n per microbatch."""
    c = total_net_time
    lam_N = total_net_time / max(n_stages, 1)
    m = theorem1_m_star(c, 1.0, lam_N, t0, m_max=m_max)
    return max(1, min(int(round(m)), m_max))


def gpipe_spmd(stage_fn: Callable[[Any, jax.Array], jax.Array],
               mesh, n_stages: int, m: int, axis: str = "stage"):
    """Returns pipelined(stacked_params, xs) with
    stacked_params: [n_stages, ...] pytree (stage-sharded),
    xs: [m, mb, ...] microbatched input (replicated),
    -> ys: [m, mb, ...] outputs of the last stage (replicated).
    """

    def inner(params, xs):
        # shard_map gives each device params[1, ...]; drop the stage dim
        params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        n_steps = m + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        h0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)

        def step(carry, t):
            h_recv, outs = carry
            # stage 0 ingests microbatch t while t < m; later stages use the
            # activation received from the previous stage (Algorithm 2: a
            # consumer thread hands its shared cache to the next activity)
            x_t = xs[jnp.minimum(t, m - 1)]
            h_in = jnp.where(sid == 0, x_t, h_recv)
            h_out = stage_fn(params, h_in)
            # last stage emits microbatch (t - n_stages + 1) when valid
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            valid = (sid == n_stages - 1) & (t >= n_stages - 1)
            upd = jnp.where(valid, h_out, outs[out_idx])
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, out_idx, 0)
            # rotate activations stage i -> i+1 (pipeline hand-off)
            h_next = jax.lax.ppermute(h_out, axis, perm)
            return (h_next, outs), None

        (h_last, outs), _ = jax.lax.scan(step, (h0, outs0),
                                         jnp.arange(n_steps))
        # broadcast the last stage's output buffer to all stages
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), jax.tree.structure((0,)))
    from ..launch.jax_compat import shard_map
    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False)


def stack_stage_params(param_list) -> Any:
    """[per-stage pytree, ...] -> one pytree with leading n_stages dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *param_list)
