"""Train step: microbatch gradient accumulation (the paper's medium-level
horizontal partitioning — the global batch is split into m even splits that
stream through forward/backward like shared caches through an execution
tree), gradient clipping and AdamW.

The jitted step donates params/opt-state (the paper's shared caching scheme
applied to device buffers: the new state reuses the old state's memory, no
copy).  Gradients accumulate in ``opt_state_dtype`` so the giant archs stay
within the DESIGN §6 memory budget.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.layers import NO_RULES, Rules
from ..models.transformer import forward_train
from .optimizer import OptConfig, adamw_update


def _split_microbatches(batch: Dict[str, jax.Array], m: int):
    """[B, ...] -> [m, B/m, ...] for every leaf."""
    def resh(x):
        B = x.shape[0]
        assert B % m == 0, f"global batch {B} not divisible by microbatches {m}"
        return x.reshape(m, B // m, *x.shape[1:])
    return jax.tree.map(resh, batch)


def make_train_step(cfg, ocfg: OptConfig, rules: Rules = NO_RULES,
                    grad_transform: Optional[Callable] = None,
                    grad_pspecs=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``grad_transform(grads)`` hooks gradient compression etc.

    ``grad_pspecs``: optional PartitionSpec tree for the per-microbatch
    gradients.  Constraining them to the parameter sharding makes GSPMD
    lower the per-microbatch data-axis reduction as a reduce-scatter into
    the sharded accumulator instead of all-reduce + slice (half the wire
    bytes — §Perf hillclimb lever)."""
    m = max(cfg.grad_accum, 1)
    gdt = jnp.dtype(getattr(cfg, "grad_accum_dtype", "")
                    or cfg.opt_state_dtype)

    def loss_fn(params, mb):
        loss, metrics = forward_train(params, mb, cfg, rules)
        return loss, metrics

    def _constrain(grads):
        if grad_pspecs is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_pspecs)

    def train_step(params, opt_state, batch):
        if m > 1:
            micro = _split_microbatches(batch, m)

            def body(carry, mb):
                g_acc, l_acc = carry
                (loss, mets), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                grads = _constrain(grads)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(gdt), g_acc, grads)
                return (g_acc, l_acc + loss), mets

            g0 = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params))
            (g_sum, loss_sum), mets = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: (g / m).astype(gdt), g_sum)
            loss = loss_sum / m
            metrics = jax.tree.map(lambda x: x.mean(), mets)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, stats = adamw_update(grads, params, opt_state,
                                                  ocfg, cfg)
        metrics = dict(metrics)
        metrics.update(stats)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def jit_train_step(cfg, ocfg: OptConfig, rules: Rules, param_spec_tree,
                   batch_specs, mesh, grad_transform=None):
    """jit with explicit in/out shardings + donation (shared caching)."""
    from jax.sharding import NamedSharding
    from .optimizer import opt_state_specs

    step = make_train_step(cfg, ocfg, rules, grad_transform)
    ns = lambda spec: NamedSharding(mesh, spec)
    p_sh = jax.tree.map(ns, param_spec_tree)
    o_sh = jax.tree.map(ns, opt_state_specs(param_spec_tree),
                        is_leaf=lambda x: not isinstance(x, dict))
    b_sh = jax.tree.map(ns, batch_specs)
    return jax.jit(step,
                   in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None),
                   donate_argnums=(0, 1))
