"""Serving: prefill + decode steps with donated KV caches.

Donating the cache buffer into each decode step is the paper's shared
caching scheme applied to serving: the updated cache reuses the previous
cache's memory — no copy per token.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.layers import NO_RULES, Rules
from ..models.transformer import (decode_step, forward_prefill, grow_cache,
                                  make_cache_shapes)


def make_serve_steps(cfg, rules: Rules = NO_RULES):
    """Returns (prefill_fn, decode_fn) (unjitted)."""

    def prefill(params, batch):
        return forward_prefill(params, batch, cfg, rules)

    def decode(params, cache, batch):
        return decode_step(params, cache, batch, cfg, rules)

    return prefill, decode


def jit_serve_steps(cfg, rules: Rules, param_spec_tree, mesh,
                    batch: int, seq_len: int):
    """jit'd prefill/decode with explicit shardings; decode donates the
    cache (argnums=1)."""
    from jax.sharding import NamedSharding

    prefill, decode = make_serve_steps(cfg, rules)
    ns = lambda s: NamedSharding(mesh, s)
    p_sh = jax.tree.map(ns, param_spec_tree)
    cache_spec = make_cache_shapes(cfg, batch, seq_len, rules, as_spec=True)
    c_sh = jax.tree.map(ns, cache_spec)
    jp = jax.jit(prefill, in_shardings=(p_sh, None))
    jd = jax.jit(decode, in_shardings=(p_sh, c_sh, None),
                 out_shardings=(None, c_sh), donate_argnums=(1,))
    return jp, jd


def sample_token(logits: jax.Array, key, temperature: float = 0.0
                 ) -> jax.Array:
    """logits [B, 1, V] -> tokens [B, 1]."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1)[:, None]
    return jax.random.categorical(key, logits[:, -1] / temperature)[:, None]


def generate(params, cfg, prompts: jax.Array, max_new_tokens: int,
             rules: Rules = NO_RULES, temperature: float = 0.0,
             key=None, vision: Optional[jax.Array] = None):
    """Batched greedy/temperature generation (reference serving loop)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    batch: Dict[str, Any] = {"tokens": prompts}
    if vision is not None:
        batch["vision"] = vision
    logits, cache = jax.jit(
        lambda p, b: forward_prefill(p, b, cfg, rules))(params, batch)
    cache = grow_cache(cache, cfg, prompts.shape[1] + max_new_tokens)
    step = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg, rules),
                   donate_argnums=(1,))
    out = []
    tok = sample_token(logits, key, temperature)
    out.append(tok)
    for i in range(max_new_tokens - 1):
        key = jax.random.fold_in(key, i)
        logits, cache = step(params, cache, {"tokens": tok})
        tok = sample_token(logits, key, temperature)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
