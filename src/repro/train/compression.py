"""Gradient compression for cross-pod synchronization.

On the multi-pod mesh the per-step gradient all-reduce crosses the (slow)
inter-pod links.  We compress the pod-crossing reduction:

- bf16 compression: cast grads to bf16 before the cross-pod psum (2x bytes).
- int8 compression: per-tensor absmax scale, symmetric int8 quantize, psum
  in int32, dequantize (4x bytes) with ERROR FEEDBACK: the quantization
  residual is carried and added to the next step's gradient, preserving
  convergence (1-bit-Adam-style analysis applies).

Implemented with shard_map over the 'pod' axis so the quantize/psum/
dequantize appears explicitly in the lowered HLO (visible to the roofline's
collective scan).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def bf16_compress(grads):
    """Simple 2x compression of the gradient tree (no state)."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(g.dtype),
                        grads)


def int8_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_roundtrip_with_feedback(g: jax.Array, err: jax.Array
                                 ) -> Tuple[jax.Array, jax.Array]:
    """Quantize (g + err), return (dequantized, new_err)."""
    corrected = g.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = int8_quantize(corrected)
    deq = int8_dequantize(q, scale, jnp.float32)
    new_err = corrected - deq
    return deq.astype(g.dtype), new_err.astype(err.dtype)


def make_error_feedback_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree_int8(grads, err_state):
    """Apply int8 round-trip with error feedback to every leaf."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [int8_roundtrip_with_feedback(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e


def cross_pod_psum_int8(mesh, grad_specs):
    """Returns fn(grads) that all-reduces over the 'pod' axis with int8
    payload via shard_map (grads assumed pre-divided by pod count)."""
    from jax.experimental.shard_map import shard_map

    def psum_one(g):
        q, scale = int8_quantize(g)
        qsum = jax.lax.psum(q.astype(jnp.int32), "pod")
        ssum = jax.lax.pmax(scale, "pod")         # shared conservative scale
        return int8_dequantize(qsum, ssum, g.dtype)

    def fn(grads):
        return jax.tree.map(psum_one, grads)

    return shard_map(fn, mesh=mesh, in_specs=(grad_specs,),
                     out_specs=grad_specs, check_rep=False)
