"""Logical-axis -> mesh-axis rules for every execution profile.

The production mesh is (data=16, model=16), optionally with a leading pod=2
axis (multi-pod).  Parameters are 2D-sharded: FSDP-style over the data-like
axes ('embed' dims) x tensor-parallel over 'model' ('heads'/'d_ff'/'vocab'/
'd_inner') — uniform across profiles so a checkpoint reshards trivially.

Profiles differ only in activation layout:
  train:   batch over (pod, data)
  prefill: batch over (pod, data)
  decode:  batch over (pod, data); KV-cache heads over 'model' when the
           kv-head count divides the model axis, otherwise the cache SEQ
           dim goes over 'model' (flash-decode layout — GQA kv=8 / MQA kv=1
           archs cannot split 8 or 1 heads over 16 chips)
  long:    batch=1 -> unsharded; KV/SSM state sharded as wide as possible
           (seq over data[+model]) — the jamba/falcon 500k cells' layout.
"""
from __future__ import annotations

from typing import Optional, Sequence

from ..models.layers import Rules


def make_rules(mesh, profile: str = "train", cfg=None) -> Rules:
    """``mesh``: jax Mesh (or any object with .shape mapping axis->size)."""
    shape = dict(mesh.shape)
    multi_pod = "pod" in shape
    data_ax = ("pod", "data") if multi_pod else "data"
    model_n = shape.get("model", 1)

    kh = getattr(cfg, "kh_eff", getattr(cfg, "n_kv_heads", 0)) \
        if cfg is not None else 0
    kv_div = bool(kh) and kh % model_n == 0

    mapping = {
        # ---- parameters (2D: FSDP x TP) ----
        "embed": data_ax,            # FSDP axis
        "vocab": "model",
        "heads": "model",            # fused h*hd projection dim
        "kv_heads": "model",         # fused kh*hd projection dim
        "d_ff": "model",
        "d_inner": "model",
        # MoE: baseline = experts replicated, TP over d_ff; EP mode (needs
        # n_experts % model == 0) = experts over 'model', d_ff unsharded
        "experts": ("model" if getattr(cfg, "expert_parallel", False)
                    else None),
        "expert_ff": (None if getattr(cfg, "expert_parallel", False)
                      else "model"),
        "layers": None,
        # ---- activations ----
        "batch": data_ax,
        "kv_seq": None,
        "kv_heads_act": "model" if kv_div else None,
        "kv_heads_cache": "model" if kv_div else None,
        # sequence parallelism (residual stream seq dim over 'model');
        # None = replicated residual (baseline, pure Megatron-TP)
        "seq_act": ("model" if getattr(cfg, "seq_shard", False)
                    and profile == "train" else None),
    }
    if profile == "decode" and not kv_div:
        # flash-decode: split the 32k KV cache along SEQ over 'model'
        mapping["kv_seq"] = "model"
    if profile == "long":
        mapping["batch"] = None              # global_batch = 1
        mapping["kv_seq"] = (data_ax if kv_div
                             else (("pod", "data", "model") if multi_pod
                                   else ("data", "model")))
    return Rules(mapping)


def data_axis_size(mesh) -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.shape:
        size *= mesh.shape["pod"]
    return size
