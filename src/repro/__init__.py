"""Public package surface.

The declarative front end in one import:

    import repro

    f = (repro.flow("q4.1")
         .source(columns)
         .filter(repro.col("lo_quantity") < 25)
         .derive("rev", repro.col("lo_extendedprice") * repro.col("lo_discount"))
         .aggregate([], {"revenue": ("rev", "sum")})
         .sink())
    res = repro.Session(backend="jax").run(f, engine="streaming", optimize=2)

Subpackages: ``repro.core`` (dataflow runtime: graph, engines, optimizer,
backends, config), ``repro.etl`` (component library + SSB flows),
``repro.kernels`` / ``repro.models`` / ``repro.train`` / ``repro.launch``
(the jax/pallas model side).
"""
from .core.config import snapshot as config_snapshot
from .core.expr import Col, Expr, Lit, col, lit, where
from .session import (Flow, FlowBuilder, ServeSession, Session, SessionRun,
                      TickResult, flow, replay_deltas)

__all__ = [
    "Col", "Expr", "Lit", "col", "lit", "where",
    "Flow", "FlowBuilder", "ServeSession", "Session", "SessionRun",
    "TickResult", "flow", "replay_deltas",
    "config_snapshot",
]
