# Training input pipeline BUILT ON core/: the host-side token pipeline IS an
# ETL dataflow (source -> tokenize/pack transforms -> batch block), executed
# by the paper's optimized engine with shared caching + pipelined prefetch.
from .pipeline import (InputPipeline, PipelineConfig, SyntheticTokenSource,
                       make_lm_batch_fn)
from .prefetch import PrefetchQueue

__all__ = ["InputPipeline", "PipelineConfig", "SyntheticTokenSource",
           "make_lm_batch_fn", "PrefetchQueue"]
