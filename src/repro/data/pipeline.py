"""LM training input pipeline AS an ETL dataflow on the core engine.

The host-side token pipeline is expressed with the paper's own abstractions
and executed by the paper's optimized engine:

    doc source (SOURCE) -> length filter (ROW_SYNC) -> eos append (ROW_SYNC)
        -> sequence packer (BLOCK) -> batch sink (SINK)

Algorithm 1 partitions this into two execution trees (the packer roots the
second); inside each tree the shared caching scheme mutates one columnar
cache in place, and Algorithm 2's pipeline parallelization streams the
horizontal splits.  Each engine run processes one *window* of documents and
yields the packed [global_batch, seq_len+1] token blocks; `PrefetchQueue`
overlaps the next window's ETL with the device train step (the BlockingQueue
at the host/device boundary).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np

from ..core.component import BlockComponent, SourceComponent
from ..core.engine import OptimizedEngine, OptimizeOptions
from ..core.expr import col
from ..core.graph import Dataflow
from ..core.shared_cache import SharedCache, concat_caches
from ..etl.components import CollectSink, Filter


@dataclass(frozen=True)
class PipelineConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab_size: int = 32000
    max_doc_len: int = 512
    min_doc_len: int = 16
    docs_per_window: int = 4096
    num_splits: int = 8                # m  (horizontal splits per window)
    pipeline_degree: int = 4           # m' (in-flight bound)
    prefetch_depth: int = 2            # host->device staging queue
    eos_id: int = 1
    seed: int = 0


class SyntheticTokenSource(SourceComponent):
    """Documents of random length with a Zipf-ish token distribution.
    Columns: tokens [n, max_doc_len] int32 (padded), length [n] int32."""

    # the RNG stream is chunk-granular: the emitted documents change with the
    # chunk size, so the executor must not realign it to a backend preference
    chunk_sensitive = True

    def __init__(self, name: str, cfg: PipelineConfig, window: int):
        super().__init__(name)
        self.cfg = cfg
        self.window = window

    def total_rows(self) -> int:
        return self.cfg.docs_per_window

    def chunks(self, chunk_rows: int) -> Iterator[SharedCache]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, self.window))
        remaining = cfg.docs_per_window
        idx = 0
        while remaining > 0:
            n = min(chunk_rows, remaining)
            # lengths ~ uniform over [2, max_doc_len]; filter drops < min
            lengths = rng.integers(2, cfg.max_doc_len + 1, n).astype(np.int32)
            ranks = rng.zipf(1.3, size=(n, cfg.max_doc_len)).astype(np.int64)
            toks = (ranks % (cfg.vocab_size - 2) + 2).astype(np.int32)
            toks[np.arange(cfg.max_doc_len)[None, :] >= lengths[:, None]] = 0
            cache = SharedCache({"tokens": toks, "length": lengths}, n,
                                split_index=idx)
            self.rows_out += n
            yield cache
            remaining -= n
            idx += 1


class SequencePacker(BlockComponent):
    """BLOCK component: concatenates document tokens (with an EOS separator)
    and re-blocks into rows of seq_len+1 — the aggregation of this dataflow."""

    def __init__(self, name: str, seq_len: int, eos_id: int,
                 carry: Optional[np.ndarray] = None):
        super().__init__(name)
        self.seq_len = seq_len
        self.eos_id = eos_id
        self.carry = carry if carry is not None else np.zeros(0, np.int32)
        self.leftover = np.zeros(0, np.int32)

    def finish(self, state: List[SharedCache]) -> SharedCache:
        merged = concat_caches(state, ordered=True)
        toks = merged.col("tokens")
        lens = merged.col("length")
        parts = [self.carry]
        for i in range(merged.n):
            parts.append(toks[i, : lens[i]])
            parts.append(np.array([self.eos_id], np.int32))
        stream = np.concatenate(parts) if parts else np.zeros(0, np.int32)
        L = self.seq_len + 1
        n_seq = len(stream) // L
        self.leftover = stream[n_seq * L:]
        out = stream[: n_seq * L].reshape(n_seq, L)
        self.rows_out += n_seq
        return SharedCache({"tokens": out}, n_seq)


def build_lm_dataflow(cfg: PipelineConfig, window: int,
                      carry: Optional[np.ndarray] = None):
    """The LM token dataflow for one document window."""
    flow = Dataflow(f"lm-input-w{window}")
    src = SyntheticTokenSource("doc_source", cfg, window)
    filt = Filter("length_filter", col("length") >= cfg.min_doc_len)
    packer = SequencePacker("sequence_packer", cfg.seq_len, cfg.eos_id,
                            carry=carry)
    sink = CollectSink("batch_sink")
    flow.chain(src, filt, packer, sink)
    return flow, packer, sink


class InputPipeline:
    """Iterator of training batches produced by the optimized ETL engine."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self._window = 0
        self._carry = np.zeros(0, np.int32)
        self._pool = np.zeros((0, cfg.seq_len + 1), np.int32)
        self.engine_runs = []

    def _refill(self) -> None:
        cfg = self.cfg
        flow, packer, sink = build_lm_dataflow(cfg, self._window, self._carry)
        run = OptimizedEngine(flow, OptimizeOptions(
            num_splits=cfg.num_splits,
            pipeline_degree=cfg.pipeline_degree)).run()
        self.engine_runs.append(run)
        self._carry = packer.leftover
        got = sink.result()["tokens"].astype(np.int32)
        self._pool = (np.concatenate([self._pool, got])
                      if len(self._pool) else got)
        self._window += 1

    def __iter__(self) -> Iterator[np.ndarray]:
        return self

    def __next__(self) -> np.ndarray:
        B = self.cfg.global_batch
        while len(self._pool) < B:
            self._refill()
        batch, self._pool = self._pool[:B], self._pool[B:]
        return batch


def make_lm_batch_fn(cfg) -> Callable[[np.ndarray], Dict[str, np.ndarray]]:
    """Adapt packed token blocks [B, S+1] to the model-family batch dict.
    Modality frontends are STUBS per the assignment: frames / vision patches
    are deterministic embeddings of the token ids."""
    if cfg.family == "audio":
        rng = np.random.default_rng(7)
        proj = rng.normal(scale=0.02,
                          size=(min(cfg.vocab_size, 512), cfg.d_model)
                          ).astype(np.float32)

        def fn(tok_block: np.ndarray) -> Dict[str, np.ndarray]:
            toks = tok_block[:, :-1] % min(cfg.vocab_size, 512)
            return {"frames": proj[toks],
                    "labels": (tok_block[:, :-1] % cfg.vocab_size
                               ).astype(np.int32)}
        return fn

    if cfg.family == "vlm":
        rng = np.random.default_rng(11)
        patches = rng.normal(scale=0.02,
                             size=(cfg.n_vision_tokens, cfg.d_model)
                             ).astype(np.float32)

        def fn(tok_block: np.ndarray) -> Dict[str, np.ndarray]:
            B = tok_block.shape[0]
            return {"tokens": (tok_block[:, :-1] % cfg.vocab_size
                               ).astype(np.int32),
                    "vision": np.broadcast_to(
                        patches, (B,) + patches.shape).copy()}
        return fn

    def fn(tok_block: np.ndarray) -> Dict[str, np.ndarray]:
        return {"tokens": (tok_block[:, :-1] % cfg.vocab_size
                           ).astype(np.int32)}
    return fn
