"""Pipelined host->device prefetch — Algorithm 2's BlockingQueue(m') applied
at the host/device boundary.

The producer thread runs the host ETL dataflow and stages ready batches in a
bounded queue (depth m'); the consumer (training loop) pops a batch while the
NEXT one is being produced — exactly the paper's pipeline consumer thread
protocol, with the device step playing the role of the downstream activity.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

_EOS = object()


class PrefetchQueue:
    """Bounded producer/consumer staging queue (depth = pipeline degree m')."""

    def __init__(self, it: Iterator[Any], depth: int = 2,
                 stage_fn: Optional[Callable[[Any], Any]] = None):
        self.q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self.stage_fn = stage_fn
        self.error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._produce, args=(it,),
                                        daemon=True, name="prefetch")
        self._stop = threading.Event()
        self._thread.start()

    def _produce(self, it: Iterator[Any]) -> None:
        try:
            for item in it:
                if self._stop.is_set():
                    return
                if self.stage_fn is not None:
                    item = self.stage_fn(item)   # e.g. device_put
                self.q.put(item)
        except BaseException as e:  # noqa: BLE001 — surfaced on next()
            self.error = e
        finally:
            self.q.put(_EOS)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is _EOS:
            if self.error is not None:
                raise self.error
            raise StopIteration
        return item

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
