"""Metrics registry — counters / gauges / histograms for one traced run.

Each ``trace.Tracer`` owns one ``MetricsRegistry``; the instrumentation
hooks (``trace.on_transfer`` / ``on_copy`` / ``on_arena`` / ``on_dispatch``
/ ``on_wait`` / ``on_kernel``) increment it while the tracer's *measuring*
window is open.  The engines open that window exactly where they open
``cache_stats_scope``, so the counter family below reconciles EXACTLY with
the run's ``CacheStats`` snapshot — the same call sites feed both — and the
snapshot lands in ``EngineRun.metrics`` / ``MetadataStore.register_run`` /
``BENCH_<tag>.json``.

Everything here is stdlib-only (thread-safe via one lock per registry) and
JSON-safe via ``snapshot()``.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

#: histogram bucket upper bounds, in seconds (log2 from 1 µs to ~16 s);
#: observations above the last bound land in the +Inf overflow slot
_BUCKET_BOUNDS_S: List[float] = [1e-6 * (1 << k) for k in range(25)]


class Histogram:
    """Fixed log2-bucket latency histogram (seconds)."""

    __slots__ = ("count", "sum_s", "min_s", "max_s", "buckets", "overflow")

    def __init__(self) -> None:
        self.count = 0
        self.sum_s = 0.0
        self.min_s: Optional[float] = None
        self.max_s: Optional[float] = None
        self.buckets = [0] * len(_BUCKET_BOUNDS_S)
        self.overflow = 0

    def observe(self, seconds: float) -> None:
        self.count += 1
        self.sum_s += seconds
        self.min_s = seconds if self.min_s is None else min(self.min_s, seconds)
        self.max_s = seconds if self.max_s is None else max(self.max_s, seconds)
        for i, bound in enumerate(_BUCKET_BOUNDS_S):
            if seconds <= bound:
                self.buckets[i] += 1
                return
        self.overflow += 1

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum_s": self.sum_s,
            "min_s": self.min_s,
            "max_s": self.max_s,
            # sparse [le_us, count] pairs — only occupied buckets
            "buckets": [[round(b * 1e6, 3), n]
                        for b, n in zip(_BUCKET_BOUNDS_S, self.buckets) if n],
            "overflow": self.overflow,
        }


class MetricsRegistry:
    """Thread-safe named counters (monotonic adds), gauges (set / high-water)
    and latency histograms."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Histogram] = {}

    # ----------------------------------------------------------- counters
    def inc(self, name: str, delta=1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + delta

    def counter(self, name: str):
        with self._lock:
            return self._counters.get(name, 0)

    # ------------------------------------------------------------- gauges
    def gauge_set(self, name: str, value) -> None:
        with self._lock:
            self._gauges[name] = value

    def gauge_max(self, name: str, value) -> None:
        """High-water gauge: keeps the maximum observed value."""
        with self._lock:
            cur = self._gauges.get(name)
            if cur is None or value > cur:
                self._gauges[name] = value

    # --------------------------------------------------------- histograms
    def observe(self, name: str, seconds: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h.observe(seconds)

    # ------------------------------------------------------------ exports
    def snapshot(self) -> dict:
        """JSON-safe {"counters", "gauges", "histograms"} snapshot."""
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.snapshot() for k, h in self._hists.items()},
            }
