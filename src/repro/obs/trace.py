"""Contextvar-scoped structured tracing — spans, instants and counter events
emitted as Chrome-trace / Perfetto JSON.

Mirrors ``core.shared_cache.cache_stats_scope``: a ``Tracer`` pushed with
``trace_scope`` (or opened per run by the engines via ``run_scope`` when
``REPRO_TRACE=1``) is carried through ``contextvars``, so the shared worker
pool — which runs every task under the submitter's copied context — scopes
events to the right run even across threads.  Scopes nest; every emit goes
to ALL active tracers.

Zero-cost guarantee when disabled: every hot call site first checks
``ACTIVE.get()`` (one contextvar read); with no tracer in scope and
``REPRO_TRACE`` unset, no object is allocated and no lock is taken.

Event model (Chrome trace "traceEvents" array, ts/dur in µs):

  ph="X" complete spans    — engine phases (cat ``phase``), per-component
                             per-chunk dispatches (cat ``compute``), fused
                             kernel launches (cat ``kernel``), h2d/d2h
                             transfers (cat ``transfer``), blocking waits
                             (cat ``wait``: channel put/get/drain, admission,
                             activity busy-wait)
  ph="i" instant events    — cache copies (cat ``copy``), arena
                             acquire/release (cat ``arena``)
  ph="C" counter events    — channel occupancy (cat ``channel``)

Each run exported by an engine becomes its own Perfetto *process* (pid =
run ordinal, process_name = flow/engine/backend/run-id) with real thread
ids and names, so one ``REPRO_TRACE_PATH`` file from a whole benchmark
session opens in ``ui.perfetto.dev`` as a stack of runs.

The transfer/copy/arena hooks are called from ``core.shared_cache``'s
scoped-statistics funnels — the SAME call sites that feed ``CacheStats`` —
so metric counters reconcile exactly with the run's cache statistics (see
``obs.metrics``).
"""
from __future__ import annotations

import contextvars
import json
import os
import subprocess
import threading
import time
import uuid
from contextlib import contextmanager, nullcontext
from datetime import datetime, timezone
from typing import Dict, List, Optional

from ..core import config
from .metrics import MetricsRegistry

#: active tracer scopes (innermost last) — module-level so hot paths can do
#: the cheapest possible disabled check: ``if ACTIVE.get(): ...``
ACTIVE: "contextvars.ContextVar[tuple]" = contextvars.ContextVar(
    "repro_trace_scopes", default=())


def active() -> bool:
    """True when at least one tracer scope is open on this context."""
    return bool(ACTIVE.get())


# ---------------------------------------------------------------------------
#  Run identity (satellite: joinable bench / metadata / trace artifacts)
# ---------------------------------------------------------------------------
def new_run_id() -> str:
    """Fresh opaque run identifier (uuid4 hex)."""
    return uuid.uuid4().hex


def iso_now() -> str:
    """Current UTC time as an ISO-8601 string."""
    return datetime.now(timezone.utc).isoformat(timespec="seconds")


_GIT_SHA: List[Optional[str]] = []        # one-element cache (None = no repo)


def git_sha() -> Optional[str]:
    """HEAD commit of the working directory's git repo, cached per process;
    ``None`` when git is unavailable or the cwd is not a repository."""
    if not _GIT_SHA:
        sha: Optional[str] = None
        try:
            out = subprocess.run(
                ["git", "rev-parse", "HEAD"], cwd=os.getcwd(),
                capture_output=True, text=True, timeout=5.0)
            if out.returncode == 0:
                sha = out.stdout.strip() or None
        except (OSError, subprocess.SubprocessError):
            sha = None
        _GIT_SHA.append(sha)
    return _GIT_SHA[0]


# ---------------------------------------------------------------------------
#  Tracer
# ---------------------------------------------------------------------------
class Tracer:
    """Thread-safe event collector for one scope (usually one engine run).

    ``measuring`` gates the METRIC counters only (events always record while
    the tracer is in scope): the engines flip it on exactly where they open
    their per-run ``cache_stats_scope``, so ``metrics`` counters cover the
    identical window as the run's ``CacheStats`` — exact reconciliation.

    Event retention is capped (``max_events``, default
    ``REPRO_TRACE_MAX_EVENTS``): once the buffer exceeds the cap the OLDEST
    half rotates out (``dropped_events`` counts the loss).  A finite batch
    run never comes near the cap; a resident serving session emitting spans
    for thousands of ticks stays bounded instead of growing for the life of
    the process.  Metric counters are monotonic scalars and never rotate.
    """

    def __init__(self, name: str = "trace", measuring: bool = True,
                 max_events: Optional[int] = None):
        self.name = name
        self.measuring = measuring
        self.metrics = MetricsRegistry()
        self.events: List[dict] = []
        self.meta: Dict[str, object] = {}
        self._lock = threading.Lock()
        self.thread_names: Dict[int, str] = {}
        self.max_events = (config.trace_max_events()
                           if max_events is None else max(0, int(max_events)))
        self.dropped_events = 0
        #: per-shard sub-tracers of a sharded run (core/shard): each exports
        #: as its own shard-tagged Perfetto process next to the parent run
        self.shard_tracers: List["Tracer"] = []

    def emit(self, ph: str, cat: str, name: str, ts_us: float,
             dur_us: Optional[float] = None,
             args: Optional[dict] = None) -> None:
        tid = threading.get_ident()
        ev = {"ph": ph, "cat": cat, "name": name,
              "ts": ts_us, "pid": 0, "tid": tid}
        if dur_us is not None:
            ev["dur"] = dur_us
        if args:
            ev["args"] = args
        with self._lock:
            if tid not in self.thread_names:
                self.thread_names[tid] = threading.current_thread().name
            self.events.append(ev)
            if self.max_events and len(self.events) > self.max_events:
                # rotate the oldest half out in one bulk delete (amortized
                # O(1) per emit) rather than trimming one event per call
                drop = len(self.events) - self.max_events // 2
                del self.events[:drop]
                self.dropped_events += drop

    # ------------------------------------------------------------- exports
    def to_chrome(self, pid: int = 0) -> List[dict]:
        """This tracer's events as Chrome-trace dicts under process ``pid``
        (plus process/thread metadata events)."""
        with self._lock:
            events = [dict(ev) for ev in self.events]
            names = dict(self.thread_names)
        out: List[dict] = []
        label = self.meta.get("flow") or self.name
        detail = "/".join(str(self.meta[k]) for k in
                          ("engine", "backend") if self.meta.get(k))
        rid = str(self.meta.get("run_id", ""))[:8]
        pname = f"{label}" + (f" [{detail}]" if detail else "") \
            + (f" #{rid}" if rid else "")
        out.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": pname}})
        out.append({"ph": "M", "name": "process_sort_index", "pid": pid,
                    "tid": 0, "args": {"sort_index": pid}})
        for tid, tname in names.items():
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": tname}})
        for ev in events:
            ev["pid"] = pid
            out.append(ev)
        return out


# ---------------------------------------------------------------------------
#  Scoping
# ---------------------------------------------------------------------------
@contextmanager
def trace_scope(tracer: Optional[Tracer] = None):
    """Push a tracer onto this context (mirrors ``cache_stats_scope``).
    Every event emitted while the scope is active — including on worker-pool
    tasks submitted under it — lands in the yielded tracer; scopes nest."""
    tr = tracer if tracer is not None else Tracer()
    token = ACTIVE.set(ACTIVE.get() + (tr,))
    try:
        yield tr
    finally:
        ACTIVE.reset(token)


@contextmanager
def run_scope(**meta):
    """Engine entry point: opens a per-run tracer when tracing is enabled
    (``REPRO_TRACE=1``) or an outer ``trace_scope`` is already active —
    otherwise yields ``None`` without allocating anything (the hard
    zero-cost disabled path)."""
    if not (ACTIVE.get() or config.trace_enabled()):
        yield None
        return
    tr = Tracer(name=str(meta.get("flow", "run")), measuring=False)
    tr.meta = dict(meta)
    token = ACTIVE.set(ACTIVE.get() + (tr,))
    try:
        yield tr
    finally:
        ACTIVE.reset(token)


def measured(tracer: Optional[Tracer]):
    """Context manager opening the tracer's metric-counter window; the
    engines use it alongside ``cache_stats_scope`` so both cover the same
    events.  None-safe (no-op when tracing is off)."""
    if tracer is None:
        return nullcontext()

    @contextmanager
    def _measured():
        tracer.measuring = True
        try:
            yield tracer
        finally:
            tracer.measuring = False
    return _measured()


# ---------------------------------------------------------------------------
#  Span / event emitters (hot paths check ACTIVE first)
# ---------------------------------------------------------------------------
class _NullSpan:
    """Reusable no-op context manager returned by ``span`` when disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("cat", "name", "args", "t0")

    def __init__(self, cat: str, name: str, args: dict):
        self.cat = cat
        self.name = name
        self.args = args

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        complete(self.cat, self.name, self.t0,
                 time.perf_counter() - self.t0, **self.args)
        return False


def span(cat: str, name: str, **args):
    """Context manager recording a complete span on every active tracer;
    a shared no-op singleton when tracing is off."""
    if not ACTIVE.get():
        return _NULL_SPAN
    return _Span(cat, name, args)


def complete(cat: str, name: str, t0: float, dt: float, **args) -> None:
    """Record a finished span [t0, t0+dt] (``perf_counter`` seconds)."""
    for tr in ACTIVE.get():
        tr.emit("X", cat, name, t0 * 1e6, dt * 1e6, args or None)


def instant(cat: str, name: str, **args) -> None:
    ts = time.perf_counter() * 1e6
    for tr in ACTIVE.get():
        tr.emit("i", cat, name, ts, args=args or None)


def counter(cat: str, name: str, **series) -> None:
    """Perfetto counter track sample (e.g. channel occupancy over time)."""
    ts = time.perf_counter() * 1e6
    for tr in ACTIVE.get():
        tr.emit("C", cat, name, ts, args=series)


# ---------------------------------------------------------------------------
#  Instrumentation hooks — called from core layers; every hook both records
#  an event and (inside the measuring window) the reconciling metric counter
# ---------------------------------------------------------------------------
def on_dispatch(component: str, t0: float, t1: float, split: int,
                rows_in: int, rows_out: int, mt: int = 0) -> None:
    """One per-chunk component dispatch (``Component.process`` or the §4.3
    multithreaded path).  Span count == ``EngineRun.dispatch_calls``."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    args = {"component": component, "split": split,
            "rows_in": rows_in, "rows_out": rows_out}
    if mt:
        args["mt_threads"] = mt
    for tr in scopes:
        tr.emit("X", "compute", component, t0 * 1e6, (t1 - t0) * 1e6, args)
        if tr.measuring:
            tr.metrics.inc("dispatch_calls")


def on_accumulate(component: str, t0: float, t1: float, rows: int) -> None:
    """Per-chunk ``accumulate`` of a block/semi-block component (not a
    dispatch — it does not count toward ``dispatch_calls``)."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    for tr in scopes:
        tr.emit("X", "compute", component, t0 * 1e6, (t1 - t0) * 1e6,
                {"component": component, "phase": "accumulate", "rows": rows})


def on_kernel(name: str, backend: str, t0: float, t1: float,
              rows: int) -> None:
    """One fused-segment kernel dispatch; feeds the per-kernel latency
    histogram."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    dt = t1 - t0
    for tr in scopes:
        tr.emit("X", "kernel", name, t0 * 1e6, dt * 1e6,
                {"backend": backend, "rows": rows})
        if tr.measuring:
            tr.metrics.inc("kernel_dispatches")
            tr.metrics.observe("kernel_dispatch_s", dt)


def on_transfer(direction: str, nbytes: int, seconds: float = 0.0) -> None:
    """One h2d/d2h crossing (from ``shared_cache.record_transfer``).
    ``seconds`` is the measured copy duration where the call site timed it
    (0 => drawn as a zero-width slice)."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    t1 = time.perf_counter()
    for tr in scopes:
        tr.emit("X", "transfer", direction, (t1 - seconds) * 1e6,
                seconds * 1e6, {"bytes": int(nbytes)})
        if tr.measuring:
            m = tr.metrics
            m.inc(f"{direction}_transfers")
            m.inc(f"{direction}_bytes", int(nbytes))
            if seconds:
                m.inc(f"{direction}_seconds", seconds)


def on_copy(nbytes: int) -> None:
    """One physical cache copy (from ``shared_cache.record_copy``)."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    ts = time.perf_counter() * 1e6
    for tr in scopes:
        tr.emit("i", "copy", "cache.copy", ts, args={"bytes": int(nbytes)})
        if tr.measuring:
            tr.metrics.inc("copies")
            tr.metrics.inc("bytes_copied", int(nbytes))


def on_arena(hit: bool, nbytes: int) -> None:
    """One ``CacheArena.acquire`` (from ``shared_cache._record_arena``)."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    ts = time.perf_counter() * 1e6
    name = "acquire-hit" if hit else "acquire-miss"
    for tr in scopes:
        tr.emit("i", "arena", name, ts, args={"bytes": int(nbytes)})
        if tr.measuring:
            m = tr.metrics
            if hit:
                m.inc("arena_hits")
                m.inc("arena_bytes_reused", int(nbytes))
            else:
                m.inc("arena_misses")


def on_arena_release(nbytes: int) -> None:
    """One buffer returned to the arena pool (event + non-reconciling
    counter — ``CacheStats`` does not track releases)."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    ts = time.perf_counter() * 1e6
    for tr in scopes:
        tr.emit("i", "arena", "release", ts, args={"bytes": int(nbytes)})
        if tr.measuring:
            tr.metrics.inc("arena_releases")


def on_fault(site: str, kind: str, component=None) -> None:
    """One injected fault fired (from ``core.faults.record_fault``)."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    ts = time.perf_counter() * 1e6
    for tr in scopes:
        tr.emit("i", "fault", f"inject:{site}", ts,
                args={"kind": kind, "component": component})
        if tr.measuring:
            tr.metrics.inc("faults_injected")


def on_retry(where: str, attempt: int, delay_s: float) -> None:
    """One transient-failure retry about to back off (from
    ``core.faults.record_retry``); feeds the retry-latency histogram."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    ts = time.perf_counter() * 1e6
    for tr in scopes:
        tr.emit("i", "fault", "retry", ts,
                args={"where": where, "attempt": attempt,
                      "delay_s": delay_s})
        if tr.measuring:
            tr.metrics.inc("retries")
            tr.metrics.observe("retry_backoff_s", delay_s)


def on_degrade(kind: str, src: str, dst: str, component=None) -> None:
    """One degradation-ladder fallback (from
    ``core.faults.record_degradation``)."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    ts = time.perf_counter() * 1e6
    for tr in scopes:
        tr.emit("i", "fault", f"degrade:{kind}", ts,
                args={"src": src, "dst": dst, "component": component})
        if tr.measuring:
            tr.metrics.inc("degradations")


def on_wait(kind: str, t0: float, t1: float, **args) -> None:
    """One blocking wait (channel put/get/drain, admission gate, activity
    busy-wait).  ``kind`` names the wait site, e.g. ``channel.put``."""
    scopes = ACTIVE.get()
    if not scopes:
        return
    dt = t1 - t0
    for tr in scopes:
        tr.emit("X", "wait", kind, t0 * 1e6, dt * 1e6, args or None)
        if tr.measuring:
            tr.metrics.inc(f"wait_s.{kind}", dt)


# ---------------------------------------------------------------------------
#  Trace file export (REPRO_TRACE=1 => REPRO_TRACE_PATH, Perfetto-loadable)
# ---------------------------------------------------------------------------
class _TraceFile:
    """Process-wide accumulator: each exported run becomes its own Perfetto
    process in one JSON file, so a whole benchmark session lands in a single
    artifact.

    Size-capped rotation: the file retains at most ``REPRO_TRACE_MAX_EVENTS``
    events ACROSS runs — once a new export pushes the total past the cap,
    the oldest retained runs rotate out (the newest run always stays, even
    oversized).  Historically ``_runs`` grew for the life of the process,
    which a per-run CLI never noticed but a resident serving session turns
    into an unbounded leak."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._runs: List[Tracer] = []
        self.rotated_runs = 0

    def add_and_flush(self, tracer: Tracer, path: str) -> str:
        cap = config.trace_max_events()
        with self._lock:
            self._runs.append(tracer)
            if cap:
                while (len(self._runs) > 1
                       and sum(len(tr.events) for tr in self._runs) > cap):
                    self._runs.pop(0)
                    self.rotated_runs += 1
            # flatten per-shard sub-tracers next to their run so each shard
            # renders as its own Perfetto process
            flat: List[Tracer] = []
            for tr in self._runs:
                flat.append(tr)
                for sub in tr.shard_tracers:
                    # run-level meta (run_id, git_sha, ...) is attached to
                    # the parent at export time — after the sub-tracers
                    # copied it — so inherit whatever they are missing
                    for mk, mv in tr.meta.items():
                        sub.meta.setdefault(mk, mv)
                    flat.append(sub)
            events: List[dict] = []
            for pid, tr in enumerate(flat, start=1):
                events.extend(tr.to_chrome(pid=pid))
            runs_meta = [dict(tr.meta, dropped_events=tr.dropped_events)
                         for tr in flat]
            rotated = self.rotated_runs
        payload = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs", "runs": runs_meta,
                          "rotated_runs": rotated},
        }
        with open(path, "w") as f:
            json.dump(payload, f)
        return path


_TRACE_FILE = _TraceFile()


def export_run(tracer: Optional[Tracer], meta: Optional[dict] = None
               ) -> Optional[str]:
    """Append one finished run to the process trace file and rewrite it.
    No-op (returns None) unless ``REPRO_TRACE=1`` — an explicitly scoped
    tracer (tests, libraries) reads ``tracer.events`` directly instead."""
    if tracer is None or not config.trace_enabled():
        return None
    if meta:
        tracer.meta.update(meta)
    return _TRACE_FILE.add_and_flush(tracer, config.trace_path())
