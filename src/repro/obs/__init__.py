"""Observability: contextvar-scoped tracing (Chrome-trace/Perfetto export),
a per-run metrics registry that reconciles exactly with ``CacheStats``, and
the ``python -m repro.obs.report`` time-attribution CLI.

Enable per run with ``REPRO_TRACE=1`` (file at ``REPRO_TRACE_PATH``, default
``repro_trace.json``) or programmatically:

    from repro.obs import trace
    with trace.trace_scope() as tracer:
        engine.run()
    tracer.events            # raw span/instant/counter events
    tracer.metrics.snapshot()
"""
from .metrics import Histogram, MetricsRegistry
from .trace import (Tracer, active, export_run, git_sha, iso_now, new_run_id,
                    run_scope, span, trace_scope)

__all__ = [
    "Histogram", "MetricsRegistry", "Tracer", "active", "export_run",
    "git_sha", "iso_now", "new_run_id", "run_scope", "span", "trace_scope",
]
