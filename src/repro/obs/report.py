"""Time-attribution report over a Chrome-trace / Perfetto JSON file.

    python -m repro.obs.report trace.json [--json]

Folds the trace's complete spans into a per-run breakdown:

- **category totals** — compute vs transfer vs wait vs overhead, computed as
  SELF time (each span's duration minus its children's on the same thread),
  so a fused kernel nested inside its component's compute span is never
  double-counted and the ``execute`` phase's uncovered remainder surfaces as
  coordination *overhead*;
- **per-component table** — self compute time, kernel time, calls, rows in,
  for every component seen in ``compute``/``kernel`` spans;
- **wait sites** — total blocked time per wait kind (channel put/get/drain,
  admission gate, activity busy-wait);
- **transfer summary** — h2d/d2h crossing counts + bytes.

Instant events (cache copies, arena acquire/release) are counted, not
timed.  With ``--json`` the same structure is printed as JSON for tooling.
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict
from typing import Dict, List

#: span categories folded into the attribution classes (phase self time is
#: the run's coordination overhead)
_CATEGORY_CLASS = {"compute": "compute", "kernel": "compute",
                   "transfer": "transfer", "wait": "wait",
                   "phase": "overhead"}


def _self_times(spans: List[dict]) -> List[dict]:
    """Annotate each complete span with ``self_us``: its duration minus the
    duration of child spans nested within it on the same (pid, tid) track.
    Spans are properly nested per track (begin/end discipline), so a scan
    with a stack suffices."""
    by_track: Dict[tuple, List[dict]] = defaultdict(list)
    for ev in spans:
        by_track[(ev.get("pid", 0), ev.get("tid", 0))].append(ev)
    for track in by_track.values():
        # outer spans first at equal start time
        track.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        stack: List[dict] = []
        for ev in track:
            ev["self_us"] = ev.get("dur", 0.0)
            end = ev["ts"] + ev.get("dur", 0.0)
            while stack and ev["ts"] >= stack[-1]["_end"] - 1e-9:
                stack.pop()
            if stack:
                stack[-1]["self_us"] -= ev.get("dur", 0.0)
            ev["_end"] = end
            stack.append(ev)
    return spans


def analyze(payload: dict) -> dict:
    """Fold one trace payload into the attribution structure (one entry per
    pid/run)."""
    events = payload.get("traceEvents", payload if isinstance(payload, list)
                         else [])
    runs_meta = (payload.get("otherData", {}).get("runs", [])
                 if isinstance(payload, dict) else [])
    by_pid: Dict[int, List[dict]] = defaultdict(list)
    for ev in events:
        by_pid[ev.get("pid", 0)].append(ev)

    out_runs = []
    for pid in sorted(by_pid):
        evs = by_pid[pid]
        spans = _self_times([e for e in evs if e.get("ph") == "X"])
        categories: Dict[str, float] = defaultdict(float)
        components: Dict[str, dict] = {}
        waits: Dict[str, float] = defaultdict(float)
        transfers: Dict[str, dict] = {}
        counts: Dict[str, int] = defaultdict(int)
        wall_us = 0.0
        for ev in spans:
            cat = ev.get("cat", "")
            cls = _CATEGORY_CLASS.get(cat)
            if cls:
                categories[cls] += max(ev["self_us"], 0.0)
            if cat == "phase" and ev["name"] == "execute":
                wall_us = max(wall_us, ev.get("dur", 0.0))
            if cat in ("compute", "kernel"):
                args = ev.get("args") or {}
                name = args.get("component", ev["name"])
                c = components.setdefault(
                    name, {"compute_us": 0.0, "kernel_us": 0.0,
                           "calls": 0, "rows_in": 0})
                if cat == "kernel":
                    c["kernel_us"] += ev.get("dur", 0.0)
                else:
                    c["compute_us"] += max(ev["self_us"], 0.0)
                    c["calls"] += 1
                    c["rows_in"] += int(args.get("rows_in",
                                                 args.get("rows", 0)) or 0)
            elif cat == "wait":
                waits[ev["name"]] += ev.get("dur", 0.0)
            elif cat == "transfer":
                t = transfers.setdefault(ev["name"],
                                         {"count": 0, "bytes": 0, "us": 0.0})
                t["count"] += 1
                t["bytes"] += int((ev.get("args") or {}).get("bytes", 0))
                t["us"] += ev.get("dur", 0.0)
        for ev in evs:
            if ev.get("ph") == "i":
                counts[f"{ev.get('cat')}.{ev.get('name')}"] += 1
        meta = runs_meta[pid - 1] if 0 < pid <= len(runs_meta) else {}
        out_runs.append({
            "pid": pid, "meta": meta, "wall_us": wall_us,
            "categories": dict(categories),
            "components": components,
            "waits": dict(waits),
            "transfers": transfers,
            "instants": dict(counts),
        })
    return {"runs": out_runs}


def _fmt_us(us: float) -> str:
    return f"{us / 1e3:10.2f}ms"


def render(result: dict) -> str:
    lines: List[str] = []
    for run in result["runs"]:
        meta = run["meta"]
        label = meta.get("flow", f"run {run['pid']}")
        detail = "/".join(str(meta[k]) for k in ("engine", "backend")
                          if meta.get(k))
        rid = str(meta.get("run_id", ""))[:8]
        lines.append(f"== {label}" + (f" [{detail}]" if detail else "")
                     + (f" run_id={rid}" if rid else "") + " ==")
        cats = run["categories"]
        total = sum(cats.values()) or 1.0
        lines.append("  category        self-time      share")
        for cls in ("compute", "transfer", "wait", "overhead"):
            us = cats.get(cls, 0.0)
            lines.append(f"  {cls:<12}{_fmt_us(us)}   {us / total:7.1%}")
        if run["wall_us"]:
            lines.append(f"  execute-phase wall: {run['wall_us'] / 1e3:.2f}ms")
        if run["components"]:
            lines.append("  component                          compute"
                         "       kernel   calls     rows_in")
            for name, c in sorted(run["components"].items(),
                                  key=lambda kv: -kv[1]["compute_us"]):
                lines.append(
                    f"  {name[:32]:<32}{_fmt_us(c['compute_us'])}"
                    f" {_fmt_us(c['kernel_us'])}"
                    f"  {c['calls']:6d}  {c['rows_in']:10d}")
        if run["waits"]:
            lines.append("  wait site                blocked")
            for name, us in sorted(run["waits"].items(), key=lambda kv: -kv[1]):
                lines.append(f"  {name:<22}{_fmt_us(us)}")
        if run["transfers"]:
            lines.append("  transfer   count        bytes         time")
            for name, t in sorted(run["transfers"].items()):
                lines.append(f"  {name:<8}{t['count']:8d} {t['bytes']:12d}"
                             f" {_fmt_us(t['us'])}")
        if run["instants"]:
            inst = ", ".join(f"{k}={v}" for k, v in
                             sorted(run["instants"].items()))
            lines.append(f"  instants: {inst}")
        lines.append("")
    return "\n".join(lines)


def main(argv: List[str] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    as_json = "--json" in args
    paths = [a for a in args if a != "--json"]
    if len(paths) != 1:
        print("usage: python -m repro.obs.report <trace.json> [--json]")
        return 2
    with open(paths[0]) as f:
        payload = json.load(f)
    result = analyze(payload)
    if not result["runs"]:
        print(f"report: no trace events in {paths[0]}")
        return 1
    print(json.dumps(result, indent=2) if as_json else render(result))
    return 0


if __name__ == "__main__":
    sys.exit(main())
