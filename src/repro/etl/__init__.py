from .components import (Aggregate, ArraySource, CollectSink, Converter,
                         DimTable, Expression, FileSink, Filter,
                         FusedExpression, FusedSegment, Lookup, Merge,
                         Project, Sort, Splitter, Union)
from .kettle import KettleEngine
from .queries import BUILDERS, QueryFlow, build_q1, build_q2, build_q3, build_q4
from .ssb import SSBData, generate, mfgr_id, region_id

__all__ = [
    "Aggregate", "ArraySource", "CollectSink", "Converter", "DimTable",
    "Expression", "FileSink", "Filter", "FusedExpression", "FusedSegment",
    "Lookup", "Merge", "Project", "Sort",
    "Splitter", "Union", "KettleEngine", "BUILDERS", "QueryFlow",
    "build_q1", "build_q2", "build_q3", "build_q4",
    "SSBData", "generate", "mfgr_id", "region_id",
]
