"""Star Schema Benchmark (SSB) data generator — the paper's evaluation
workload (§5): fact table `lineorder` + dimensions `customer`, `supplier`,
`part`, `date`.

Categorical attributes are dictionary-encoded int columns (columnar form);
the string dictionaries are exported so queries can reference values like
'AMERICA' or 'MFGR#1' symbolically.  All keys are dense (1..N), which lets
the *independent* query oracles in queries.py use direct array indexing
rather than the DimTable searchsorted path used by the dataflow engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

REGIONS = ["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"]
N_NATIONS = 25                       # nation i belongs to region i % 5
MFGRS = [f"MFGR#{i}" for i in range(1, 6)]
N_CATEGORIES = 25                    # category i belongs to mfgr i // 5
N_BRANDS = 1000                      # brand i belongs to category i // 40
YEARS = list(range(1992, 1999))


def region_of_nation(nation: np.ndarray) -> np.ndarray:
    return nation % 5


def mfgr_of_category(category: np.ndarray) -> np.ndarray:
    return category // 5


def category_of_brand(brand: np.ndarray) -> np.ndarray:
    return brand // 40


@dataclass
class SSBData:
    customer: Dict[str, np.ndarray]
    supplier: Dict[str, np.ndarray]
    part: Dict[str, np.ndarray]
    date: Dict[str, np.ndarray]
    lineorder: Dict[str, np.ndarray]

    def nbytes(self) -> int:
        return sum(sum(v.nbytes for v in t.values())
                   for t in (self.customer, self.supplier, self.part,
                             self.date, self.lineorder))


def generate(lineorder_rows: int = 1_000_000,
             customers: int = 30_000,
             suppliers: int = 2_000,
             parts: int = 20_000,
             seed: int = 42) -> SSBData:
    """Generate SSB tables.  Default sizes give a ~60MB fact table; scale
    ``lineorder_rows`` up for the paper's GB-scale runs."""
    rng = np.random.default_rng(seed)

    c_nation = rng.integers(0, N_NATIONS, customers)
    customer = {
        "c_custkey": np.arange(1, customers + 1, dtype=np.int64),
        "c_nation": c_nation.astype(np.int64),
        "c_region": region_of_nation(c_nation).astype(np.int64),
        "c_city": (c_nation * 10 + rng.integers(0, 10, customers)).astype(np.int64),
    }

    s_nation = rng.integers(0, N_NATIONS, suppliers)
    supplier = {
        "s_suppkey": np.arange(1, suppliers + 1, dtype=np.int64),
        "s_nation": s_nation.astype(np.int64),
        "s_region": region_of_nation(s_nation).astype(np.int64),
        "s_city": (s_nation * 10 + rng.integers(0, 10, suppliers)).astype(np.int64),
    }

    p_brand = rng.integers(0, N_BRANDS, parts)
    p_category = category_of_brand(p_brand)
    part = {
        "p_partkey": np.arange(1, parts + 1, dtype=np.int64),
        "p_brand1": p_brand.astype(np.int64),
        "p_category": p_category.astype(np.int64),
        "p_mfgr": mfgr_of_category(p_category).astype(np.int64),
    }

    # 7 years x 365 days
    n_days = len(YEARS) * 365
    day_of_year = np.tile(np.arange(1, 366), len(YEARS))
    year = np.repeat(np.array(YEARS, dtype=np.int64), 365)
    month = np.minimum((day_of_year - 1) // 31 + 1, 12)
    date = {
        "d_datekey": (year * 10000 + month * 100
                      + ((day_of_year - 1) % 31 + 1)).astype(np.int64),
        "d_year": year,
        "d_yearmonthnum": (year * 100 + month).astype(np.int64),
        "d_weeknuminyear": ((day_of_year - 1) // 7 + 1).astype(np.int64),
    }

    n = lineorder_rows
    quantity = rng.integers(1, 51, n).astype(np.int64)
    extendedprice = rng.integers(90_000, 1_100_000, n).astype(np.int64)
    discount = rng.integers(0, 11, n).astype(np.int64)
    revenue = (extendedprice * (100 - discount) // 100).astype(np.int64)
    lineorder = {
        "lo_orderkey": np.arange(1, n + 1, dtype=np.int64),
        "lo_custkey": rng.integers(1, customers + 1, n).astype(np.int64),
        "lo_suppkey": rng.integers(1, suppliers + 1, n).astype(np.int64),
        "lo_partkey": rng.integers(1, parts + 1, n).astype(np.int64),
        "lo_orderdate": date["d_datekey"][rng.integers(0, n_days, n)],
        "lo_quantity": quantity,
        "lo_discount": discount,
        "lo_extendedprice": extendedprice,
        "lo_revenue": revenue,
        "lo_supplycost": rng.integers(40_000, 60_000, n).astype(np.int64),
    }
    return SSBData(customer=customer, supplier=supplier, part=part,
                   date=date, lineorder=lineorder)


def region_id(name: str) -> int:
    return REGIONS.index(name)


def mfgr_id(name: str) -> int:
    return MFGRS.index(name)
