"""A Kettle-like (Pentaho PDI) baseline engine — the paper's §5.2 comparison.

Kettle's architecture: every step (component) runs in its own thread,
connected by bounded row-set buffers; rows are COPIED between steps (separate
output/input caches — no shared caching), and steps optionally run multiple
internal worker threads.  This engine mirrors that: one thread per component,
a bounded queue per component, a physical copy on every hop, and optional
inside-component multithreading — but NO execution-tree partitioning, NO
shared caching and NO Theorem-1 pipeline planning.
"""
from __future__ import annotations

import contextvars
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.component import ComponentType, SourceComponent
from ..core.engine import EngineRun, _finish_obs, _run_counters
from ..core.graph import Dataflow
from ..core.shared_cache import SharedCache, cache_stats_scope, record_copy
from ..obs import trace as obs_trace

_EOS = object()


class KettleEngine:
    def __init__(self, flow: Dataflow, chunk_rows: int = 65536,
                 queue_caches: int = 4,
                 mt_threads: Optional[Dict[str, int]] = None,
                 backend: Optional[str] = None):
        self.flow = flow
        self.chunk_rows = chunk_rows
        self.queue_caches = queue_caches
        self.mt_threads = mt_threads or {}
        self.backend = backend      # None => REPRO_BACKEND env / "numpy"

    def run(self) -> EngineRun:
        from ..core.backend import resolve_backend
        flow = self.flow
        flow.validate()
        flow.reset_stats()
        bk = resolve_backend(self.backend)
        for comp in flow.vertices.values():
            comp.backend = bk
        inqs: Dict[str, "queue.Queue"] = {
            n: queue.Queue(maxsize=self.queue_caches) for n in flow.vertices}
        errors: List[BaseException] = []
        mt_max = max([1] + list(self.mt_threads.values()))
        pool = ThreadPoolExecutor(max_workers=mt_max) if mt_max > 1 else None

        def route(name: str, outs: List[SharedCache], split_index: int) -> None:
            succs = flow.succ(name)
            per_port = len(outs) == len(succs) and len(outs) > 1
            for i, u in enumerate(succs):
                out = outs[i] if per_port else outs[0]
                copied = out.copy()               # rowset hop = physical copy
                record_copy(out)
                copied.split_index = split_index
                inqs[u].put(copied)

        def route_eos(name: str) -> None:
            for u in flow.succ(name):
                inqs[u].put(_EOS)

        def process_one(comp, cache: SharedCache) -> List[SharedCache]:
            t = self.mt_threads.get(comp.name, 1)
            if (t > 1 and comp.supports_multithreading and pool is not None
                    and cache.n > t):
                t0 = time.perf_counter()
                ranges = cache.row_ranges(t)
                futs = [pool.submit(comp.process_range, cache, r)
                        for r in ranges]
                parts = [f.result() for f in futs]
                outs = comp.merge_ranges(cache, ranges, parts)
                t1 = time.perf_counter()
                comp.busy_time += t1 - t0
                comp.calls += 1
                if obs_trace.ACTIVE.get():
                    obs_trace.on_dispatch(comp.name, t0, t1,
                                          cache.split_index, cache.n,
                                          sum(c.n for c in outs),
                                          mt=len(ranges))
                return outs
            return comp.process(cache, shared=True)

        def step_thread(name: str) -> None:
            comp = flow.component(name)
            try:
                if isinstance(comp, SourceComponent):
                    for i, chunk in enumerate(comp.chunks(self.chunk_rows)):
                        route(name, [chunk], i)
                    route_eos(name)
                    return
                eos_needed = flow.in_degree(name)
                eos_seen = 0
                is_block = comp.ctype in (ComponentType.BLOCK,
                                          ComponentType.SEMI_BLOCK)
                state = comp.new_state() if is_block else None
                while eos_seen < eos_needed:
                    item = inqs[name].get()
                    if item is _EOS:
                        eos_seen += 1
                        continue
                    if is_block:
                        comp.accumulate(state, item)
                    else:
                        outs = process_one(comp, item)
                        route(name, outs, item.split_index)
                if is_block:
                    # deterministic accumulation order
                    state.sort(key=lambda c: c.split_index)
                    out = comp.finish(state)
                    route(name, [out], 0)
                route_eos(name)
            except BaseException as e:  # noqa: BLE001
                errors.append(e)
                route_eos(name)

        with obs_trace.run_scope(flow=flow.name, engine="kettle",
                                 backend=bk.name) as tracer:
            t_start = time.perf_counter()
            with cache_stats_scope() as stats, obs_trace.measured(tracer), \
                    obs_trace.span("phase", "execute"):
                # raw step threads do not inherit contextvars: run each under
                # a context captured INSIDE the scope so the per-run
                # collectors (cache stats AND tracer) see every hop copy
                ctx = contextvars.copy_context()
                threads = [threading.Thread(
                    target=lambda n=n: ctx.copy().run(step_thread, n),
                    daemon=True, name=f"kettle-{n}")
                    for n in flow.topo_order()]
                for th in threads:
                    th.start()
                for th in threads:
                    th.join()
                if pool is not None:
                    pool.shutdown()
            wall = time.perf_counter() - t_start
            if errors:
                raise errors[0]
            run = EngineRun(
                wall_time=wall, copies=0, bytes_copied=0,
                engine="kettle",
                backend=bk.name,
                dispatch_calls=sum(c.calls for c in flow.vertices.values()),
                activity_times={n: c.busy_time
                                for n, c in flow.vertices.items()})
            _run_counters(run, stats.snapshot())
            _finish_obs(tracer, run)
        return run
