"""ETL component library over columnar numpy row sets.

Component classification follows the paper's §3:
  row-synchronized: Filter, Lookup, Project, Expression, Converter, Splitter
  block:            Aggregate, Sort
  semi-block:       Union, Merge
  plus ArraySource / CollectSink / FileSink.

Row-synchronized components mutate the shared cache IN PLACE (shared caching
scheme).  Heavy row-synchronized components (Filter/Lookup/Expression)
implement `process_range` + `merge_ranges` for §4.3 inside-component
multithreading with a row-order synchronizer.

Heavy components do not inline their kernels: they dispatch through the
active operator backend (``core/backend/``) — ``numpy`` reference or ``jax``
accelerated — via ``Component.get_backend()``.  Engines assign the run's
backend on every component before executing.

Predicates and derived-column expressions are preferably **column-expression
AST nodes** (``core/expr.py``): their read sets are derived from the AST, so
the optimizer's commute/fusion rules and the fused-kernel upload sets get
exact provenance.  Legacy ``fn(cache, rows)`` callables still work as a
deprecated shim — without a ``reads=`` declaration they emit a
``DeprecationWarning`` and opt out of every provenance-driven rewrite.
"""
from __future__ import annotations

import threading
import time
import warnings
from typing import (Callable, Dict, FrozenSet, Iterator, List, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from ..core import config, faults
from ..core.backend import AGG_OPS, SEGMENT_KEEP_MASK
from ..core.component import (BlockComponent, Component, ComponentType,
                              SemiBlockComponent, SinkComponent,
                              SourceComponent)
from ..core.expr import Col, Expr, expr_reads
from ..core.shared_cache import GLOBAL_ARENA, SharedCache, concat_caches
from ..obs import trace as obs_trace

ColumnRef = Union[str, Col]


def _col_name(ref: ColumnRef) -> str:
    """Column arguments accept a plain name or a DSL ``col()`` reference.
    Composite expressions are rejected — materialize them with an
    ``Expression`` (``FlowBuilder.derive``) first."""
    if isinstance(ref, Col):
        return ref.name
    if isinstance(ref, Expr):
        raise TypeError(
            f"{ref!r} is a composite expression; only bare col() references "
            f"name a column here — derive() it into a column first")
    if isinstance(ref, str):
        return ref
    raise TypeError(f"expected a column name or col() reference, got {ref!r}")


def _resolve_reads(fn, reads: Optional[Sequence[str]], owner: str,
                   kind: str) -> Optional[FrozenSet[str]]:
    """The declared read set of a predicate/expression.

    DSL ``Expr`` nodes derive it exactly from the AST (a conflicting manual
    ``reads=`` raises — the declaration would otherwise silently drift from
    the truth).  Legacy callables keep their hand-declared ``reads=``; a
    callable WITHOUT one gets a ``DeprecationWarning`` naming the DSL
    replacement, because ``None`` silently opts the component out of
    filter-commute, segment fusion and the minimal device upload set."""
    if isinstance(fn, Expr):
        derived = expr_reads(fn)
        if reads is not None and frozenset(reads) != derived:
            raise ValueError(
                f"{kind} {owner!r}: reads={sorted(reads)} conflicts with the "
                f"expression's derived read set {sorted(derived)} — drop the "
                f"reads= argument (provenance is derived from the AST)")
        return derived
    if reads is None:
        warnings.warn(
            f"{kind} {owner!r}: opaque callable without reads= — the "
            f"optimizer and fused kernels cannot see its column provenance, "
            f"so every provenance-driven rewrite refuses.  Build the "
            f"predicate/expression with the repro.col() DSL (exact derived "
            f"reads), or declare reads= explicitly.",
            DeprecationWarning, stacklevel=3)
        return None
    return frozenset(reads)


# ---------------------------------------------------------------------------
#  Sources
# ---------------------------------------------------------------------------
class ArraySource(SourceComponent):
    """In-memory columnar table source; yields chunked caches (views)."""

    def __init__(self, name: str, columns: Dict[str, np.ndarray]):
        super().__init__(name)
        lens = {len(v) for v in columns.values()}
        if len(lens) > 1:
            raise ValueError("ragged source columns")
        self.columns = columns
        self._n = lens.pop() if lens else 0

    def total_rows(self) -> int:
        return self._n

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return frozenset(self.columns)

    def est_output_bytes(self) -> int:
        """Cache-size metadata for the runtime planner (channel sizing),
        computed with the active backend's dtype widths so the estimate stays
        correct when columns live on device (e.g. 64-bit host columns
        canonicalized to 32-bit jax arrays)."""
        return self.get_backend().est_nbytes(self.columns)

    def set_data(self, columns: Dict[str, np.ndarray]) -> None:
        """Swap the table this source emits — the serving loop's feed point.
        The column SET must match the original schema (runtime plans and
        compiled segment kernels are built against it); the row count may
        change freely between ticks."""
        if set(columns) != set(self.columns):
            missing = sorted(set(self.columns) - set(columns))
            extra = sorted(set(columns) - set(self.columns))
            raise ValueError(
                f"source {self.name!r}: tick columns do not match the "
                f"declared schema (missing {missing}, unexpected {extra})")
        lens = {len(v) for v in columns.values()}
        if len(lens) > 1:
            raise ValueError("ragged source columns")
        self.columns = dict(columns)
        self._n = lens.pop() if lens else 0

    def chunks(self, chunk_rows: int) -> Iterator[SharedCache]:
        i = 0
        idx = 0
        while i < self._n:
            j = min(i + chunk_rows, self._n)
            # a chunk view is the root output split; downstream mutators
            # compact/overwrite in place, so materialize the chunk buffer
            # once — drawn from the CacheArena, so the steady state of a
            # chunked run recycles the same few buffers (zero per-chunk
            # allocation) once the executor returns consumed splits
            cols: Dict[str, np.ndarray] = {}
            owned = []
            for k, v in self.columns.items():
                arr, root = GLOBAL_ARENA.acquire_copy(v[i:j])
                cols[k] = arr
                if root is not None:
                    owned.append(root)
            cache = SharedCache(cols, j - i, split_index=idx)
            cache._owned = owned or None
            self.rows_out += j - i
            yield cache
            i = j
            idx += 1


# ---------------------------------------------------------------------------
#  Row-synchronized components
# ---------------------------------------------------------------------------
class RowSyncMT(Component):
    """Base for row-sync components with §4.3 multithreading support."""

    supports_multithreading = True

    def _run(self, cache: SharedCache) -> List[SharedCache]:
        full = slice(0, cache.n)
        part = self.process_range(cache, full)
        return self.merge_ranges(cache, [full], [part])

    # subclasses implement process_range(cache, rows) -> dict and
    # merge_ranges(cache, ranges, parts) -> [cache]


class Filter(RowSyncMT):
    """Keep rows where predicate(cache, rows) is True.  In-place compaction.

    The predicate is preferably a DSL expression
    (``col("lo_quantity") < 25``) — its read set is then derived exactly
    from the AST.  Legacy callables may declare ``reads=`` by hand; the
    cost-based optimizer commutes this filter ahead of adjacent
    row-preserving components only when the read set is known and disjoint
    from the neighbour's outputs, so an undeclared (None) read set refuses
    every commute."""

    def __init__(self, name: str,
                 predicate: Union[Expr, Callable[[SharedCache, slice],
                                                 np.ndarray]],
                 reads: Optional[Sequence[str]] = None):
        super().__init__(name)
        if isinstance(predicate, Expr) and not predicate.columns():
            raise ValueError(
                f"Filter {name!r}: predicate {predicate!r} reads no columns "
                f"— a constant predicate either keeps or drops every row")
        self.predicate = predicate
        self.reads = _resolve_reads(predicate, reads, name, "Filter")

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return incols

    def produced_columns(self) -> frozenset:
        return frozenset()          # drops rows, never adds columns

    def consumed_columns(self) -> Optional[frozenset]:
        return self.reads

    def segment_ops(self) -> list:
        return [("filter", self.predicate, self.reads)]

    def process_range(self, cache: SharedCache, rows: slice) -> dict:
        return {"__mask__": self.get_backend().filter_mask(self.predicate,
                                                           cache, rows)}

    def merge_ranges(self, cache: SharedCache, ranges: List[slice],
                     parts: List[dict]) -> List[SharedCache]:
        mask = self.get_backend().concat([p["__mask__"] for p in parts])
        cache.compact(mask)          # row order preserved (synchronizer)
        return [cache]


class DimTable:
    """Dimension table for Lookup: key -> payload columns, vectorized via
    sorted keys + searchsorted.  ``row_filter`` marks non-qualifying dim rows
    as unmatched at build time (the paper's `AND c_region='AMERICA'` style
    join conditions)."""

    def __init__(self, key: np.ndarray, payload: Dict[str, np.ndarray],
                 row_filter: Optional[np.ndarray] = None):
        order = np.argsort(key, kind="stable")
        self.keys = np.asarray(key)[order]
        self.payload = {k: np.asarray(v)[order] for k, v in payload.items()}
        if row_filter is not None:
            self.qualifies = np.asarray(row_filter, dtype=bool)[order]
        else:
            self.qualifies = np.ones(len(self.keys), dtype=bool)

    def probe(self, vals: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (row_idx, matched_mask)."""
        idx = np.searchsorted(self.keys, vals)
        idx = np.clip(idx, 0, max(len(self.keys) - 1, 0))
        matched = (self.keys[idx] == vals) & self.qualifies[idx] \
            if len(self.keys) else np.zeros(len(vals), dtype=bool)
        return idx, matched

    def __getstate__(self):
        # the jax backend caches device arrays on the instance; they don't
        # pickle (process shard route) and rebuild lazily in the worker
        state = dict(self.__dict__)
        state.pop("_jax_device_cache", None)
        state.pop("_jax_hash_cache", None)
        return state


class Lookup(RowSyncMT):
    """Join with a dimension table; unmatched rows get ``default`` (-1) in
    every returned column — downstream Filter drops them (paper §5.1)."""

    row_preserving = True

    def __init__(self, name: str, dim: DimTable, key_col: ColumnRef,
                 return_cols: Dict[str, str], default: int = -1,
                 matched_flag: Optional[str] = None):
        super().__init__(name)
        self.dim = dim
        self.key_col = _col_name(key_col)
        self.return_cols = return_cols       # out_name -> dim payload col
        self.default = default
        self.matched_flag = matched_flag     # optional bool col with match bit

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return incols | self.produced_columns()

    def produced_columns(self) -> frozenset:
        out = set(self.return_cols)
        if self.matched_flag:
            out.add(self.matched_flag)
        return frozenset(out)

    def consumed_columns(self) -> frozenset:
        return frozenset({self.key_col})

    def segment_ops(self) -> list:
        return [("lookup", self.dim, self.key_col, dict(self.return_cols),
                 self.default, self.matched_flag)]

    def process_range(self, cache: SharedCache, rows: slice) -> dict:
        bk = self.get_backend()
        vals = cache.col(self.key_col)[rows]
        idx, matched = bk.searchsorted_probe(self.dim, vals)
        out: Dict[str, np.ndarray] = {}
        for out_name, dim_col in self.return_cols.items():
            out[out_name] = bk.lookup_gather(self.dim, dim_col, idx, matched,
                                             self.default)
        if self.matched_flag:
            out[self.matched_flag] = matched
        return out

    def merge_ranges(self, cache: SharedCache, ranges: List[slice],
                     parts: List[dict]) -> List[SharedCache]:
        bk = self.get_backend()
        names = parts[0].keys()
        for name in names:                     # merge in input-range order
            cache.add_column(name, bk.concat([p[name] for p in parts]))
        return [cache]


class Expression(RowSyncMT):
    """Compute a new column from existing ones (paper's component 8).

    ``fn`` is preferably a DSL expression (``col("a") * col("b")``) whose
    read set is derived from the AST; legacy callables may declare
    ``reads=`` by hand — provenance metadata for the cost-based optimizer's
    commute/fusion rules and the fused-kernel upload sets."""

    row_preserving = True

    def __init__(self, name: str, out_col: str,
                 fn: Union[Expr, Callable[[SharedCache, slice], np.ndarray]],
                 reads: Optional[Sequence[str]] = None):
        super().__init__(name)
        if isinstance(fn, Expr) and not fn.columns():
            raise ValueError(
                f"Expression {name!r}: {fn!r} reads no columns — a scalar "
                f"constant is not a per-row column (it would crash at "
                f"merge time); derive it from a real column, e.g. "
                f"col(x) * 0 + value")
        self.out_col = _col_name(out_col)
        self.fn = fn
        self.reads = _resolve_reads(fn, reads, name, "Expression")

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return incols | {self.out_col}

    def produced_columns(self) -> frozenset:
        return frozenset({self.out_col})

    def consumed_columns(self) -> Optional[frozenset]:
        return self.reads

    def segment_ops(self) -> list:
        return [("expr", self.out_col, self.fn, self.reads)]

    def process_range(self, cache: SharedCache, rows: slice) -> dict:
        return {self.out_col: self.get_backend().eval_expression(self.fn,
                                                                 cache, rows)}

    def merge_ranges(self, cache: SharedCache, ranges: List[slice],
                     parts: List[dict]) -> List[SharedCache]:
        cache.add_column(self.out_col, self.get_backend().concat(
            [p[self.out_col] for p in parts]))
        return [cache]


class FusedExpression(Component):
    """Several Expression activities collapsed into ONE pipeline activity by
    the cost-based optimizer (expression fusion).  The sub-expressions run
    sequentially against the shared cache, each output column visible to the
    next — identical results, one activity's worth of per-split overhead
    (the t0 of Theorem 1) instead of several."""

    row_preserving = True

    def __init__(self, name: str,
                 exprs: Sequence[Tuple[str, Callable]],
                 reads: Optional[frozenset] = None):
        super().__init__(name)
        self.exprs = list(exprs)             # [(out_col, fn), ...] in order
        self.reads = reads                   # None => unknown

    @classmethod
    def fuse(cls, a: Component, b: Component) -> "FusedExpression":
        """Fuse two adjacent Expression / FusedExpression components
        (``a`` upstream of ``b``), combining their provenance."""
        def parts(c):
            return c.exprs if isinstance(c, FusedExpression) \
                else [(c.out_col, c.fn)]
        reads = None
        ra, rb = a.consumed_columns(), b.consumed_columns()
        if ra is not None and rb is not None:
            # b's reads of a's outputs are internal to the fused activity
            reads = ra | (rb - a.produced_columns())
        return cls(f"fused({a.name}+{b.name})", parts(a) + parts(b),
                   reads=reads)

    def produced_columns(self) -> frozenset:
        return frozenset(out for out, _ in self.exprs)

    def consumed_columns(self) -> Optional[frozenset]:
        return self.reads

    def segment_ops(self) -> list:
        # DSL sub-expressions carry their exact per-op read sets; legacy
        # callables fall back to the combined external read set (self.reads,
        # None => unknown), which over-approximates each of them
        return [("expr", out_col, fn,
                 fn.columns() if isinstance(fn, Expr) else self.reads)
                for out_col, fn in self.exprs]

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return incols | self.produced_columns()

    def _run(self, cache: SharedCache) -> List[SharedCache]:
        bk = self.get_backend()
        for out_col, fn in self.exprs:
            cache.add_column(out_col,
                             bk.eval_expression(fn, cache, slice(0, cache.n)))
        return [cache]


class FusedSegment(Component):
    """A maximal row-synchronized chain (Filter / Expression / Lookup /
    Project / Converter and fused combinations) collapsed into ONE pipeline
    activity by segment fusion (core/planner.discover_segments +
    core/optimizer.fuse_segments_flow).

    The whole segment executes as a SINGLE backend dispatch per chunk via
    ``Backend.compile_segment``: the numpy backend composes the ops into one
    vectorized host pass (bit-identical to the unfused chain), the jax
    backend jits the segment into one device kernel (one h2d in, one d2h out
    per chunk).  Ops are declarative tuples (see each component's
    ``segment_ops``):

        ("filter",  predicate, reads_or_None)
        ("expr",    out_col, fn, reads_or_None)
        ("lookup",  dim, key_col, return_cols, default, matched_flag)
        ("project", keep_tuple)
        ("convert", conversions_dict)

    CONTRACT: members must be row-local (each output row a function of its
    own input row only) — exactly the paper's §3 row-synchronized
    classification.  The compiled runner is cached per backend on the
    component, so tracing/composition happens once per run."""

    def __init__(self, name: str, ops: Sequence[tuple],
                 members: Optional[Sequence[str]] = None,
                 produced: Optional[frozenset] = None,
                 consumed: Optional[frozenset] = None,
                 row_pres: bool = False):
        super().__init__(name)
        self.ops = list(ops)
        self.members = list(members or [])
        self._produced = produced
        self._consumed = consumed
        self.row_preserving = row_pres
        self._compiled: Dict[str, Callable] = {}
        #: mask deferral (set by the optimizer's fuse-segment-aggregate
        #: rewrite): columns the terminal Aggregate consumes / its name.
        #: Backends with ``supports_segment_defer`` then skip the per-chunk
        #: compact and emit the keep-mask as a SEGMENT_KEEP_MASK column.
        self.defer_cols: Optional[frozenset] = None
        self.defer_to: Optional[str] = None

    # compiled runners are per-process (process shard route); rebuilt lazily
    _UNPICKLABLE = Component._UNPICKLABLE + ("_compiled",)

    def __setstate__(self, state):
        super().__setstate__(state)
        self._compiled = {}

    @classmethod
    def from_components(cls, comps: Sequence[Component]) -> "FusedSegment":
        """Fuse an ordered chain of fusable components, combining their ops
        and provenance.  Raises ``ValueError`` on a non-fusable member."""
        ops: List[tuple] = []
        produced: Optional[set] = set()
        consumed: Optional[set] = set()
        for c in comps:
            sub = c.segment_ops()
            if sub is None:
                raise ValueError(f"component {c.name!r} ({type(c).__name__}) "
                                 f"cannot join a fused segment")
            ops.extend(sub)
            r = c.consumed_columns()
            p = c.produced_columns()
            if consumed is not None:
                # reads of columns produced EARLIER in the segment are
                # internal; unknown reads (or unknown prior writes) poison
                # the whole declared set
                consumed = (None if r is None or produced is None
                            else consumed | (r - produced))
            if produced is not None:
                produced = None if p is None else produced | p
        name = f"fusedseg({'+'.join(c.name for c in comps)})"
        return cls(name, ops, members=[c.name for c in comps],
                   produced=None if produced is None else frozenset(produced),
                   consumed=None if consumed is None else frozenset(consumed),
                   row_pres=all(c.row_preserving for c in comps))

    def produced_columns(self) -> Optional[frozenset]:
        return self._produced

    def consumed_columns(self) -> Optional[frozenset]:
        return self._consumed

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        from ..core.backend.base import segment_final_live
        return frozenset(segment_final_live(self.ops, incols))

    def kernel_input_columns(self) -> Optional[frozenset]:
        """External columns the segment's compute ops read (the upload set
        for device backends); ``None`` when some op's read set is undeclared
        — the backend then feeds every cache column to the kernel."""
        needed: set = set()
        produced: set = set()
        for op in self.ops:
            kind = op[0]
            if kind == "filter":
                if op[2] is None:
                    return None
                needed |= op[2] - produced
            elif kind == "expr":
                if op[3] is None:
                    return None
                needed |= op[3] - produced
                produced.add(op[1])
            elif kind == "lookup":
                needed |= {op[2]} - produced
                produced.update(op[3])
                if op[5]:
                    produced.add(op[5])
            elif kind == "convert":
                needed |= set(op[1]) - produced
                produced.update(op[1])
            # project: metadata-only, nothing to upload
        return frozenset(needed)

    def defer_mask_to(self, agg: "Aggregate") -> None:
        """Mark this segment as fused through its terminal ``Aggregate``:
        deferral-capable backends keep the chunk uncompacted (device-resident,
        no per-chunk d2h mask sync) and ``agg.finish`` applies the combined
        keep-mask once after the merge.  Host backends ignore the marking —
        their eager compact is free and byte-identical."""
        self.defer_cols = frozenset(agg.consumed_columns())
        self.defer_to = agg.name
        self._compiled.clear()        # runners bake in the deferral mode

    def spec(self) -> Dict[str, str]:
        out = super().spec()
        out["members"] = ",".join(self.members)
        if self.defer_to:
            out["defer_mask_to"] = self.defer_to
        return out

    def _dispatch(self, bk, cache: SharedCache) -> None:
        """One compiled-segment dispatch with the kernel degradation ladder:
        a non-transient, non-injected failure of the compiled runner falls
        back to the backend-agnostic host reference pass
        (``Backend.compile_segment`` base implementation, bit-identical to
        the unfused chain) and the fallback sticks for the rest of the
        component's life — later chunks skip the broken kernel.  Transient
        faults escalate unchanged (chunk-level replay retries them);
        explicitly injected permanent/poison faults abort promptly.

        The pre-dispatch snapshot is taken only under active fault
        injection: real kernel failures surface at compile/trace/dispatch
        time, before the runner's write-back mutates the cache."""
        runner = self._compiled.get(bk.name)
        if runner is None:
            runner = self._compiled[bk.name] = bk.compile_segment(self)
        snap = faults.snapshot_cache(cache) if faults.active() else None
        try:
            if snap is not None:
                faults.inject("kernel", component=self.name,
                              split=cache.split_index)
            runner(cache)
            return
        except BaseException as e:
            if (faults.classify(e) == "transient"
                    or isinstance(e, (faults.PermanentFault,
                                      faults.PoisonFault))
                    or not config.degrade_enabled()
                    or getattr(runner, "_is_reference", False)):
                raise
            from ..core.backend.base import Backend as _Base
            faults.record_degradation(
                "kernel", src=f"segment[{bk.name}]", dst="reference",
                component=self.name, error=repr(e))
            ref = _Base.compile_segment(bk, self)
            ref._is_reference = True
            self._compiled[bk.name] = ref
            if snap is not None:
                faults.restore_cache(cache, snap)
            ref(cache)

    def _run(self, cache: SharedCache) -> List[SharedCache]:
        bk = self.get_backend()
        if obs_trace.ACTIVE.get():
            n_in = cache.n
            t0 = time.perf_counter()
            self._dispatch(bk, cache)
            obs_trace.on_kernel(self.name, bk.name, t0, time.perf_counter(),
                                n_in)
        else:
            self._dispatch(bk, cache)
        return [cache]


class Project(Component):
    """Keep a subset of columns.  With the shared caching scheme this is a
    metadata-only operation (no rows move)."""

    row_preserving = True

    def __init__(self, name: str, keep: Sequence[ColumnRef]):
        super().__init__(name)
        self.keep = [_col_name(k) for k in keep]

    def produced_columns(self) -> frozenset:
        return frozenset()           # only removes columns

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return incols & frozenset(self.keep)

    def consumed_columns(self) -> frozenset:
        return frozenset(self.keep)

    def segment_ops(self) -> list:
        return [("project", tuple(self.keep))]

    def _run(self, cache: SharedCache) -> List[SharedCache]:
        cache.keep_columns(self.keep)
        return [cache]


class Converter(Component):
    """Data format converter (row-synchronized)."""

    row_preserving = True

    def __init__(self, name: str, conversions: Dict[str, np.dtype]):
        super().__init__(name)
        self.conversions = conversions

    def produced_columns(self) -> frozenset:
        # overwrites the converted columns: a filter reading them must NOT
        # hop this component (it would see the pre-conversion dtype)
        return frozenset(self.conversions)

    def consumed_columns(self) -> frozenset:
        return frozenset(self.conversions)

    def segment_ops(self) -> list:
        return [("convert", dict(self.conversions))]

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return incols

    def _run(self, cache: SharedCache) -> List[SharedCache]:
        for col, dt in self.conversions.items():
            # add_column (not a raw columns[] write) bumps cache.version so
            # backends drop any cached device view of the old column
            cache.add_column(col, cache.col(col).astype(dt))
        return [cache]


class Splitter(Component):
    """Route rows to two output ports by predicate (row-synchronized)."""

    def __init__(self, name: str,
                 predicate: Callable[[SharedCache, slice], np.ndarray]):
        super().__init__(name)
        self.predicate = predicate

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return incols            # routes rows; column set unchanged

    def _run(self, cache: SharedCache) -> List[SharedCache]:
        mask = np.asarray(self.predicate(cache, slice(0, cache.n)), dtype=bool)
        hi = SharedCache({k: cache.col(k)[mask] for k in cache.names},
                         int(mask.sum()), cache.split_index)
        lo = SharedCache({k: cache.col(k)[~mask] for k in cache.names},
                         int((~mask).sum()), cache.split_index)
        return [hi, lo]


# ---------------------------------------------------------------------------
#  Block components
# ---------------------------------------------------------------------------
class _AggServeState:
    """Cross-tick partial store for a serving-mode ``Aggregate``: per-group
    MERGEABLE partials (sum/min/max/count — ``avg`` is decomposed into a sum
    and a count and divided only at emit) kept as host scalars in their
    backend dtype, so merging a tick is the same dtype-preserving arithmetic
    the backend's one-shot reduce performs."""

    __slots__ = ("index", "keys", "partials")

    def __init__(self, partial_names: Sequence[str]):
        self.index: Dict[tuple, int] = {}      # group key tuple -> position
        self.keys: List[tuple] = []            # group key tuples, insertion order
        self.partials: Dict[str, list] = {p: [] for p in partial_names}


#: internal partial-name separator — ``\x00`` cannot appear in a user column
_PARTIAL_SEP = "\x00"


class Aggregate(BlockComponent):
    """Group-by aggregation — the paper's canonical block component
    (sum/avg/min/max).  Accumulates all input caches, then reduces.

    Serving mode (``begin_serving``/``end_serving``): ``finish`` becomes an
    incremental upsert instead of a one-shot block reduce — the tick's rows
    are reduced with the normal backend kernel, merged into a persistent
    per-group partial store, and the emitted cache is the DELTA: every group
    touched this tick with its current merged value (an upsert row retracts
    the group's previously emitted value)."""

    #: segment fusion may extend a row-sync chain through this component:
    #: the fused segment defers its keep-mask (no per-chunk d2h) and finish()
    #: applies it once to the merged cache before reducing
    segment_terminal_aggregate = True

    def __init__(self, name: str, group_by: Sequence[ColumnRef],
                 aggs: Dict[str, Tuple[ColumnRef, str]]):
        """``aggs``: out_col -> (in_col, op) with op in sum/avg/min/max/count.
        Column arguments accept plain names or DSL ``col()`` references."""
        super().__init__(name)
        self.group_by = [_col_name(g) for g in group_by]
        for out, (col, op) in aggs.items():
            if op not in AGG_OPS:     # same set every backend validates
                raise ValueError(f"unknown agg op {op!r}")
        self.aggs = {out: (_col_name(col), op)
                     for out, (col, op) in aggs.items()}
        self._serving: Optional[_AggServeState] = None

    def produced_columns(self) -> frozenset:
        return frozenset(self.group_by) | frozenset(self.aggs)

    def consumed_columns(self) -> frozenset:
        return frozenset(self.group_by) | frozenset(
            col for col, _ in self.aggs.values())

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        # aggregation REPLACES the schema: group keys + aggregate outputs
        return self.produced_columns()

    # ------------------------------------------------------------ serving
    def _partial_plan(self) -> Dict[str, Tuple[str, str]]:
        """Mergeable-partial spec for the serving tick reduce: partial name
        -> (input column, op).  ``avg`` is not mergeable and decomposes into
        a sum partial and a count partial (divided at emit); every other op
        merges with itself."""
        plan: Dict[str, Tuple[str, str]] = {}
        for out, (col, op) in self.aggs.items():
            if op == "avg":
                plan[out + _PARTIAL_SEP + "sum"] = (col, "sum")
                plan[out + _PARTIAL_SEP + "count"] = (col, "count")
            else:
                plan[out] = (col, op)
        return plan

    def begin_serving(self) -> None:
        """Enter serving mode with a fresh cross-tick partial store."""
        self._serving = _AggServeState(list(self._partial_plan()))

    def end_serving(self) -> None:
        """Leave serving mode and drop the partial store — the component is
        immediately reusable for ordinary batch runs."""
        self._serving = None

    def serving_snapshot(self):
        """Copy of the cross-tick partial store, taken before a tick
        attempt so a retried tick merges its rows exactly once (replaying
        into already-merged partials would double-count).  ``None`` outside
        serving mode."""
        st = self._serving
        if st is None:
            return None
        return (dict(st.index), list(st.keys),
                {p: list(v) for p, v in st.partials.items()})

    def serving_restore(self, snap) -> None:
        """Rewind the partial store to a ``serving_snapshot`` (no-op for
        ``None`` / outside serving mode)."""
        if self._serving is None or snap is None:
            return
        st = self._serving
        st.index = dict(snap[0])
        st.keys = list(snap[1])
        st.partials = {p: list(v) for p, v in snap[2].items()}

    def _serving_finish(self, merged: SharedCache) -> SharedCache:
        st = self._serving
        plan = self._partial_plan()
        n = merged.n
        if n == 0:
            # empty tick: nothing merges, the delta is empty (same dtype
            # conventions as the batch empty path)
            cols = {g: np.array([], dtype=np.int64) for g in self.group_by}
            for out in self.aggs:
                cols[out] = np.array([], dtype=np.float64)
            return SharedCache(cols, 0)
        bk = self.get_backend()
        group_cols, part_cols = bk.groupby_reduce(
            [merged.col(g) for g in self.group_by],
            {p: (merged.col(col), op) for p, (col, op) in plan.items()},
            n)
        group_h = [np.asarray(bk.to_host(c)) for c in group_cols]
        part_h = {p: np.asarray(bk.to_host(c)) for p, c in part_cols.items()}
        merged.recycle()            # tick-loop steady state: buffers pool
        n_groups = len(group_h[0]) if group_h else 1
        # upsert the tick's reduced groups into the persistent store — the
        # merge arithmetic stays in each partial's own dtype (numpy scalar
        # ops of one dtype never promote), so merged partials are the same
        # values the one-shot reduce computes on exactly-representable data
        for r in range(n_groups):
            key = tuple(c[r] for c in group_h)
            pos = st.index.get(key)
            if pos is None:
                st.index[key] = len(st.keys)
                st.keys.append(key)
                for p in plan:
                    st.partials[p].append(part_h[p][r])
            else:
                for p, (_, op) in plan.items():
                    cur, new = st.partials[p][pos], part_h[p][r]
                    if op == "min":
                        st.partials[p][pos] = np.minimum(cur, new)
                    elif op == "max":
                        st.partials[p][pos] = np.maximum(cur, new)
                    else:            # sum / count partials merge additively
                        st.partials[p][pos] = cur + new
        # the delta: every group touched this tick (already in the backend's
        # lexicographic group order) with its current MERGED value — an
        # upsert row supersedes the group's previously emitted value
        cols = dict(zip(self.group_by, group_h))
        rows = [st.index[tuple(c[r] for c in group_h)]
                for r in range(n_groups)]
        for out, (col, op) in self.aggs.items():
            if op == "avg":
                s = st.partials[out + _PARTIAL_SEP + "sum"]
                cnt = st.partials[out + _PARTIAL_SEP + "count"]
                # divide in the sum's dtype — the same single-rounding
                # division the one-shot kernel performs
                vals = [s[i] / s[i].dtype.type(cnt[i]) for i in rows]
            else:
                vals = [st.partials[out][i] for i in rows]
            cols[out] = np.array(vals, dtype=vals[0].dtype)
        self.rows_out += n_groups
        return SharedCache(cols, n_groups)

    # ------------------------------------------------------------ batch
    def finish(self, state: List[SharedCache]) -> SharedCache:
        merged = concat_caches(state, ordered=True, recycle_inputs=True)
        if SEGMENT_KEEP_MASK in merged.names:
            # an upstream fused segment deferred its keep-mask: drop the
            # sentinel and compact the MERGED cache once — on device backends
            # this is the single d2h mask sync that replaced one per chunk
            mask = merged.col(SEGMENT_KEEP_MASK)
            merged.keep_columns(
                [c for c in merged.names if c != SEGMENT_KEEP_MASK])
            merged.compact(mask)
        if self._serving is not None:
            return self._serving_finish(merged)
        n = merged.n
        if n == 0:
            cols = {g: np.array([], dtype=np.int64) for g in self.group_by}
            for out in self.aggs:
                cols[out] = np.array([], dtype=np.float64)
            return SharedCache(cols, 0)
        # groupby_reduce is the backend's block kernel: the jax backend routes
        # sum/avg through the kernels/segment_sum Pallas op
        group_cols, agg_cols = self.get_backend().groupby_reduce(
            [merged.col(g) for g in self.group_by],
            {out: (merged.col(col), op) for out, (col, op) in self.aggs.items()},
            n)
        cols = dict(zip(self.group_by, group_cols))
        cols.update(agg_cols)
        # degenerate global aggregation with no agg columns: one empty row
        n_groups = len(next(iter(cols.values()))) if cols else 1
        self.rows_out += n_groups
        return SharedCache(cols, n_groups)

    # ------------------------------------------------------------ sharded
    # The shard runtime's partial→shuffle→merge decomposition reuses the
    # serving partial plan: each shard reduces its rows to per-group
    # MERGEABLE partials (avg → sum+count), the coordinator second-stage
    # reduces the stashed partial tables (value partials with their own op,
    # count partials by summing) in each partial's stage-1 dtype, and avg
    # divides once at emit — the identical single-rounding arithmetic the
    # serial one-shot reduce performs on exactly-representable data.

    def shard_partial(self, state: List[SharedCache]) -> Optional[dict]:
        """Reduce one shard pass's accumulated input to a host partial
        table ``{group col / partial name: np.ndarray}``; ``None`` when the
        shard delivered no rows (nothing to merge)."""
        merged = concat_caches(state, ordered=True, recycle_inputs=True)
        if SEGMENT_KEEP_MASK in merged.names:
            # same deferred-keep-mask compaction as finish(): one d2h sync
            mask = merged.col(SEGMENT_KEEP_MASK)
            merged.keep_columns(
                [c for c in merged.names if c != SEGMENT_KEEP_MASK])
            merged.compact(mask)
        n = merged.n
        if n == 0:
            merged.recycle()
            return None
        plan = self._partial_plan()
        bk = self.get_backend()
        group_cols, part_cols = bk.groupby_reduce(
            [merged.col(g) for g in self.group_by],
            {p: (merged.col(col), op) for p, (col, op) in plan.items()},
            n)
        table = {g: np.asarray(bk.to_host(c))
                 for g, c in zip(self.group_by, group_cols)}
        for p, c in part_cols.items():
            table[p] = np.asarray(bk.to_host(c))
        merged.recycle()
        return table

    def shard_empty(self) -> SharedCache:
        """Schema-shaped empty output a shard pass emits downstream — the
        same dtype conventions as the batch empty path."""
        cols = {g: np.array([], dtype=np.int64) for g in self.group_by}
        for out in self.aggs:
            cols[out] = np.array([], dtype=np.float64)
        return SharedCache(cols, 0)

    def shard_merge(self, state: List[SharedCache], partials: Sequence[dict],
                    combiner=None) -> SharedCache:
        """Coordinator merge: second-stage reduce the stashed per-shard
        partial tables (plus a partial of any rows the merge pass itself
        delivered — a cut-ancestored aggregate's real input arrives then)
        into the exact serial result.  ``combiner`` is the optional mesh
        route reducer; the host ``reduce_partials`` is the reference."""
        from ..core.shard.merge import reduce_partials
        own = self.shard_partial(state)
        tables = list(partials)
        if own is not None:
            tables.append(own)
        if not tables:
            return self.shard_empty()
        plan = self._partial_plan()
        second = {p: ("sum" if op == "count" else op)
                  for p, (_, op) in plan.items()}
        cat = {c: np.concatenate([np.asarray(t[c]) for t in tables])
               for c in (*self.group_by, *plan)}
        merged = combiner(cat, self.group_by, second) \
            if combiner is not None else None
        if merged is None:
            merged = reduce_partials(cat, self.group_by, second)
        group_cols, part_cols = merged
        cols = dict(zip(self.group_by, group_cols))
        for out, (col, op) in self.aggs.items():
            if op == "avg":
                s = part_cols[out + _PARTIAL_SEP + "sum"]
                cnt = part_cols[out + _PARTIAL_SEP + "count"]
                # divide in the sum's dtype — same single rounding as the
                # one-shot kernel (and as _serving_finish's emit)
                vals = [s[i] / s[i].dtype.type(cnt[i]) for i in range(len(s))]
                cols[out] = (np.array(vals, dtype=vals[0].dtype) if vals
                             else np.array([], dtype=np.float64))
            else:
                cols[out] = part_cols[out]
        n_groups = len(next(iter(cols.values()))) if cols else 1
        self.rows_out += n_groups
        return SharedCache(cols, n_groups)


class Sort(BlockComponent):
    """Total sort — block component (needs all rows)."""

    def __init__(self, name: str, by: Sequence[ColumnRef],
                 ascending: bool = True):
        super().__init__(name)
        self.by = [_col_name(b) for b in by]
        self.ascending = ascending

    def consumed_columns(self) -> frozenset:
        return frozenset(self.by)

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return incols

    def finish(self, state: List[SharedCache]) -> SharedCache:
        merged = concat_caches(state, ordered=True, recycle_inputs=True)
        order = self.get_backend().sort_rows(
            [merged.col(b) for b in self.by], ascending=self.ascending)
        merged.take(order)
        self.rows_out += merged.n
        return merged


# ---------------------------------------------------------------------------
#  Semi-block components
# ---------------------------------------------------------------------------
class Union(SemiBlockComponent):
    """Concatenate rows from multiple upstreams (bag union)."""

    def __init__(self, name: str):
        super().__init__(name)

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        # concat requires identical branch schemas; incols is already the
        # intersection across the fan-in branches
        return incols

    def finish(self, state: List[SharedCache]) -> SharedCache:
        out = concat_caches(state, ordered=False, recycle_inputs=True)
        self.rows_out += out.n
        return out


class Merge(SemiBlockComponent):
    """Sorted merge of multiple upstreams by key columns."""

    def __init__(self, name: str, by: Sequence[ColumnRef]):
        super().__init__(name)
        self.by = [_col_name(b) for b in by]

    def consumed_columns(self) -> frozenset:
        return frozenset(self.by)

    def output_schema(self, incols: FrozenSet[str]) -> FrozenSet[str]:
        return incols

    def finish(self, state: List[SharedCache]) -> SharedCache:
        merged = concat_caches(state, ordered=False, recycle_inputs=True)
        merged.take(self.get_backend().sort_rows(
            [merged.col(b) for b in self.by]))
        self.rows_out += merged.n
        return merged


# ---------------------------------------------------------------------------
#  Sinks
# ---------------------------------------------------------------------------
class CollectSink(SinkComponent):
    """Buffers result caches; exposes the final table (split-ordered)."""

    def __init__(self, name: str):
        super().__init__(name)
        self._lock = threading.Lock()
        self._buf: List[SharedCache] = []

    def write(self, cache: SharedCache) -> None:
        snap = SharedCache(cache.to_dict(), cache.n, cache.split_index)
        with self._lock:
            self._buf.append(snap)

    def result(self) -> Dict[str, np.ndarray]:
        with self._lock:
            caches = sorted(self._buf, key=lambda c: c.split_index)
            out = concat_caches(caches, ordered=False)
            table = out.to_dict()        # to_dict copies: recycling is safe
            # return the concat's arena buffers instead of dropping them —
            # a per-tick result() in a resident serving session would
            # otherwise miss-allocate fresh buffers on every single tick
            out.recycle()
            return table

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    # ------------------------------------------------------------ sharded
    def drain(self) -> List[SharedCache]:
        """Take the buffered caches (the shard runtime harvests each shard
        pass's writes, then reassembles the serial buffer via reinject)."""
        with self._lock:
            buf, self._buf = self._buf, []
            return buf

    def reinject(self, caches: List[SharedCache]) -> None:
        with self._lock:
            self._buf.extend(caches)

    # locks don't pickle (process shard route); rebuilt on load
    _UNPICKLABLE = SinkComponent._UNPICKLABLE + ("_lock",)

    def __setstate__(self, state):
        super().__setstate__(state)
        self._lock = threading.Lock()


class FileSink(CollectSink):
    """Writes the final result to a text file (paper: 'writes the final
    results into a text file')."""

    def __init__(self, name: str, path: str, sep: str = "|"):
        super().__init__(name)
        self.path = path
        self.sep = sep

    def close(self) -> None:
        cols = self.result()
        names = list(cols.keys())
        with open(self.path, "w") as f:
            f.write(self.sep.join(names) + "\n")
            if names:
                n = len(cols[names[0]])
                for i in range(n):
                    f.write(self.sep.join(str(cols[c][i]) for c in names) + "\n")
