"""SSB query dataflows (paper §5).

Each builder returns (Dataflow, CollectSink, oracle) where ``oracle(data)``
computes the expected result with an INDEPENDENT implementation (direct
dense-key array indexing — no DimTable/searchsorted code shared with the
engine path), so engine-vs-oracle equality is a real correctness check.

Q4.1 is the paper's Figure-11 flow: lineorder source -> 4 lookups -> filter
-> project -> expression -> groupby-sum (block) -> sort (block) -> sink,
which Algorithm 1 partitions into execution trees T1={1..8}, T2={9},
T3={10,11}.

Predicates and derived columns are built with the column-expression DSL
(``core/expr.py``) by default — their read sets are derived from the AST, so
the optimizer and fused kernels get exact provenance.  ``use_dsl=False``
(or ``REPRO_FLOW_STYLE=lambda``) rebuilds the pre-DSL flows from legacy
lambdas with hand-declared ``reads=`` — kept as the A/B reference the
DSL-vs-lambda equivalence tests and benchmarks compare against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..core import config
from ..core.component import StageBoundary
from ..core.expr import col
from ..core.graph import Dataflow
from .components import (Aggregate, ArraySource, CollectSink, DimTable,
                         Expression, Filter, Lookup, Project, Sort)
from .ssb import SSBData, mfgr_id, region_id


@dataclass
class QueryFlow:
    name: str
    flow: Dataflow
    sink: CollectSink
    oracle: Callable[[SSBData], Dict[str, np.ndarray]]
    #: how the flow's predicates/expressions were built ("dsl" | "lambda") —
    #: recorded in benchmark JSON so the perf trajectory tells the two apart
    style: str = "dsl"


def _style(use_dsl: Optional[bool]) -> bool:
    """Resolve a builder's ``use_dsl`` argument: explicit flag wins, else
    the process default (``REPRO_FLOW_STYLE``, "dsl" unless overridden)."""
    return config.flow_style() == "dsl" if use_dsl is None else bool(use_dsl)


# ---------------------------------------------------------------------------
#  helpers
# ---------------------------------------------------------------------------
def _dense(payload: np.ndarray, keys: np.ndarray) -> np.ndarray:
    """Oracle-side direct index: payload value per key (keys are 1..N)."""
    return payload[keys - 1]


def _dims(data: SSBData):
    cust = DimTable(data.customer["c_custkey"],
                    {"c_nation": data.customer["c_nation"],
                     "c_region": data.customer["c_region"],
                     "c_city": data.customer["c_city"]})
    supp = DimTable(data.supplier["s_suppkey"],
                    {"s_nation": data.supplier["s_nation"],
                     "s_region": data.supplier["s_region"],
                     "s_city": data.supplier["s_city"]})
    part = DimTable(data.part["p_partkey"],
                    {"p_brand1": data.part["p_brand1"],
                     "p_category": data.part["p_category"],
                     "p_mfgr": data.part["p_mfgr"]})
    date = DimTable(data.date["d_datekey"],
                    {"d_year": data.date["d_year"],
                     "d_yearmonthnum": data.date["d_yearmonthnum"],
                     "d_weeknuminyear": data.date["d_weeknuminyear"]})
    return cust, supp, part, date


# ---------------------------------------------------------------------------
#  Q1.1 — revenue from discount/quantity band in 1993
# ---------------------------------------------------------------------------
def build_q1(data: SSBData, use_dsl: Optional[bool] = None) -> QueryFlow:
    dsl = _style(use_dsl)
    _, _, _, date = _dims(data)
    flow = Dataflow("ssb-q1.1")
    src = ArraySource("lineorder", data.lineorder)
    lk_date = Lookup("lookup_date", date, "lo_orderdate",
                     {"d_year": "d_year"}, matched_flag="d_ok")
    if dsl:
        filt = Filter("filter", col("d_ok")
                      & (col("d_year") == 1993)
                      & col("lo_discount").between(1, 3)
                      & (col("lo_quantity") < 25))
        expr = Expression("revenue_expr", "rev",
                          col("lo_extendedprice") * col("lo_discount"))
    else:
        filt = Filter("filter", lambda c, r: (
            c.col("d_ok")[r]
            & (c.col("d_year")[r] == 1993)
            & (c.col("lo_discount")[r] >= 1) & (c.col("lo_discount")[r] <= 3)
            & (c.col("lo_quantity")[r] < 25)),
            reads=["d_ok", "d_year", "lo_discount", "lo_quantity"])
        expr = Expression("revenue_expr", "rev",
                          lambda c, r: c.col("lo_extendedprice")[r]
                          * c.col("lo_discount")[r],
                          reads=["lo_extendedprice", "lo_discount"])
    agg = Aggregate("sum_revenue", [], {"revenue": ("rev", "sum")})
    sink = CollectSink("sink")
    flow.chain(src, lk_date, filt, expr, agg, sink)

    def oracle(d: SSBData) -> Dict[str, np.ndarray]:
        lo = d.lineorder
        dmap = {k: i for i, k in enumerate(d.date["d_datekey"])}
        year = d.date["d_year"][np.array([dmap[k] for k in lo["lo_orderdate"]])]
        m = ((year == 1993) & (lo["lo_discount"] >= 1)
             & (lo["lo_discount"] <= 3) & (lo["lo_quantity"] < 25))
        rev = (lo["lo_extendedprice"][m] * lo["lo_discount"][m]).astype(np.float64)
        return {"revenue": np.array([rev.sum()])}

    return QueryFlow("Q1.1", flow, sink, oracle,
                     style="dsl" if dsl else "lambda")


# ---------------------------------------------------------------------------
#  Q2.1 — revenue by year/brand for category MFGR#12-equivalent, AMERICA
# ---------------------------------------------------------------------------
def build_q2(data: SSBData, use_dsl: Optional[bool] = None) -> QueryFlow:
    dsl = _style(use_dsl)
    _, supp, part, date = _dims(data)
    CATEGORY = 12
    AMERICA = region_id("AMERICA")
    part_f = DimTable(data.part["p_partkey"],
                      {"p_brand1": data.part["p_brand1"]},
                      row_filter=data.part["p_category"] == CATEGORY)
    supp_f = DimTable(data.supplier["s_suppkey"],
                      {"s_nation": data.supplier["s_nation"]},
                      row_filter=data.supplier["s_region"] == AMERICA)
    flow = Dataflow("ssb-q2.1")
    src = ArraySource("lineorder", data.lineorder)
    lk_part = Lookup("lookup_part", part_f, "lo_partkey",
                     {"p_brand1": "p_brand1"})
    lk_supp = Lookup("lookup_supplier", supp_f, "lo_suppkey",
                     {"s_nation": "s_nation"})
    lk_date = Lookup("lookup_date", date, "lo_orderdate",
                     {"d_year": "d_year"})
    if dsl:
        filt = Filter("filter", (col("p_brand1") >= 0)
                      & (col("s_nation") >= 0) & (col("d_year") >= 0))
    else:
        filt = Filter("filter", lambda c, r: (
            (c.col("p_brand1")[r] >= 0) & (c.col("s_nation")[r] >= 0)
            & (c.col("d_year")[r] >= 0)),
            reads=["p_brand1", "s_nation", "d_year"])
    agg = Aggregate("sum_revenue", ["d_year", "p_brand1"],
                    {"revenue": ("lo_revenue", "sum")})
    srt = Sort("sort", ["d_year", "p_brand1"])
    sink = CollectSink("sink")
    flow.chain(src, lk_part, lk_supp, lk_date, filt, agg, srt, sink)

    def oracle(d: SSBData) -> Dict[str, np.ndarray]:
        lo = d.lineorder
        brand = _dense(d.part["p_brand1"], lo["lo_partkey"])
        cat = _dense(d.part["p_category"], lo["lo_partkey"])
        sregion = _dense(d.supplier["s_region"], lo["lo_suppkey"])
        dmap = {k: i for i, k in enumerate(d.date["d_datekey"])}
        year = d.date["d_year"][np.array([dmap[k] for k in lo["lo_orderdate"]])]
        m = (cat == CATEGORY) & (sregion == AMERICA)
        return _group_sum_oracle({"d_year": year[m], "p_brand1": brand[m]},
                                 lo["lo_revenue"][m], "revenue")

    return QueryFlow("Q2.1", flow, sink, oracle,
                     style="dsl" if dsl else "lambda")


# ---------------------------------------------------------------------------
#  Q3.1 — revenue by c_nation, s_nation, year in ASIA, 1992<=y<=1997
# ---------------------------------------------------------------------------
def build_q3(data: SSBData, use_dsl: Optional[bool] = None) -> QueryFlow:
    dsl = _style(use_dsl)
    ASIA = region_id("ASIA")
    cust_f = DimTable(data.customer["c_custkey"],
                      {"c_nation": data.customer["c_nation"]},
                      row_filter=data.customer["c_region"] == ASIA)
    supp_f = DimTable(data.supplier["s_suppkey"],
                      {"s_nation": data.supplier["s_nation"]},
                      row_filter=data.supplier["s_region"] == ASIA)
    date = DimTable(data.date["d_datekey"], {"d_year": data.date["d_year"]})
    flow = Dataflow("ssb-q3.1")
    src = ArraySource("lineorder", data.lineorder)
    lk_cust = Lookup("lookup_customer", cust_f, "lo_custkey",
                     {"c_nation": "c_nation"})
    lk_supp = Lookup("lookup_supplier", supp_f, "lo_suppkey",
                     {"s_nation": "s_nation"})
    lk_date = Lookup("lookup_date", date, "lo_orderdate",
                     {"d_year": "d_year"})
    if dsl:
        filt = Filter("filter", (col("c_nation") >= 0)
                      & (col("s_nation") >= 0)
                      & col("d_year").between(1992, 1997))
    else:
        filt = Filter("filter", lambda c, r: (
            (c.col("c_nation")[r] >= 0) & (c.col("s_nation")[r] >= 0)
            & (c.col("d_year")[r] >= 1992) & (c.col("d_year")[r] <= 1997)),
            reads=["c_nation", "s_nation", "d_year"])
    agg = Aggregate("sum_revenue", ["c_nation", "s_nation", "d_year"],
                    {"revenue": ("lo_revenue", "sum")})
    srt = Sort("sort", ["d_year", "c_nation", "s_nation"])
    sink = CollectSink("sink")
    flow.chain(src, lk_cust, lk_supp, lk_date, filt, agg, srt, sink)

    def oracle(d: SSBData) -> Dict[str, np.ndarray]:
        lo = d.lineorder
        cn = _dense(d.customer["c_nation"], lo["lo_custkey"])
        cr = _dense(d.customer["c_region"], lo["lo_custkey"])
        sn = _dense(d.supplier["s_nation"], lo["lo_suppkey"])
        sr = _dense(d.supplier["s_region"], lo["lo_suppkey"])
        dmap = {k: i for i, k in enumerate(d.date["d_datekey"])}
        year = d.date["d_year"][np.array([dmap[k] for k in lo["lo_orderdate"]])]
        m = (cr == ASIA) & (sr == ASIA) & (year >= 1992) & (year <= 1997)
        return _group_sum_oracle(
            {"c_nation": cn[m], "s_nation": sn[m], "d_year": year[m]},
            lo["lo_revenue"][m], "revenue",
            sort_by=["d_year", "c_nation", "s_nation"])

    return QueryFlow("Q3.1", flow, sink, oracle,
                     style="dsl" if dsl else "lambda")


# ---------------------------------------------------------------------------
#  Q4.1 — the paper's Figure-11 dataflow (profit by year, customer nation)
# ---------------------------------------------------------------------------
def build_q4(data: SSBData, staged: bool = False,
             use_dsl: Optional[bool] = None) -> QueryFlow:
    """``staged=True`` inserts an explicit StageBoundary between the lookup
    stage and the filter/project/expression stage — the multi-tree variant
    whose trees are connected by a ROW-SYNCHRONIZED boundary, which the
    streaming executor overlaps (Q4.1s in BUILDERS)."""
    dsl = _style(use_dsl)
    AMERICA = region_id("AMERICA")
    M1, M2 = mfgr_id("MFGR#1"), mfgr_id("MFGR#2")
    cust_f = DimTable(data.customer["c_custkey"],
                      {"c_nation": data.customer["c_nation"]},
                      row_filter=data.customer["c_region"] == AMERICA)
    supp_f = DimTable(data.supplier["s_suppkey"],
                      {"s_nation": data.supplier["s_nation"]},
                      row_filter=data.supplier["s_region"] == AMERICA)
    part_f = DimTable(data.part["p_partkey"], {"p_mfgr": data.part["p_mfgr"]},
                      row_filter=((data.part["p_mfgr"] == M1)
                                  | (data.part["p_mfgr"] == M2)))
    date = DimTable(data.date["d_datekey"], {"d_year": data.date["d_year"]})

    flow = Dataflow("ssb-q4.1")
    src = ArraySource("lineorder", data.lineorder)                    # 1
    lk_cust = Lookup("lookup_customer", cust_f, "lo_custkey",
                     {"c_nation": "c_nation"})                        # 2
    lk_supp = Lookup("lookup_supplier", supp_f, "lo_suppkey",
                     {"s_nation": "s_nation"})                        # 3
    lk_part = Lookup("lookup_part", part_f, "lo_partkey",
                     {"p_mfgr": "p_mfgr"})                            # 4
    lk_date = Lookup("lookup_date", date, "lo_orderdate",
                     {"d_year": "d_year"})                            # 5
    if dsl:
        filt = Filter("filter_unmatched",                              # 6
                      (col("c_nation") >= 0) & (col("s_nation") >= 0)
                      & (col("p_mfgr") >= 0) & (col("d_year") >= 0))
        expr = Expression("profit_expr", "profit",                     # 8
                          col("lo_revenue") - col("lo_supplycost"))
    else:
        filt = Filter("filter_unmatched", lambda c, r: (               # 6
            (c.col("c_nation")[r] >= 0) & (c.col("s_nation")[r] >= 0)
            & (c.col("p_mfgr")[r] >= 0) & (c.col("d_year")[r] >= 0)),
            reads=["c_nation", "s_nation", "p_mfgr", "d_year"])
        expr = Expression("profit_expr", "profit",
                          lambda c, r: c.col("lo_revenue")[r]
                          - c.col("lo_supplycost")[r],
                          reads=["lo_revenue", "lo_supplycost"])      # 8
    proj = Project("project", ["d_year", "c_nation",
                               "lo_revenue", "lo_supplycost"])        # 7
    agg = Aggregate("groupby_sum", ["d_year", "c_nation"],
                    {"profit": ("profit", "sum")})                    # 9
    srt = Sort("sort", ["d_year", "c_nation"])                        # 10
    sink = CollectSink("sink")                                        # 11
    if staged:
        cut = StageBoundary("stage_cut")
        flow.chain(src, lk_cust, lk_supp, lk_part, lk_date, cut, filt,
                   proj, expr, agg, srt, sink)
    else:
        flow.chain(src, lk_cust, lk_supp, lk_part, lk_date, filt, proj,
                   expr, agg, srt, sink)

    def oracle(d: SSBData) -> Dict[str, np.ndarray]:
        lo = d.lineorder
        cn = _dense(d.customer["c_nation"], lo["lo_custkey"])
        cr = _dense(d.customer["c_region"], lo["lo_custkey"])
        sr = _dense(d.supplier["s_region"], lo["lo_suppkey"])
        pm = _dense(d.part["p_mfgr"], lo["lo_partkey"])
        dmap = {k: i for i, k in enumerate(d.date["d_datekey"])}
        year = d.date["d_year"][np.array([dmap[k] for k in lo["lo_orderdate"]])]
        m = ((cr == AMERICA) & (sr == AMERICA) & ((pm == M1) | (pm == M2)))
        profit = lo["lo_revenue"] - lo["lo_supplycost"]
        return _group_sum_oracle({"d_year": year[m], "c_nation": cn[m]},
                                 profit[m], "profit")

    return QueryFlow("Q4.1s" if staged else "Q4.1", flow, sink, oracle,
                     style="dsl" if dsl else "lambda")


def build_q4_staged(data: SSBData, use_dsl: Optional[bool] = None) -> QueryFlow:
    return build_q4(data, staged=True, use_dsl=use_dsl)


# ---------------------------------------------------------------------------
def _group_sum_oracle(groups: Dict[str, np.ndarray], vals: np.ndarray,
                      out_name: str, sort_by=None) -> Dict[str, np.ndarray]:
    """Independent group-by-sum using python dicts over packed keys."""
    names = list(groups.keys())
    arrs = [groups[k] for k in names]
    acc: Dict[tuple, float] = {}
    for i in range(len(vals)):
        key = tuple(int(a[i]) for a in arrs)
        acc[key] = acc.get(key, 0.0) + float(vals[i])
    if sort_by is None:
        sort_by = names
    pos = [names.index(s) for s in sort_by]
    keys_sorted = sorted(acc.keys(), key=lambda k: tuple(k[p] for p in pos))
    out = {n: np.array([k[i] for k in keys_sorted], dtype=np.int64)
           for i, n in enumerate(names)}
    out[out_name] = np.array([acc[k] for k in keys_sorted], dtype=np.float64)
    return out


BUILDERS = {"Q1.1": build_q1, "Q2.1": build_q2, "Q3.1": build_q3,
            "Q4.1": build_q4, "Q4.1s": build_q4_staged}
