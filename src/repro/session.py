"""Unified user-facing front end: declarative flow construction + one run
entry point.

``FlowBuilder`` (``repro.flow("q4.1")``) chains ETL components fluently over
the column-expression DSL and finishes with ``.sink()``, which validates the
flow AND statically checks every expression's read columns against the
propagated schema (``core/planner.infer_schema``) — a typo'd column name
fails at build time with the component and column named, not as a
``KeyError`` in a worker thread mid-run.

``Session`` unifies what used to take four engine classes, the backend
registry, ``OptimizeOptions``, calibration and the metadata store:

    import repro
    import numpy as np

    f = (repro.flow("q4.1")
         .source(data.lineorder)
         .lookup(cust_dim, "lo_custkey", {"c_nation": "c_nation"})
         .filter(repro.col("c_nation") >= 0)
         .derive("profit", repro.col("lo_revenue") - repro.col("lo_supplycost"))
         .aggregate(["d_year", "c_nation"], {"profit": ("profit", "sum")})
         .sink())

    session = repro.Session(backend="jax")
    res = session.run(f, engine="streaming", optimize=2, fuse=True)
    res.table                     # {column: np.ndarray}
    res.run.summary()             # EngineRun instrumentation

``Session.run`` also accepts any object with ``.flow``/``.sink`` attributes
(e.g. an ``etl.queries.QueryFlow``) or a bare ``(Dataflow, sink)`` pair.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .core import (Dataflow, EngineRun, MetadataStore, OptimizedEngine,
                   OptimizeOptions, OrdinaryEngine, StreamingEngine)
from .core.component import StageBoundary
from .core.optimizer import FlowStatistics, run_calibration
from .core.planner import infer_schema
from .etl.components import (Aggregate, ArraySource, CollectSink, Converter,
                             DimTable, Expression, Filter, Lookup, Project,
                             Sort)
from .etl.kettle import KettleEngine

__all__ = ["Flow", "FlowBuilder", "Session", "SessionRun", "flow"]


@dataclass
class Flow:
    """A built dataflow plus its collecting sink — what ``FlowBuilder.sink``
    returns and ``Session.run`` consumes."""
    name: str
    flow: Dataflow
    sink: CollectSink
    #: statically inferred output schema at the sink (None when an
    #: unknown-provenance component poisoned the inference)
    schema: Optional[frozenset] = None

    def result(self) -> Dict[str, np.ndarray]:
        return self.sink.result()


class FlowBuilder:
    """Fluent linear-chain flow construction.  Every step appends one
    component; ``sink()`` validates and seals the flow.  Component names are
    auto-generated (``filter_1``, ``derive_2``, ...) unless ``name=`` is
    given."""

    def __init__(self, name: str = "flow"):
        self.name = name
        self._flow = Dataflow(name)
        self._chain: list = []
        self._n = 0

    # ------------------------------------------------------------ internals
    def _auto(self, prefix: str, name: Optional[str]) -> str:
        self._n += 1
        return name if name else f"{prefix}_{self._n}"

    def _append(self, comp) -> "FlowBuilder":
        if self._chain and isinstance(self._chain[-1], CollectSink):
            raise ValueError(f"flow {self.name!r} is already sealed by a "
                             f"sink — no further steps allowed")
        if not self._chain and not isinstance(comp, ArraySource):
            raise ValueError(f"flow {self.name!r} must start with .source()")
        self._chain.append(comp)
        return self

    @staticmethod
    def _dim(dim) -> DimTable:
        """Accept a prebuilt DimTable or a (key, payload[, row_filter])
        tuple."""
        if isinstance(dim, DimTable):
            return dim
        if isinstance(dim, tuple) and len(dim) in (2, 3):
            return DimTable(*dim)
        raise TypeError("lookup dimension must be a DimTable or a "
                        "(key_array, payload_dict[, row_filter]) tuple")

    # ----------------------------------------------------------------- steps
    def source(self, columns: Dict[str, np.ndarray], *,
               name: str = "source") -> "FlowBuilder":
        """Start the flow from an in-memory columnar table."""
        if self._chain:
            raise ValueError(f"flow {self.name!r} already has a source")
        self._chain.append(ArraySource(name, columns))
        return self

    def lookup(self, dim, key, returns: Dict[str, str], *,
               default: int = -1, matched_flag: Optional[str] = None,
               name: Optional[str] = None) -> "FlowBuilder":
        """Join a dimension table: ``returns`` maps output column -> dim
        payload column; unmatched rows get ``default``."""
        return self._append(Lookup(self._auto("lookup", name),
                                   self._dim(dim), key, dict(returns),
                                   default=default,
                                   matched_flag=matched_flag))

    def filter(self, predicate, *, name: Optional[str] = None,
               reads: Optional[Sequence[str]] = None) -> "FlowBuilder":
        """Keep rows where the predicate holds — preferably a DSL expression
        (exact derived provenance)."""
        return self._append(Filter(self._auto("filter", name), predicate,
                                   reads=reads))

    def derive(self, out_col: str, expr, *, name: Optional[str] = None,
               reads: Optional[Sequence[str]] = None) -> "FlowBuilder":
        """Compute a new column from existing ones."""
        return self._append(Expression(self._auto("derive", name), out_col,
                                       expr, reads=reads))

    def project(self, *keep, name: Optional[str] = None) -> "FlowBuilder":
        """Keep only the named columns (metadata-only under shared
        caching)."""
        return self._append(Project(self._auto("project", name), list(keep)))

    def convert(self, conversions: Optional[Dict[str, np.dtype]] = None, *,
                name: Optional[str] = None, **dtypes) -> "FlowBuilder":
        """Convert column dtypes: ``convert({"x": np.int32})`` or
        ``convert(x=np.int32)``."""
        conv = dict(conversions or {})
        conv.update(dtypes)
        return self._append(Converter(self._auto("convert", name), conv))

    def boundary(self, *, name: Optional[str] = None) -> "FlowBuilder":
        """Insert an explicit StageBoundary cut (streaming tree boundary)."""
        return self._append(StageBoundary(self._auto("boundary", name)))

    def aggregate(self, group_by: Sequence, aggs: Dict[str, Tuple], *,
                  name: Optional[str] = None) -> "FlowBuilder":
        """Group-by aggregation: ``aggs`` maps output column ->
        (input column, op) with op in sum/avg/min/max/count."""
        return self._append(Aggregate(self._auto("aggregate", name),
                                      list(group_by), dict(aggs)))

    def sort(self, by: Sequence, *, ascending: bool = True,
             name: Optional[str] = None) -> "FlowBuilder":
        """Total sort by the given key columns."""
        return self._append(Sort(self._auto("sort", name), list(by),
                                 ascending=ascending))

    # ------------------------------------------------------------------ seal
    def sink(self, *, name: str = "sink") -> Flow:
        """Seal the flow with a collecting sink, validate the DAG and
        statically check every declared read set against the propagated
        schema (exact with DSL expressions)."""
        sink = CollectSink(name)
        self._append(sink)
        self._flow.chain(*self._chain)
        self._flow.validate()
        schemas = infer_schema(self._flow, strict=True)
        return Flow(self.name, self._flow, sink, schema=schemas.get(name))


def flow(name: str = "flow") -> FlowBuilder:
    """Start a declarative flow: ``repro.flow("q4.1").source(...)...``."""
    return FlowBuilder(name)


# ---------------------------------------------------------------------------
#  Session
# ---------------------------------------------------------------------------
@dataclass
class SessionRun:
    """One executed flow: the engine instrumentation + the sink table."""
    run: EngineRun
    table: Dict[str, np.ndarray]

    @property
    def run_id(self) -> str:
        """Opaque identifier joining this run to its metadata-store record,
        benchmark JSON and trace-file process (see ``repro.obs``)."""
        return self.run.run_id

    @property
    def trace_file(self) -> Optional[str]:
        """Exported Perfetto trace (``REPRO_TRACE=1``), else ``None``."""
        return self.run.trace_file

    @property
    def metrics(self) -> Dict[str, object]:
        """The run tracer's metric snapshot (counters / gauges /
        histograms); ``{}`` when tracing was off."""
        return self.run.metrics

    def summary(self) -> str:
        return self.run.summary()


class Session:
    """One entry point over the four engines, backend resolution,
    ``OptimizeOptions``, calibration and metadata recording.

    ``backend`` and ``options`` set session-wide defaults;
    ``run(..., **overrides)`` wins per call.  Every run (and calibration)
    is recorded in the session's ``MetadataStore`` (pass ``metadata=None``
    explicitly to disable recording)."""

    ENGINES = ("ordinary", "kettle", "optimized", "streaming")

    _OWN_STORE = object()          # sentinel: create a private MetadataStore

    def __init__(self, *, backend: Optional[str] = None,
                 metadata=_OWN_STORE,
                 options: Optional[OptimizeOptions] = None):
        self.backend = backend
        self.metadata = (MetadataStore() if metadata is Session._OWN_STORE
                         else metadata)
        self.defaults = options or OptimizeOptions()

    # ------------------------------------------------------------ plumbing
    @staticmethod
    def _flow_pair(f) -> Tuple[Dataflow, Optional[CollectSink]]:
        if isinstance(f, Flow):
            return f.flow, f.sink
        if isinstance(f, Dataflow):
            return f, None
        if isinstance(f, tuple) and len(f) == 2:
            return f
        if hasattr(f, "flow") and hasattr(f, "sink"):   # e.g. QueryFlow
            return f.flow, f.sink
        raise TypeError(
            f"cannot run {f!r}: expected a built Flow, a QueryFlow-like "
            f"object with .flow/.sink, a Dataflow, or a (Dataflow, sink) "
            f"pair")

    # ----------------------------------------------------------------- runs
    def run(self, f, *, engine: str = "streaming",
            optimize: Optional[int] = None, fuse: Optional[bool] = None,
            backend: Optional[str] = None, **opts) -> SessionRun:
        """Execute a flow.  ``engine`` is one of ``ordinary`` / ``kettle``
        (the copy-everywhere baselines) / ``optimized`` / ``streaming``;
        ``optimize`` maps to ``OptimizeOptions.optimize_level`` (>= 2 turns
        on the cost-based adaptive path), ``fuse`` to segment fusion, and
        any other ``OptimizeOptions`` field may be overridden by keyword."""
        df, sink = self._flow_pair(f)
        if sink is not None and hasattr(sink, "clear"):
            sink.clear()          # re-running a flow must not accumulate
        # per-call > Session(backend=) > Session(options=...).backend
        if backend is None:
            backend = (self.backend if self.backend is not None
                       else self.defaults.backend)
        if engine in ("ordinary", "kettle"):
            if (optimize or 0) >= 2 or fuse:
                raise ValueError(
                    f"engine {engine!r} is a copy-everywhere baseline — "
                    f"optimize>=2 / fuse=True need the optimized or "
                    f"streaming engine")
            bad = set(opts) - {"chunk_rows"}
            if bad:
                raise TypeError(f"engine {engine!r} does not take "
                                f"{sorted(bad)}")
            cls = OrdinaryEngine if engine == "ordinary" else KettleEngine
            kw = {"backend": backend}
            if opts.get("chunk_rows"):
                kw["chunk_rows"] = opts["chunk_rows"]
            run = cls(df, **kw).run()
        elif engine in ("optimized", "streaming"):
            o = replace(self.defaults, **opts)
            if backend is not None:    # never clobber options.backend with None
                o = replace(o, backend=backend)
            if optimize is not None:
                o = replace(o, optimize_level=int(optimize))
            if fuse is not None:
                o = replace(o, fuse_segments=bool(fuse))
            cls = StreamingEngine if engine == "streaming" else OptimizedEngine
            run = cls(df, o, metadata=self.metadata).run()
        else:
            raise ValueError(f"unknown engine {engine!r}; "
                             f"expected one of {self.ENGINES}")
        if self.metadata is not None and engine in ("ordinary", "kettle"):
            self.metadata.register_run(df, run)
        table = sink.result() if sink is not None else {}
        return SessionRun(run=run, table=table)

    def calibrate(self, f, *, sample_rows: int = 4096,
                  backend: Optional[str] = None) -> FlowStatistics:
        """Run the cost-based optimizer's calibration pass (source prefix,
        sinks suppressed) and record the statistics in the metadata store."""
        from .core.backend import resolve_backend
        df, _ = self._flow_pair(f)
        stats = run_calibration(
            df, sample_rows=sample_rows,
            backend=resolve_backend(backend if backend is not None
                                    else self.backend))
        if self.metadata is not None:
            self.metadata.register_statistics(df, stats)
        return stats
