"""Unified user-facing front end: declarative flow construction + one run
entry point.

``FlowBuilder`` (``repro.flow("q4.1")``) chains ETL components fluently over
the column-expression DSL and finishes with ``.sink()``, which validates the
flow AND statically checks every expression's read columns against the
propagated schema (``core/planner.infer_schema``) — a typo'd column name
fails at build time with the component and column named, not as a
``KeyError`` in a worker thread mid-run.

``Session`` unifies what used to take four engine classes, the backend
registry, ``OptimizeOptions``, calibration and the metadata store:

    import repro
    import numpy as np

    f = (repro.flow("q4.1")
         .source(data.lineorder)
         .lookup(cust_dim, "lo_custkey", {"c_nation": "c_nation"})
         .filter(repro.col("c_nation") >= 0)
         .derive("profit", repro.col("lo_revenue") - repro.col("lo_supplycost"))
         .aggregate(["d_year", "c_nation"], {"profit": ("profit", "sum")})
         .sink())

    session = repro.Session(backend="jax")
    res = session.run(f, engine="streaming", optimize=2, fuse=True)
    res.table                     # {column: np.ndarray}
    res.run.summary()             # EngineRun instrumentation

``Session.run`` also accepts any object with ``.flow``/``.sink`` attributes
(e.g. an ``etl.queries.QueryFlow``) or a bare ``(Dataflow, sink)`` pair.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from .core import (Dataflow, EngineRun, MetadataStore, OptimizedEngine,
                   OptimizeOptions, OrdinaryEngine, ServingEngine,
                   StreamingEngine)
from .core import config as _config
from .core import faults as _faults
from .core.component import StageBoundary
from .core.optimizer import FlowStatistics, run_calibration
from .core.planner import infer_schema
from .etl.components import (Aggregate, ArraySource, CollectSink, Converter,
                             DimTable, Expression, Filter, Lookup, Project,
                             Sort)
from .etl.kettle import KettleEngine

__all__ = ["Flow", "FlowBuilder", "ServeSession", "Session", "SessionRun",
           "TickResult", "flow", "replay_deltas"]


@dataclass
class Flow:
    """A built dataflow plus its collecting sink — what ``FlowBuilder.sink``
    returns and ``Session.run`` consumes."""
    name: str
    flow: Dataflow
    sink: CollectSink
    #: statically inferred output schema at the sink (None when an
    #: unknown-provenance component poisoned the inference)
    schema: Optional[frozenset] = None

    def result(self) -> Dict[str, np.ndarray]:
        return self.sink.result()


class FlowBuilder:
    """Fluent linear-chain flow construction.  Every step appends one
    component; ``sink()`` validates and seals the flow.  Component names are
    auto-generated (``filter_1``, ``derive_2``, ...) unless ``name=`` is
    given."""

    def __init__(self, name: str = "flow"):
        self.name = name
        self._flow = Dataflow(name)
        self._chain: list = []
        self._n = 0

    # ------------------------------------------------------------ internals
    def _auto(self, prefix: str, name: Optional[str]) -> str:
        self._n += 1
        return name if name else f"{prefix}_{self._n}"

    def _append(self, comp) -> "FlowBuilder":
        if self._chain and isinstance(self._chain[-1], CollectSink):
            raise ValueError(f"flow {self.name!r} is already sealed by a "
                             f"sink — no further steps allowed")
        if not self._chain and not isinstance(comp, ArraySource):
            raise ValueError(f"flow {self.name!r} must start with .source()")
        self._chain.append(comp)
        return self

    @staticmethod
    def _dim(dim) -> DimTable:
        """Accept a prebuilt DimTable or a (key, payload[, row_filter])
        tuple."""
        if isinstance(dim, DimTable):
            return dim
        if isinstance(dim, tuple) and len(dim) in (2, 3):
            return DimTable(*dim)
        raise TypeError("lookup dimension must be a DimTable or a "
                        "(key_array, payload_dict[, row_filter]) tuple")

    # ----------------------------------------------------------------- steps
    def source(self, columns: Dict[str, np.ndarray], *,
               name: str = "source") -> "FlowBuilder":
        """Start the flow from an in-memory columnar table."""
        if self._chain:
            raise ValueError(f"flow {self.name!r} already has a source")
        self._chain.append(ArraySource(name, columns))
        return self

    def lookup(self, dim, key, returns: Dict[str, str], *,
               default: int = -1, matched_flag: Optional[str] = None,
               name: Optional[str] = None) -> "FlowBuilder":
        """Join a dimension table: ``returns`` maps output column -> dim
        payload column; unmatched rows get ``default``."""
        return self._append(Lookup(self._auto("lookup", name),
                                   self._dim(dim), key, dict(returns),
                                   default=default,
                                   matched_flag=matched_flag))

    def filter(self, predicate, *, name: Optional[str] = None,
               reads: Optional[Sequence[str]] = None) -> "FlowBuilder":
        """Keep rows where the predicate holds — preferably a DSL expression
        (exact derived provenance)."""
        return self._append(Filter(self._auto("filter", name), predicate,
                                   reads=reads))

    def derive(self, out_col: str, expr, *, name: Optional[str] = None,
               reads: Optional[Sequence[str]] = None) -> "FlowBuilder":
        """Compute a new column from existing ones."""
        return self._append(Expression(self._auto("derive", name), out_col,
                                       expr, reads=reads))

    def project(self, *keep, name: Optional[str] = None) -> "FlowBuilder":
        """Keep only the named columns (metadata-only under shared
        caching)."""
        return self._append(Project(self._auto("project", name), list(keep)))

    def convert(self, conversions: Optional[Dict[str, np.dtype]] = None, *,
                name: Optional[str] = None, **dtypes) -> "FlowBuilder":
        """Convert column dtypes: ``convert({"x": np.int32})`` or
        ``convert(x=np.int32)``."""
        conv = dict(conversions or {})
        conv.update(dtypes)
        return self._append(Converter(self._auto("convert", name), conv))

    def boundary(self, *, name: Optional[str] = None) -> "FlowBuilder":
        """Insert an explicit StageBoundary cut (streaming tree boundary)."""
        return self._append(StageBoundary(self._auto("boundary", name)))

    def aggregate(self, group_by: Sequence, aggs: Dict[str, Tuple], *,
                  name: Optional[str] = None) -> "FlowBuilder":
        """Group-by aggregation: ``aggs`` maps output column ->
        (input column, op) with op in sum/avg/min/max/count."""
        return self._append(Aggregate(self._auto("aggregate", name),
                                      list(group_by), dict(aggs)))

    def sort(self, by: Sequence, *, ascending: bool = True,
             name: Optional[str] = None) -> "FlowBuilder":
        """Total sort by the given key columns."""
        return self._append(Sort(self._auto("sort", name), list(by),
                                 ascending=ascending))

    # ------------------------------------------------------------------ seal
    def sink(self, *, name: str = "sink") -> Flow:
        """Seal the flow with a collecting sink, validate the DAG and
        statically check every declared read set against the propagated
        schema (exact with DSL expressions)."""
        sink = CollectSink(name)
        self._append(sink)
        self._flow.chain(*self._chain)
        self._flow.validate()
        schemas = infer_schema(self._flow, strict=True)
        return Flow(self.name, self._flow, sink, schema=schemas.get(name))


def flow(name: str = "flow") -> FlowBuilder:
    """Start a declarative flow: ``repro.flow("q4.1").source(...)...``."""
    return FlowBuilder(name)


# ---------------------------------------------------------------------------
#  Session
# ---------------------------------------------------------------------------
@dataclass
class SessionRun:
    """One executed flow: the engine instrumentation + the sink table."""
    run: EngineRun
    table: Dict[str, np.ndarray]

    @property
    def run_id(self) -> str:
        """Opaque identifier joining this run to its metadata-store record,
        benchmark JSON and trace-file process (see ``repro.obs``)."""
        return self.run.run_id

    @property
    def trace_file(self) -> Optional[str]:
        """Exported Perfetto trace (``REPRO_TRACE=1``), else ``None``."""
        return self.run.trace_file

    @property
    def metrics(self) -> Dict[str, object]:
        """The run tracer's metric snapshot (counters / gauges /
        histograms); ``{}`` when tracing was off."""
        return self.run.metrics

    def summary(self) -> str:
        return self.run.summary()


class Session:
    """One entry point over the four engines, backend resolution,
    ``OptimizeOptions``, calibration and metadata recording.

    ``backend`` and ``options`` set session-wide defaults;
    ``run(..., **overrides)`` wins per call.  Every run (and calibration)
    is recorded in the session's ``MetadataStore`` (pass ``metadata=None``
    explicitly to disable recording)."""

    ENGINES = ("ordinary", "kettle", "optimized", "streaming")

    _OWN_STORE = object()          # sentinel: create a private MetadataStore

    def __init__(self, *, backend: Optional[str] = None,
                 metadata=_OWN_STORE,
                 options: Optional[OptimizeOptions] = None):
        self.backend = backend
        self.metadata = (MetadataStore() if metadata is Session._OWN_STORE
                         else metadata)
        self.defaults = options or OptimizeOptions()

    # ------------------------------------------------------------ plumbing
    @staticmethod
    def _flow_pair(f) -> Tuple[Dataflow, Optional[CollectSink]]:
        if isinstance(f, Flow):
            return f.flow, f.sink
        if isinstance(f, Dataflow):
            return f, None
        if isinstance(f, tuple) and len(f) == 2:
            return f
        if hasattr(f, "flow") and hasattr(f, "sink"):   # e.g. QueryFlow
            return f.flow, f.sink
        raise TypeError(
            f"cannot run {f!r}: expected a built Flow, a QueryFlow-like "
            f"object with .flow/.sink, a Dataflow, or a (Dataflow, sink) "
            f"pair")

    # ----------------------------------------------------------------- runs
    def run(self, f, *, engine: str = "streaming",
            optimize: Optional[int] = None, fuse: Optional[bool] = None,
            backend: Optional[str] = None, **opts) -> SessionRun:
        """Execute a flow.  ``engine`` is one of ``ordinary`` / ``kettle``
        (the copy-everywhere baselines) / ``optimized`` / ``streaming``;
        ``optimize`` maps to ``OptimizeOptions.optimize_level`` (>= 2 turns
        on the cost-based adaptive path), ``fuse`` to segment fusion, and
        any other ``OptimizeOptions`` field may be overridden by keyword."""
        df, sink = self._flow_pair(f)
        if sink is not None and hasattr(sink, "clear"):
            sink.clear()          # re-running a flow must not accumulate
        # per-call > Session(backend=) > Session(options=...).backend
        if backend is None:
            backend = (self.backend if self.backend is not None
                       else self.defaults.backend)
        if engine in ("ordinary", "kettle"):
            if (optimize or 0) >= 2 or fuse:
                raise ValueError(
                    f"engine {engine!r} is a copy-everywhere baseline — "
                    f"optimize>=2 / fuse=True need the optimized or "
                    f"streaming engine")
            bad = set(opts) - {"chunk_rows"}
            if bad:
                raise TypeError(f"engine {engine!r} does not take "
                                f"{sorted(bad)}")
            cls = OrdinaryEngine if engine == "ordinary" else KettleEngine
            kw = {"backend": backend}
            if opts.get("chunk_rows"):
                kw["chunk_rows"] = opts["chunk_rows"]
            run = cls(df, **kw).run()
        elif engine in ("optimized", "streaming"):
            o = replace(self.defaults, **opts)
            if backend is not None:    # never clobber options.backend with None
                o = replace(o, backend=backend)
            if optimize is not None:
                o = replace(o, optimize_level=int(optimize))
            if fuse is not None:
                o = replace(o, fuse_segments=bool(fuse))
            cls = StreamingEngine if engine == "streaming" else OptimizedEngine
            run = cls(df, o, metadata=self.metadata).run()
        else:
            raise ValueError(f"unknown engine {engine!r}; "
                             f"expected one of {self.ENGINES}")
        if self.metadata is not None and engine in ("ordinary", "kettle"):
            self.metadata.register_run(df, run)
        table = sink.result() if sink is not None else {}
        return SessionRun(run=run, table=table)

    def serve(self, f, *, optimize: Optional[int] = None,
              fuse: Optional[bool] = None, backend: Optional[str] = None,
              **opts) -> "ServeSession":
        """Open a resident serving session over a flow: the worker pool,
        compiled segment kernels, device-resident dimension tables and arena
        buffers stay warm while micro-batches stream in through
        ``ServeSession.tick``.

        The flow's ``ArraySource`` defines the tick schema (every tick must
        supply exactly those columns); a terminal ``Aggregate`` switches to
        incremental upsert deltas (see ``replay_deltas``).  Options mirror
        ``run(engine="streaming", ...)`` except ``optimize >= 2`` (the
        adaptive rewrite path re-plans per run and is rejected for resident
        serving)."""
        df, sink = self._flow_pair(f)
        if sink is None or not hasattr(sink, "clear"):
            raise ValueError("serve() needs a flow with a collecting sink "
                             "(build with repro.flow(...)....sink())")
        o = replace(self.defaults, **opts)
        if backend is None:
            backend = (self.backend if self.backend is not None
                       else self.defaults.backend)
        if backend is not None:
            o = replace(o, backend=backend)
        if optimize is not None:
            o = replace(o, optimize_level=int(optimize))
        if fuse is not None:
            o = replace(o, fuse_segments=bool(fuse))
        if o.optimize_level >= 2:
            raise ValueError(
                "serve() does not take optimize>=2: the cost-based adaptive "
                "path re-plans per run, which defeats resident serving")
        srcs = [c for c in df.vertices.values() if isinstance(c, ArraySource)]
        if len(srcs) != 1:
            raise ValueError(
                f"serve() needs exactly one ArraySource to feed ticks into; "
                f"flow {df.name!r} has {len(srcs)}")
        sink.clear()
        engine = ServingEngine(df, o, metadata=self.metadata)
        return ServeSession(df, engine, srcs[0], sink)

    def calibrate(self, f, *, sample_rows: int = 4096,
                  backend: Optional[str] = None) -> FlowStatistics:
        """Run the cost-based optimizer's calibration pass (source prefix,
        sinks suppressed) and record the statistics in the metadata store."""
        from .core.backend import resolve_backend
        df, _ = self._flow_pair(f)
        stats = run_calibration(
            df, sample_rows=sample_rows,
            backend=resolve_backend(backend if backend is not None
                                    else self.backend))
        if self.metadata is not None:
            self.metadata.register_statistics(df, stats)
        return stats


# ---------------------------------------------------------------------------
#  Resident serving
# ---------------------------------------------------------------------------
@dataclass
class TickResult:
    """One micro-batch through a resident serving session."""
    #: 0-based tick index
    tick: int
    #: rows ingested this tick
    rows_in: int
    #: emitted delta table — appended rows for row-sync flows, upserted
    #: groups (current merged values) for terminal-Aggregate flows
    delta: Dict[str, np.ndarray]
    #: the session's high-water mark after this tick (None if never given)
    watermark: Optional[float]
    #: wall-clock seconds for the tick
    wall_s: float
    #: per-tick cache-stats snapshot (copies / transfers / arena / compiles)
    cache_stats: Dict[str, int] = field(default_factory=dict)
    #: transient-failure retries this tick took before succeeding (0 on a
    #: clean tick)
    retries: int = 0
    #: True when the micro-batch was dropped into the session's dead-letter
    #: buffer (poison fault, or transient retries exhausted) — the delta is
    #: empty and the session stays alive
    dead_lettered: bool = False

    @property
    def rows_out(self) -> int:
        if not self.delta:
            return 0
        return len(next(iter(self.delta.values())))


class ServeSession:
    """A resident serving loop: one warm worker pool + compiled kernels +
    device caches, fed by ``tick(columns, watermark=...)``.

    Watermarks are monotone: a tick whose watermark regresses below the
    session high-water mark raises (``REPRO_SERVE_STRICT_WATERMARK=1``,
    the default) or is clamped up to it (``=0``).  ``close()`` drains the
    pool and returns the session summary; the flow itself stays reusable
    (``Session.run`` / a fresh ``serve()`` both work afterwards).

    Usable as a context manager:

        with session.serve(f, fuse=True) as srv:
            for batch, wm in source_feed:
                delta = srv.tick(batch, watermark=wm).delta
    """

    def __init__(self, flow: Dataflow, engine: ServingEngine,
                 source: ArraySource, sink: CollectSink):
        self.flow = flow
        self.engine = engine
        self.source = source
        self.sink = sink
        self.watermark: Optional[float] = None
        self._closed = False
        self._summary: Dict[str, object] = {}
        #: bounded record of recent TickResults (REPRO_SERVE_HISTORY)
        self.history: List[TickResult] = []
        #: bounded dead-letter buffer: micro-batches dropped after a poison
        #: fault or exhausted transient retries, oldest evicted first —
        #: each entry keeps the batch columns so an operator can re-tick it
        self.dead_letters: "deque" = deque(maxlen=_config.DEAD_LETTER_MAX)

    # ------------------------------------------------------------------ api
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def ticks(self) -> int:
        return self.engine.ticks

    def tick(self, columns: Dict[str, np.ndarray], *,
             watermark: Optional[float] = None) -> TickResult:
        """Ingest one micro-batch and return the emitted delta."""
        if self._closed:
            raise RuntimeError(
                f"serving session for flow {self.flow.name!r} is closed")
        lag: Optional[float] = None
        if watermark is not None:
            watermark = float(watermark)
            if self.watermark is not None and watermark < self.watermark:
                if _config.serve_strict_watermark():
                    raise ValueError(
                        f"watermark regressed: {watermark} < high-water mark "
                        f"{self.watermark} (set "
                        f"{_config.ENV_SERVE_STRICT_WATERMARK}=0 to clamp "
                        f"instead)")
                watermark = self.watermark
            self.watermark = watermark
            lag = max(0.0, time.time() - watermark)
        self.source.set_data(columns)
        rows_in = self.source.columns and len(
            next(iter(self.source.columns.values()))) or 0
        aggs = [c for c in self.flow.vertices.values()
                if hasattr(c, "serving_snapshot")]
        attempt, delay = 0, _config.retry_backoff()
        while True:
            # an aborted attempt (or previous tick) may have left partial
            # per-split rows buffered in the sink — they belong to an
            # execution that FAILED, so they must never leak into this
            # tick's delta
            self.sink.clear()
            # snapshot the cross-tick aggregate partials: a retried tick
            # must merge its rows exactly once
            snaps = [(c, c.serving_snapshot()) for c in aggs]
            try:
                _faults.inject("tick", component=self.flow.name,
                               split=self.engine.ticks)
                info = self.engine.tick(watermark_lag=lag)
                break
            except BaseException as e:
                for c, s in snaps:
                    if s is None and c._serving is not None:
                        # the failed attempt was the session's FIRST tick
                        # (serving mode began mid-attempt): a fresh store IS
                        # the pre-attempt state
                        c.begin_serving()
                    else:
                        c.serving_restore(s)
                kind = _faults.classify(e)
                if kind == "transient" and attempt < _config.retry_max():
                    _faults.record_retry(f"tick.{self.flow.name}", attempt,
                                         delay)
                    if delay > 0.0:
                        time.sleep(delay)
                    delay = min(delay * 2.0, _faults.RETRY_BACKOFF_CAP_S)
                    attempt += 1
                    continue
                if kind == "permanent":
                    # abort promptly with the original exception; the
                    # restores above leave the session consistent, so a
                    # later tick still works
                    raise
                # poison batch (or transient retries exhausted): drop it
                # into the bounded dead-letter buffer and stay alive
                self.sink.clear()
                self.dead_letters.append({
                    "tick": self.engine.ticks, "columns": columns,
                    "watermark": self.watermark, "attempts": attempt + 1,
                    "error": repr(e)})
                if self.engine.tracer is not None:
                    self.engine.tracer.metrics.inc("dead_letters")
                result = TickResult(tick=self.engine.ticks,
                                    rows_in=int(rows_in), delta={},
                                    watermark=self.watermark, wall_s=0.0,
                                    retries=attempt, dead_lettered=True)
                self.history.append(result)
                cap = _config.serve_history()
                if len(self.history) > cap:
                    del self.history[:len(self.history) - cap]
                return result
        delta = self.sink.result()
        self.sink.clear()
        result = TickResult(tick=info["tick"], rows_in=int(rows_in),
                            delta=delta, watermark=self.watermark,
                            wall_s=info["wall_s"],
                            cache_stats=info["cache_stats"],
                            retries=attempt)
        self.history.append(result)
        cap = _config.serve_history()
        if len(self.history) > cap:
            del self.history[:len(self.history) - cap]
        return result

    def close(self) -> Dict[str, object]:
        """Stop serving: drain the pool, export the session trace (if
        tracing), and leave the flow reusable.  Idempotent."""
        if self._closed:
            return dict(self._summary)
        self._summary = self.engine.close()
        self._closed = True
        return dict(self._summary)

    # -------------------------------------------------------- context mgmt
    def __enter__(self) -> "ServeSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def replay_deltas(deltas: Iterable[Union[TickResult, Dict[str, np.ndarray]]],
                  group_by: Optional[Sequence[str]] = None
                  ) -> Dict[str, np.ndarray]:
    """Reassemble the per-tick deltas of a serving session into the table
    the equivalent one-shot batch run would produce.

    For row-sync flows (no terminal Aggregate) pass ``group_by=None``: the
    deltas are append-only and simply concatenate in tick order.  For a
    terminal-Aggregate flow pass its group columns: each delta upserts the
    groups it touches (last write wins) and the result is sorted into the
    batch engines' lexicographic-ascending group order."""
    tables = [d.delta if isinstance(d, TickResult) else d for d in deltas]
    tables = [t for t in tables
              if t and len(next(iter(t.values()))) > 0]
    if not tables:
        return {}
    cols = list(tables[0])
    for t in tables[1:]:
        if set(t) != set(cols):
            raise ValueError(
                f"delta column sets differ: {sorted(cols)} vs {sorted(t)}")
    cat = {c: np.concatenate([t[c] for t in tables]) for c in cols}
    if group_by is None:
        return cat
    missing = [c for c in group_by if c not in cat]
    if missing:
        raise KeyError(f"group_by columns {missing} not in the deltas "
                       f"(have {sorted(cols)})")
    keys = [cat[c] for c in group_by]
    last: Dict[tuple, int] = {}
    for i in range(len(cat[cols[0]])):
        last[tuple(k[i].item() for k in keys)] = i
    idx = np.fromiter(last.values(), dtype=np.int64, count=len(last))
    sel = {c: cat[c][idx] for c in cols}
    if group_by:
        order = np.lexsort(tuple(sel[c] for c in group_by)[::-1])
        sel = {c: sel[c][order] for c in cols}
    return sel
