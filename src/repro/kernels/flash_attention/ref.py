"""Pure-jnp oracle for flash attention — materializes the full score matrix
with fp32 softmax (numerically exact reference)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jax.Array:
    """q: [B, Sq, Kh, G, hd]; k, v: [B, Skv, Kh, hd] -> [B, Sq, Kh, G, hd]."""
    B, Sq, Kh, G, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp <= qp
    if window and window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)          # fully-masked rows -> 0
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
