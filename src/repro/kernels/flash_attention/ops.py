"""Public flash_attention op: jit'd wrapper choosing Pallas (TPU),
interpret=True (CPU validation) or the pure-jnp reference."""
from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "impl", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, impl: str = "auto",
                    block_q: int = 512, block_k: int = 512) -> jax.Array:
    """GQA flash attention.  q: [B, Sq, Kh, G, hd]; k, v: [B, Skv, Kh, hd]."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      softcap=softcap, block_q=block_q,
                                      block_k=block_k)
    if impl == "interpret":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      softcap=softcap, block_q=block_q,
                                      block_k=block_k, interpret=True)
    return flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap)
