"""Pallas TPU kernel: flash attention (online softmax), causal/bidirectional
GQA with optional sliding window and logit softcap.

TPU adaptation (DESIGN §4): the grid is (batch*kv_head, q_blocks, kv_blocks)
with the LAST axis sequential — Pallas streams one K/V block at a time
HBM->VMEM while the [block_q, head_dim] output tile and the online-softmax
carries (m, l) live in VMEM scratch across the kv axis.  Q blocks are
revisited per kv step via the BlockSpec index maps; the MXU does the
[block_q, hd] @ [hd, block_k] score matmul and the [block_q, block_k] @
[block_k, hd] value matmul at systolic throughput.

Causality/window pruning: blocks entirely masked are skipped with pl.when
(score compute is guarded), which converts the O(S^2) grid into the ~S^2/2
causal trapezoid at zero code complexity — the grid still enumerates blocks
but the skipped ones do no FLOPs and no VMEM writes.

VMEM working set: q[bq,hd] + k[bk,hd] + v[bk,hd] + o[bq,hd] + m,l[bq,1]
  + scores[bq,bk] ~= (2*bq + 2*bk)*hd*4 + bq*bk*4.
With bq=bk=512, hd=128: ~2.1 MB << 16 MB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  block_q: int, block_k: int, kv_blocks: int, seq_kv: int):
    """Grid: (bh, q_block, kv_block); kv_block is the innermost sequential axis.

    q_ref: [block_q, hd]; k_ref/v_ref: [block_k, hd]
    o_ref: [block_q, hd] output tile
    m_ref, l_ref: [block_q, 1] online-softmax max / normalizer (VMEM scratch)
    acc_ref: [block_q, hd] un-normalized output accumulator (VMEM scratch)
    """
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # --- block-level pruning: skip fully-masked K/V blocks -----------------
    #   causal:   need k_start <= q_end
    #   window:   need k_end > q_start - window
    run = True
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + block_k - 1
                              >= q_start - window + 1)

    @pl.when(run)
    def _block():
        q = q_ref[...]
        k = k_ref[...]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        # element mask inside the block
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_kv                      # pad rows beyond seq
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # [bq, 1]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked q rows (m_new == NEG_INF): exp(NEG_INF - NEG_INF)
        p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF,
                                  m_prev - m_new))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p.astype(v_ref.dtype), v_ref[...],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _flush():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...]
                      / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           softcap: float = 0.0,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False) -> jax.Array:
    """GQA flash attention.

    q: [B, Sq, Kh, G, hd]; k, v: [B, Skv, Kh, hd].  Returns [B, Sq, Kh, G, hd].
    The (B, Kh, G) axes are folded into the grid's first dim; K/V are
    broadcast across G (grouped-query attention).
    """
    B, Sq, Kh, G, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)

    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    q_blocks = -(-Sq // bq)
    kv_blocks = -(-Skv // bk)
    pad_q = q_blocks * bq - Sq
    pad_k = kv_blocks * bk - Skv

    # fold: [B*Kh*G, S, hd] for q; [B*Kh, S, hd] for k/v
    qf = jnp.moveaxis(q, 1, 3).reshape(B * Kh * G, Sq, hd)
    kf = jnp.moveaxis(k, 1, 2).reshape(B * Kh, Skv, hd)
    vf = jnp.moveaxis(v, 1, 2).reshape(B * Kh, Skv, hd)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, kv_blocks=kv_blocks,
        seq_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B * Kh * G, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((None, bk, hd), lambda b, qi, ki: (b // G, ki, 0)),
            pl.BlockSpec((None, bk, hd), lambda b, qi, ki: (b // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Kh * G, q_blocks * bq, hd),
                                       q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :Sq].reshape(B, Kh, G, Sq, hd)
    return jnp.moveaxis(out, 3, 1)
