"""Pallas TPU kernel: radix-partitioned grouped aggregation.

Replaces the sort + segment-sum route for the paper's BLOCK component: keys
are first densified to contiguous group ids (backend-side, lexicographic
order preserved), then the id space is cut into ``n_parts`` radix
partitions of ``part_groups`` groups each (the id's high bits select the
partition).  Each partition reduces independently with the MXU one-hot
matmul (DESIGN §4 — no atomic scatter on TPU), carrying a
[part_groups, C+1] VMEM accumulator across a sequential row-tile sweep; the
trailing accumulator column tallies row counts, so sums AND counts come out
of one matmul.

Why partition at all, when ``segment_sum`` already reduces any n_groups?
The full-width accumulator and one-hot are [*, n_groups]: past a few
thousand groups they blow the VMEM budget.  The radix cut bounds both at
``part_groups`` regardless of total group count (2^20 dense cells works in
~1 MB of VMEM), trading one extra row sweep per partition — each sweep
reads the SAME row tiles, so the grid is (n_parts, n_tiles) with the tile
axis innermost and rows outside partition p one-hot to zero.

VMEM working set per step:
    rows_tile * (C+2) * 4             (values tile + ids)
  + rows_tile * part_groups * 4       (one-hot, MXU feed)
  + part_groups * (C+1) * 4           (accumulator scratch)
With rows_tile=512, part_groups=256, C<=8: ~0.8 MB << 16 MB VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _radix_groupby_kernel(ids_ref, val_ref, out_ref, acc_ref, *,
                          part_groups: int, n_tiles: int):
    """One grid step: accumulate one row tile into partition p's VMEM
    accumulator.

    ids_ref: [rows_tile, 1]             int32 dense group ids (-1 = padding)
    val_ref: [rows_tile, C+1]           float32 values + ones column
    out_ref: [part_groups, C+1]         partition block (last tile only)
    acc_ref: [part_groups, C+1]         VMEM scratch accumulator
    """
    p = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[...]                                    # [R, 1]
    vals = val_ref[...]                                   # [R, C+1]
    local = ids - p * part_groups                         # id within part p
    # one-hot membership [R, G_p]: rows outside partition p (and padding
    # rows, local < 0) match no local group
    groups = jax.lax.broadcasted_iota(jnp.int32,
                                      (ids.shape[0], part_groups), 1)
    onehot = ((local == groups) & (local >= 0)
              & (local < part_groups)).astype(vals.dtype)
    acc_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(t == n_tiles - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


def radix_groupby_pallas(ids: jax.Array, values: jax.Array, n_groups: int,
                         part_groups: int = 256, rows_tile: int = 512,
                         interpret: bool = False
                         ) -> Tuple[jax.Array, jax.Array]:
    """ids: [N] int32 dense group ids in [0, n_groups) (-1 = padding);
    values: [N, C] float32 (C may be 0).  Returns
    ``(sums [n_groups, C], counts [n_groups])`` float32."""
    N, C = values.shape
    n_parts = max(1, -(-n_groups // part_groups))
    g_pad = n_parts * part_groups
    n_tiles = max(1, -(-N // rows_tile))
    pad = n_tiles * rows_tile - N
    ones = (ids >= 0).astype(jnp.float32)[:, None]
    ext = jnp.concatenate([values.astype(jnp.float32), ones], axis=1)
    if pad:
        ids = jnp.pad(ids, ((0, pad),), constant_values=-1)
        ext = jnp.pad(ext, ((0, pad), (0, 0)))
    ids2d = ids[:, None].astype(jnp.int32)

    kernel = functools.partial(_radix_groupby_kernel,
                               part_groups=part_groups, n_tiles=n_tiles)
    out = pl.pallas_call(
        kernel,
        grid=(n_parts, n_tiles),              # tile axis innermost: each
        in_specs=[                            # partition sweeps all rows
            pl.BlockSpec((rows_tile, 1), lambda p, t: (t, 0)),
            pl.BlockSpec((rows_tile, C + 1), lambda p, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((part_groups, C + 1), lambda p, t: (p, 0)),
        out_shape=jax.ShapeDtypeStruct((g_pad, C + 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((part_groups, C + 1), jnp.float32)],
        interpret=interpret,
    )(ids2d, ext)
    return out[:n_groups, :C], out[:n_groups, C]
