"""Public radix-groupby op: jit'd wrapper choosing the Pallas kernel (TPU)
or interpret=True (CPU validation) with the pure-jnp oracle as fallback."""
from __future__ import annotations

import functools
from typing import Tuple

import jax

from .kernel import radix_groupby_pallas
from .ref import radix_groupby_ref


@functools.partial(jax.jit, static_argnames=("n_groups", "impl",
                                             "part_groups", "rows_tile"))
def radix_groupby(ids: jax.Array, values: jax.Array, n_groups: int,
                  impl: str = "auto", part_groups: int = 256,
                  rows_tile: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Grouped float32 sums + counts over dense group ids: out rows are the
    dense id cells (ascending), ``counts[g]`` tallies rows with
    ``ids == g`` (-1 = padding, matches no group).

    impl: 'pallas' (TPU), 'interpret' (Pallas body on CPU), 'reference'
    (pure jnp), 'auto' (pallas on TPU else reference).
    """
    if impl == "auto":
        impl = ("pallas" if jax.default_backend() == "tpu" else "reference")
    if impl == "pallas":
        return radix_groupby_pallas(ids, values, n_groups,
                                    part_groups=part_groups,
                                    rows_tile=rows_tile)
    if impl == "interpret":
        return radix_groupby_pallas(ids, values, n_groups,
                                    part_groups=part_groups,
                                    rows_tile=rows_tile, interpret=True)
    return radix_groupby_ref(ids, values, n_groups)
