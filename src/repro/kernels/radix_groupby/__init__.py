from .ops import radix_groupby
from .ref import radix_groupby_ref

__all__ = ["radix_groupby", "radix_groupby_ref"]
