"""Pure-jnp oracle for the radix-partitioned groupby (the allclose
reference): grouped float32 sums + occupancy counts over dense group ids."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def radix_groupby_ref(ids: jax.Array, values: jax.Array, n_groups: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """ids: [N] int (-1 = padding); values: [N, C] (C may be 0).
    Returns ``(sums [n_groups, C] float32, counts [n_groups] float32)`` —
    counts are float32 row tallies (exact below 2^24 rows per group), the
    accumulator dtype of the MXU one-hot matmul route."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    vals = jnp.where(valid[:, None], values.astype(jnp.float32), 0.0)
    ext = jnp.concatenate([vals, valid.astype(jnp.float32)[:, None]], axis=1)
    out = jax.ops.segment_sum(ext, safe, num_segments=n_groups)
    return out[:, :-1], out[:, -1]
