"""Pure-jnp oracle for segment_sum (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(seg_ids: jax.Array, values: jax.Array,
                    n_groups: int) -> jax.Array:
    """seg_ids: [N] int (-1 = padding); values: [N, C].  -> [n_groups, C]."""
    valid = seg_ids >= 0
    safe = jnp.where(valid, seg_ids, 0)
    vals = jnp.where(valid[:, None], values.astype(jnp.float32), 0.0)
    return jax.ops.segment_sum(vals, safe, num_segments=n_groups)
