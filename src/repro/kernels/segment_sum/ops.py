"""Public segment_sum op: jit'd wrapper choosing the Pallas kernel (TPU) or
interpret=True (CPU validation) with the pure-jnp oracle as fallback."""
from __future__ import annotations

import functools

import jax

from .kernel import segment_sum_pallas
from .ref import segment_sum_ref


@functools.partial(jax.jit,
                   static_argnames=("n_groups", "impl", "rows_tile"))
def segment_sum(seg_ids: jax.Array, values: jax.Array, n_groups: int,
                impl: str = "auto", rows_tile: int = 512) -> jax.Array:
    """Grouped sum: out[g] = sum of values rows whose seg_id == g.

    impl: 'pallas' (TPU), 'interpret' (Pallas body on CPU), 'reference'
    (pure jnp), 'auto' (pallas on TPU else reference).
    """
    if impl == "auto":
        impl = ("pallas" if jax.default_backend() == "tpu" else "reference")
    if impl == "pallas":
        return segment_sum_pallas(seg_ids, values, n_groups,
                                  rows_tile=rows_tile)
    if impl == "interpret":
        return segment_sum_pallas(seg_ids, values, n_groups,
                                  rows_tile=rows_tile, interpret=True)
    return segment_sum_ref(seg_ids, values, n_groups)
