"""Pallas TPU kernel: grouped aggregation (segment sum).

The paper's BLOCK component (Fig-11 component 9, `groupby_sum`) is a
scatter-add on GPUs/CPUs.  TPUs have no atomic scatter, so we ADAPT the
operation to the MXU (DESIGN §4): each row tile builds a one-hot membership
matrix [rows_tile, n_groups] and the per-tile aggregation is the matmul

    acc[g, c] += onehot[r, g]^T @ vals[r, c]

which is systolic-friendly and runs at matmul throughput.  The grid iterates
row tiles SEQUENTIALLY (TPU grid axes are sequential by default) carrying the
[n_groups, n_cols] accumulator in a VMEM scratch buffer; only the final tile
writes the accumulator back to HBM.

VMEM working set per step:
    rows_tile * n_cols * 4   (values tile)
  + rows_tile * 4            (segment ids)
  + rows_tile * n_groups * 4 (one-hot, materialized by the MXU feed)
  + n_groups * n_cols * 4    (accumulator scratch)
With rows_tile=512, n_groups<=1024, n_cols<=8: ~2.3 MB << 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segment_sum_kernel(seg_ref, val_ref, out_ref, acc_ref, *,
                        n_groups: int, n_tiles: int):
    """One grid step: accumulate one row tile into the VMEM accumulator.

    seg_ref: [rows_tile, 1]     int32 group ids (-1 = padding row)
    val_ref: [rows_tile, C]     float32 values
    out_ref: [n_groups, C]      output (written on the last tile only)
    acc_ref: [n_groups, C]      VMEM scratch accumulator
    """
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seg = seg_ref[...]                                    # [R, 1]
    vals = val_ref[...]                                   # [R, C]
    # one-hot membership: [R, G]; padding rows (seg<0) match no group
    groups = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], n_groups), 1)
    onehot = (seg == groups).astype(vals.dtype)
    # MXU: [R, G]^T @ [R, C] -> [G, C] (contract over the row dim)
    acc_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(t == n_tiles - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


def segment_sum_pallas(seg_ids: jax.Array, values: jax.Array, n_groups: int,
                       rows_tile: int = 512, interpret: bool = False
                       ) -> jax.Array:
    """seg_ids: [N] int32 in [0, n_groups) (or -1 for padding rows);
    values: [N, C] float32.  Returns [n_groups, C] float32 sums."""
    N, C = values.shape
    n_tiles = max(1, -(-N // rows_tile))
    pad = n_tiles * rows_tile - N
    if pad:
        seg_ids = jnp.pad(seg_ids, ((0, pad),), constant_values=-1)
        values = jnp.pad(values, ((0, pad), (0, 0)))
    seg2d = seg_ids[:, None].astype(jnp.int32)            # TPU wants >=2D

    kernel = functools.partial(_segment_sum_kernel, n_groups=n_groups,
                               n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((rows_tile, 1), lambda t: (t, 0)),
            pl.BlockSpec((rows_tile, C), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((n_groups, C), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_groups, C), jnp.float32)],
        interpret=interpret,
    )(seg2d, values)
