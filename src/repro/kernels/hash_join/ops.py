"""Public hash-probe op: jit'd wrapper choosing the Pallas kernel (TPU) or
interpret=True (CPU validation) with the pure-jnp oracle as fallback.  The
table comes from the host-side ``hash_build`` (build once per dimension
table, probe per chunk)."""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax

from .kernel import hash_probe_pallas
from .ref import hash_probe_ref


@functools.partial(jax.jit,
                   static_argnames=("max_probes", "impl", "rows_tile"))
def hash_probe(slot_keys: Sequence[jax.Array], slot_idx: jax.Array,
               val_cols: Sequence[jax.Array], max_probes: int,
               impl: str = "auto", rows_tile: int = 512
               ) -> Tuple[jax.Array, jax.Array]:
    """Probe an open-addressing hash table: returns ``(idx, found)`` where
    ``idx[i]`` is the build's first-occurrence row index of ``val_cols[i]``
    (0 when not found) and ``found[i]`` marks presence.

    impl: 'pallas' (TPU), 'interpret' (Pallas body on CPU), 'reference'
    (pure jnp), 'auto' (pallas on TPU else reference).
    """
    slot_keys = tuple(slot_keys)
    val_cols = tuple(val_cols)
    if impl == "auto":
        impl = ("pallas" if jax.default_backend() == "tpu" else "reference")
    if impl == "pallas":
        return hash_probe_pallas(slot_keys, slot_idx, val_cols, max_probes,
                                 rows_tile=rows_tile)
    if impl == "interpret":
        return hash_probe_pallas(slot_keys, slot_idx, val_cols, max_probes,
                                 rows_tile=rows_tile, interpret=True)
    return hash_probe_ref(slot_keys, slot_idx, val_cols, max_probes)
