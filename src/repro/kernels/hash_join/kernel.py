"""Pallas TPU kernel: open-addressing hash probe (the Lookup join).

The paper's Lookup component is a key -> row-index join against a cached
dimension table.  The legacy device route is a jitted ``searchsorted``
(O(log d) per probe, keys must be pre-sorted); this kernel probes an
open-addressing table built once on the host (``ref.hash_build``) —
arbitrary key order, multi-column keys, O(1 + cluster) gathers per probe.

ADAPTATION (DESIGN §4): TPUs have no per-lane scatter/gather memory unit,
but the probe table is small (2*d slots, int32) and lives fully in VMEM as
a broadcast block; the probe loop is a ``fori_loop`` of vectorized
``jnp.take`` gathers (one per probe distance, bounded by the build's static
``max_probes`` = longest occupied run + 1).  Rows resolve independently —
a done-mask freezes resolved lanes, so the loop cost is the WORST lane's
cluster, which the <=0.5 load factor keeps short.

VMEM working set per step:
    table: (1 + n_keys) * T * 4 bytes     (slot_idx + per-column slot keys)
  + rows_tile * n_keys * 4                (probe values tile)
With T = 2^17 (64k-row dimension) and 2 key columns: ~1.6 MB << 16 MB.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import hash_keys


def _hash_probe_kernel(*refs, n_keys: int, table_size: int, max_probes: int):
    val_refs = refs[:n_keys]
    key_refs = refs[n_keys:2 * n_keys]
    idx_ref = refs[2 * n_keys]
    out_idx_ref = refs[2 * n_keys + 1]
    out_found_ref = refs[2 * n_keys + 2]

    vals = [r[...][:, 0] for r in val_refs]               # [R] each
    slot_keys = [r[...][:, 0] for r in key_refs]          # [T] each
    slot_idx = idx_ref[...][:, 0]                         # [T]
    n = vals[0].shape[0]
    h = hash_keys(vals)

    def body(step, carry):
        idx, found, done = carry
        cand = ((h + jnp.uint32(step))
                & jnp.uint32(table_size - 1)).astype(jnp.int32)
        occ = jnp.take(slot_idx, cand, mode="clip")
        eq = jnp.ones(n, dtype=bool)
        for sk, v in zip(slot_keys, vals):
            eq = eq & (jnp.take(sk, cand, mode="clip") == v)
        hit = (~done) & (occ >= 0) & eq
        miss = (~done) & (occ < 0)
        idx = jnp.where(hit, occ, idx)
        return idx, found | hit, done | hit | miss

    idx = jnp.zeros(n, dtype=jnp.int32)
    found = jnp.zeros(n, dtype=bool)
    done = jnp.zeros(n, dtype=bool)
    idx, found, _ = jax.lax.fori_loop(0, max_probes, body, (idx, found, done))
    out_idx_ref[...] = idx[:, None]
    out_found_ref[...] = found[:, None].astype(jnp.int32)


def hash_probe_pallas(slot_keys: Sequence[jax.Array], slot_idx: jax.Array,
                      val_cols: Sequence[jax.Array], max_probes: int,
                      rows_tile: int = 512, interpret: bool = False
                      ) -> Tuple[jax.Array, jax.Array]:
    """slot_keys: per-key-column [T] arrays; slot_idx: [T] int32 (-1 empty);
    val_cols: per-key-column [N] probe values.  Returns ``(idx int32 [N],
    found bool [N])`` — first-occurrence row index, 0 for misses."""
    n_keys = len(val_cols)
    N = val_cols[0].shape[0]
    T = int(slot_idx.shape[0])
    n_tiles = max(1, -(-N // rows_tile))
    pad = n_tiles * rows_tile - N
    vals2d = []
    for v in val_cols:
        if pad:
            v = jnp.pad(v, ((0, pad),))
        vals2d.append(v[:, None])
    keys2d = [k[:, None] for k in slot_keys]
    idx2d = slot_idx.astype(jnp.int32)[:, None]

    kernel = functools.partial(_hash_probe_kernel, n_keys=n_keys,
                               table_size=T, max_probes=int(max_probes))
    idx, found = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=(
            [pl.BlockSpec((rows_tile, 1), lambda t: (t, 0))] * n_keys
            + [pl.BlockSpec((T, 1), lambda t: (0, 0))] * (n_keys + 1)
        ),
        out_specs=[
            pl.BlockSpec((rows_tile, 1), lambda t: (t, 0)),
            pl.BlockSpec((rows_tile, 1), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles * rows_tile, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles * rows_tile, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*vals2d, *keys2d, idx2d)
    return idx[:N, 0], found[:N, 0].astype(bool)
