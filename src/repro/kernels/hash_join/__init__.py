from .ops import hash_probe
from .ref import hash_build, hash_keys, hash_keys_np, hash_probe_ref

__all__ = ["hash_build", "hash_keys", "hash_keys_np", "hash_probe",
           "hash_probe_ref"]
