"""Host-side open-addressing build + pure-jnp probe oracle for the hash
join (the allclose/equality reference).

The build runs ONCE per dimension table on the host (numpy) and the probe
runs per chunk on the device, so the two halves must agree bit-for-bit on
the hash function.  Both sides compute a murmur3-style fmix32 finalizer over
the key's low 32 bits (uint32 wraparound arithmetic — identical in numpy
and in jnp with x64 disabled, where 64-bit keys canonicalize to 32-bit on
device anyway).

Duplicate keys keep the FIRST occurrence (lowest row index).  Built over a
``DimTable``'s sorted key column this makes the probe's gather index equal
to ``searchsorted``'s leftmost-duplicate index, so the hash route is
byte-compatible with the legacy sorted-probe route; over an arbitrary
(shuffled) key order it is simply first-occurrence-wins.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

#: murmur3 fmix32 constants — shared by the host build and the device probe
_FMIX_C1 = 0x85EB_CA6B
_FMIX_C2 = 0xC2B2_AE35
#: per-key-column mixing multiplier (odd => bijective mod 2^32)
_COL_MIX = 0x9E37_79B9


def _fmix32_np(h: np.ndarray) -> np.ndarray:
    h = h.astype(np.uint32)
    h ^= h >> np.uint32(16)
    h *= np.uint32(_FMIX_C1)
    h ^= h >> np.uint32(13)
    h *= np.uint32(_FMIX_C2)
    h ^= h >> np.uint32(16)
    return h


def hash_keys_np(key_cols: Sequence[np.ndarray]) -> np.ndarray:
    """uint32 combined hash of one or more integer key columns (host)."""
    h = np.zeros(len(key_cols[0]), dtype=np.uint32)
    for k in key_cols:
        h = _fmix32_np(h ^ (np.asarray(k).astype(np.uint32)
                            * np.uint32(_COL_MIX)))
    return h


def hash_keys(key_cols: Sequence[jax.Array]) -> jax.Array:
    """uint32 combined hash of one or more integer key columns (device) —
    bit-identical to :func:`hash_keys_np`."""
    h = jnp.zeros(key_cols[0].shape[0], dtype=jnp.uint32)
    for k in key_cols:
        h = h ^ (k.astype(jnp.uint32) * jnp.uint32(_COL_MIX))
        h = h ^ (h >> 16)
        h = h * jnp.uint32(_FMIX_C1)
        h = h ^ (h >> 13)
        h = h * jnp.uint32(_FMIX_C2)
        h = h ^ (h >> 16)
    return h


def _next_pow2(x: int) -> int:
    return 1 << max(4, (x - 1).bit_length())


def hash_build(key_cols: Sequence[np.ndarray]) -> Dict[str, object]:
    """Open-addressing (linear probing) build over ``d`` rows of one or more
    integer key columns, vectorized on the host.

    Returns ``{"slot_keys": tuple_of_[T]_arrays, "slot_idx": int32 [T],
    "table_size": T, "max_probes": int}`` — ``slot_idx[t] < 0`` marks an
    empty slot, ``max_probes`` is a static probe-length bound (longest
    occupied run + 1), so a device probe loop with that trip count always
    terminates at a hit or an empty slot.

    Insertion processes rows in index order, one probe distance per round,
    so equal keys keep the FIRST row index and colliding distinct keys are
    placed deterministically (lowest index wins a free slot).  Table size is
    the next power of two >= 2*d (load factor <= 0.5)."""
    key_cols = [np.asarray(k) for k in key_cols]
    d = len(key_cols[0])
    if any(len(k) != d for k in key_cols):
        raise ValueError("hash_build: key columns must share a length")
    size = _next_pow2(max(2 * max(d, 1), 16))
    mask = np.uint32(size - 1)

    slot_idx = np.full(size, -1, dtype=np.int32)
    slot_keys = [np.zeros(size, dtype=k.dtype) for k in key_cols]
    if d:
        h0 = hash_keys_np(key_cols)
        live = np.arange(d, dtype=np.int64)     # unplaced rows, index order
        step = np.uint32(0)
        while live.size:
            cand = ((h0[live] + step) & mask).astype(np.int64)
            occ = slot_idx[cand]
            # drop duplicates of an already-placed identical key (keep-first)
            dup = occ >= 0
            for sk, k in zip(slot_keys, key_cols):
                dup &= sk[cand] == k[live]
            placeable = occ < 0
            if placeable.any():
                # lowest row index wins each contested free slot this round
                slots = cand[placeable]
                rows = live[placeable]
                _, first = np.unique(slots, return_index=True)
                slot_idx[slots[first]] = rows[first]
                won = np.zeros(len(rows), dtype=bool)
                won[first] = True
                for sk, k in zip(slot_keys, key_cols):
                    sk[slots[first]] = k[rows[first]]
                placed = np.zeros(len(live), dtype=bool)
                placed[np.flatnonzero(placeable)[won]] = True
            else:
                placed = np.zeros(len(live), dtype=bool)
            live = live[~(placed | dup)]
            step += np.uint32(1)

    # static probe bound: longest run of occupied slots (+1 for the empty
    # terminator), computed on the doubled table to cover wraparound
    occ2 = np.concatenate([slot_idx >= 0, slot_idx >= 0])
    max_run = 0
    run = 0
    for o in occ2:
        run = run + 1 if o else 0
        if run > max_run:
            max_run = run
    max_probes = int(min(max_run, size) + 1)
    return {"slot_keys": tuple(slot_keys), "slot_idx": slot_idx,
            "table_size": size, "max_probes": max_probes}


def hash_probe_ref(slot_keys: Sequence[jax.Array], slot_idx: jax.Array,
                   val_cols: Sequence[jax.Array], max_probes: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Pure-jnp probe: returns ``(row_idx int32, found bool)`` per probe
    row.  ``row_idx`` is the build's first-occurrence index for found keys
    and 0 for misses (callers gate every gather on ``found``).  Traceable —
    the fused segment kernel inlines this directly."""
    size = slot_idx.shape[0]
    n = val_cols[0].shape[0]
    h = hash_keys(list(val_cols))

    def body(step, carry):
        idx, found, done = carry
        cand = ((h + jnp.uint32(step)) & jnp.uint32(size - 1)).astype(jnp.int32)
        occ = jnp.take(slot_idx, cand, mode="clip")
        eq = jnp.ones(n, dtype=bool)
        for sk, v in zip(slot_keys, val_cols):
            eq = eq & (jnp.take(sk, cand, mode="clip") == v)
        hit = (~done) & (occ >= 0) & eq
        miss = (~done) & (occ < 0)
        idx = jnp.where(hit, occ, idx)
        return idx, found | hit, done | hit | miss

    idx = jnp.zeros(n, dtype=jnp.int32)
    found = jnp.zeros(n, dtype=bool)
    done = jnp.zeros(n, dtype=bool)
    idx, found, _ = jax.lax.fori_loop(0, max_probes, body, (idx, found, done))
    return idx, found
