# Pallas TPU kernels for the compute hot-spots this framework optimizes:
#
#   segment_sum     — grouped aggregation (the paper's BLOCK component,
#                     Fig-11 component 9 `groupby_sum`) adapted to the MXU:
#                     one-hot matmul accumulate instead of a GPU atomic-scatter.
#   hash_join       — open-addressing hash build (host) + Pallas probe for the
#                     Lookup component: the device-cached DimTable becomes a
#                     VMEM-resident hash table, probes return gather indices +
#                     qualify mask for arbitrary (unsorted, multi-column) keys.
#   radix_groupby   — radix-partitioned grouped aggregation over dense key
#                     ids: partitions the id space so the one-hot accumulator
#                     stays VMEM-bounded at any group count, replacing the
#                     sort + segment-sum route.
#   flash_attention — the staggering activity of every transformer cell
#                     (causal/bidirectional GQA + sliding window), online
#                     softmax with K/V streamed HBM->VMEM block by block.
#   mamba_scan      — the staggering activity of SSM cells; chunked selective
#                     scan with the [d_inner, d_state] carry held in VMEM
#                     scratch across a sequential grid axis.
#
# Each package has kernel code (pl.pallas_call + BlockSpec), ops.py (jit'd
# public wrapper with an interpret=True CPU path) and ref.py (pure-jnp
# oracle used by the per-kernel allclose sweeps in tests/).
