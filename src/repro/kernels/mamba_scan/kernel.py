"""Pallas TPU kernel: fused selective-scan (Mamba-1 SSM hot loop).

Recurrence per channel c and state n:
    h_t[c, n] = exp(delta_t[c] * A[c, n]) * h_{t-1}[c, n]
                + delta_t[c] * B_t[n] * x_t[c]
    y_t[c]    = sum_n h_t[c, n] * C_t[n]

TPU adaptation (DESIGN §4): the GPU implementation materializes
dA/dBx = [B, T, d_inner, N] in HBM.  We instead fuse the outer products into
the kernel: inputs are the SMALL tensors delta/x [B, T, d], B/C [B, T, N] and
A [d, N]; the [d_blk, N] intermediates exist only in VMEM/VREGs.  HBM traffic
drops by ~2*N (N=16 => ~32x) versus the materialized form — the same
copy-elimination idea as the paper's shared caching scheme, applied to the
HBM<->VMEM boundary.

Grid: (batch, d_inner blocks, seq chunks) — the LAST axis is sequential;
the [d_blk, N] state carry lives in VMEM scratch across chunk steps.  Each
chunk streams [chunk, d_blk] slices of delta/x and [chunk, N] slices of B/C
from HBM while the inner fori_loop runs the recurrence on VREG-resident
tiles (elementwise VPU work — the op is memory-bound, so the win is the
HBM-traffic reduction, not MXU utilization).

VMEM per step (d_blk=512, N=16, chunk=64, fp32):
  delta/x: 2*64*512*4 = 256 KB; B/C: 2*64*16*4 = 8 KB; A: 512*16*4 = 32 KB;
  h carry: 32 KB; y: 128 KB  => ~0.5 MB << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_scan_kernel(delta_ref, x_ref, b_ref, c_ref, a_ref, h0_ref,
                       y_ref, hT_ref, h_ref, *,
                       chunk: int, n_chunks: int):
    """One (batch, d_block) lane over one sequence chunk.

    delta_ref, x_ref: [chunk, d_blk]   fp32
    b_ref, c_ref:     [chunk, N]       fp32
    a_ref:            [d_blk, N]       fp32 (A = -exp(A_log), precomputed)
    h0_ref:           [d_blk, N]       fp32 initial state
    y_ref:            [chunk, d_blk]   output
    hT_ref:           [d_blk, N]       final state (written on last chunk)
    h_ref:            [d_blk, N]       VMEM scratch carry
    """
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[...]

    a = a_ref[...]                                     # [d_blk, N]
    delta = delta_ref[...]                             # [ch, d_blk]
    x = x_ref[...]
    bmat = b_ref[...]                                  # [ch, N]
    cmat = c_ref[...]

    def step(t, h):
        d_t = delta[t][:, None]                        # [d_blk, 1]
        dA = jnp.exp(d_t * a)                          # [d_blk, N]
        dBx = d_t * bmat[t][None, :] * x[t][:, None]   # fused outer product
        h = dA * h + dBx
        y_t = jnp.sum(h * cmat[t][None, :], axis=1)    # [d_blk]
        pl.store(y_ref, (pl.dslice(t, 1), slice(None)), y_t[None, :])
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == n_chunks - 1)
    def _flush():
        hT_ref[...] = h


def mamba_scan_pallas(delta: jax.Array, x: jax.Array, B: jax.Array,
                      C: jax.Array, A: jax.Array, h0: jax.Array, *,
                      chunk: int = 64, d_block: int = 512,
                      interpret: bool = False):
    """delta, x: [Bt, T, d]; B, C: [Bt, T, N]; A: [d, N]; h0: [Bt, d, N].
    Returns (y [Bt, T, d], hT [Bt, d, N]), all fp32."""
    Bt, T, d = delta.shape
    N = B.shape[-1]
    ch = min(chunk, T)
    db = min(d_block, d)
    n_chunks = -(-T // ch)
    n_dblk = -(-d // db)
    pad_t = n_chunks * ch - T
    pad_d = n_dblk * db - d
    if pad_t or pad_d:
        delta = jnp.pad(delta, ((0, 0), (0, pad_t), (0, pad_d)))
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, pad_d)))
        B = jnp.pad(B, ((0, 0), (0, pad_t), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad_t), (0, 0)))
    if pad_d:
        A = jnp.pad(A, ((0, pad_d), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_d), (0, 0)))

    kernel = functools.partial(_mamba_scan_kernel, chunk=ch,
                               n_chunks=n_chunks)
    y, hT = pl.pallas_call(
        kernel,
        grid=(Bt, n_dblk, n_chunks),
        in_specs=[
            pl.BlockSpec((None, ch, db), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((None, ch, db), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((None, ch, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((None, ch, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((db, N), lambda b, di, ci: (di, 0)),
            pl.BlockSpec((None, db, N), lambda b, di, ci: (b, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, ch, db), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((None, db, N), lambda b, di, ci: (b, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, n_chunks * ch, n_dblk * db),
                                 jnp.float32),
            jax.ShapeDtypeStruct((Bt, n_dblk * db, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((db, N), jnp.float32)],
        interpret=interpret,
    )(delta.astype(jnp.float32), x.astype(jnp.float32),
      B.astype(jnp.float32), C.astype(jnp.float32),
      A.astype(jnp.float32), h0.astype(jnp.float32))
    return y[:, :T, :d], hT[:, :d]
