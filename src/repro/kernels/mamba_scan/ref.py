"""Pure-jnp oracle for the selective scan (lax.scan over time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(delta: jax.Array, x: jax.Array, B: jax.Array,
                   C: jax.Array, A: jax.Array, h0: jax.Array):
    """delta, x: [Bt, T, d]; B, C: [Bt, T, N]; A: [d, N]; h0: [Bt, d, N].
    Returns (y [Bt, T, d], hT [Bt, d, N])."""
    delta = delta.astype(jnp.float32)
    x = x.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    A = A.astype(jnp.float32)
    h0 = h0.astype(jnp.float32)

    def step(h, inp):
        d_t, x_t, b_t, c_t = inp                  # [Bt,d], [Bt,d], [Bt,N], [Bt,N]
        dA = jnp.exp(d_t[..., None] * A)          # [Bt, d, N]
        dBx = d_t[..., None] * b_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y_t = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y_t

    xs = (jnp.moveaxis(delta, 1, 0), jnp.moveaxis(x, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT
