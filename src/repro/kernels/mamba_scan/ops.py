"""Public mamba_scan op: jit'd wrapper choosing Pallas (TPU), interpret=True
(CPU validation) or the pure-jnp reference."""
from __future__ import annotations

import functools

import jax

from .kernel import mamba_scan_pallas
from .ref import mamba_scan_ref


@functools.partial(jax.jit, static_argnames=("impl", "chunk", "d_block"))
def mamba_scan(delta: jax.Array, x: jax.Array, B: jax.Array, C: jax.Array,
               A: jax.Array, h0: jax.Array, impl: str = "auto",
               chunk: int = 64, d_block: int = 512):
    """Fused selective scan.  delta, x: [Bt, T, d]; B, C: [Bt, T, N];
    A: [d, N]; h0: [Bt, d, N] -> (y [Bt, T, d], hT [Bt, d, N])."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "pallas":
        return mamba_scan_pallas(delta, x, B, C, A, h0, chunk=chunk,
                                 d_block=d_block)
    if impl == "interpret":
        return mamba_scan_pallas(delta, x, B, C, A, h0, chunk=chunk,
                                 d_block=d_block, interpret=True)
    return mamba_scan_ref(delta, x, B, C, A, h0)
