"""Serving launcher — batched request serving with donated KV caches.

The serving loop is the paper's pipeline applied to inference: requests are
staged in a bounded queue (BlockingQueue(m')), prefill builds the shared
cache, and each decode step reuses the donated cache buffer in place (the
shared caching scheme at the HBM level — no per-token copy).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --smoke \
      --requests 8 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import queue
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models.layers import NO_RULES
from ..models.transformer import (decode_step, forward_prefill, grow_cache,
                                  init_params)
from ..train.serve_step import sample_token


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [prompt_len] int32
    max_new: int
    out_tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0


class BatchedServer:
    """Static-batch server: groups up to ``batch`` same-length requests,
    prefills once, decodes to the longest max_new (donated cache)."""

    def __init__(self, cfg, params=None, batch: int = 8, rules=NO_RULES,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.rules = rules
        self.batch = batch
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.params = (params if params is not None
                       else init_params(cfg, jax.random.PRNGKey(0)))
        self._prefill = jax.jit(
            lambda p, b: forward_prefill(p, b, cfg, rules))
        self._decode = jax.jit(
            lambda p, c, b: decode_step(p, c, b, cfg, rules),
            donate_argnums=(1,))
        self.stats: Dict[str, float] = {"prefills": 0, "decode_steps": 0}

    def serve_batch(self, requests: List[Request]) -> List[Request]:
        assert len(requests) <= self.batch
        prompts = np.stack([r.prompt for r in requests])
        max_new = max(r.max_new for r in requests)
        batch = {"tokens": jnp.asarray(prompts)}
        logits, cache = self._prefill(self.params, batch)
        cache = grow_cache(cache, self.cfg, prompts.shape[1] + max_new)
        self.stats["prefills"] += 1
        tok = sample_token(logits, self.key, self.temperature)
        for i, r in enumerate(requests):
            r.out_tokens.append(int(tok[i, 0]))
        for step in range(max_new - 1):
            self.key = jax.random.fold_in(self.key, step)
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok})
            self.stats["decode_steps"] += 1
            tok = sample_token(logits, self.key, self.temperature)
            for i, r in enumerate(requests):
                if len(r.out_tokens) < r.max_new:
                    r.out_tokens.append(int(tok[i, 0]))
        now = time.time()
        for r in requests:
            r.t_done = now
        return requests

    def run(self, requests: List[Request]) -> List[Request]:
        """Admission control: bounded wave scheduling over the request list
        (groups of ``batch``) — the task planner over request waves."""
        done: List[Request] = []
        for i in range(0, len(requests), self.batch):
            wave = requests[i: i + self.batch]
            done.extend(self.serve_batch(wave))
        return done


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new=args.max_new, t_submit=time.time())
            for i in range(args.requests)]
    server = BatchedServer(cfg, batch=args.batch,
                           temperature=args.temperature)
    t0 = time.time()
    done = server.run(reqs)
    wall = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {wall:.2f}s "
          f"({n_tok/wall:.1f} tok/s); "
          f"prefills={server.stats['prefills']:.0f} "
          f"decode_steps={server.stats['decode_steps']:.0f}")


if __name__ == "__main__":
    main()
