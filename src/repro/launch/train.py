"""Training launcher — the end-to-end driver wiring every substrate layer:

  ETL input pipeline (core engine, shared caches, Algorithm-2 prefetch)
    -> jit'd train_step (microbatch splits, donation, sharded params)
    -> CheckpointManager (async, atomic, keep-k) + StragglerWatchdog
    -> ElasticRunner (restore-and-continue on failure)

On this CPU container it runs the smoke configs end-to-end (examples/ use
it); on a TPU pod the same driver runs the full configs — the mesh comes
from make_production_mesh() and every sharding flows from configs/sharding
rules, so nothing changes but --mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
      --steps 50 --batch 8 --seq-len 128
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..data import InputPipeline, PipelineConfig, PrefetchQueue, make_lm_batch_fn
from ..models.transformer import init_params
from ..train.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from ..train.fault import StragglerWatchdog
from ..train.optimizer import OptConfig, init_opt_state
from ..train.train_step import make_train_step
from ..models.layers import NO_RULES


def build_state(cfg, seed: int = 0):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params, cfg)
    return params, opt_state


def train_loop(cfg, *, steps: int, batch: int, seq_len: int,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
               resume: bool = False, log_every: int = 10,
               prefetch_depth: int = 2, seed: int = 0,
               rules=NO_RULES) -> Dict[str, Any]:
    """Returns {'losses': [...], 'steps_done': n, 'tokens_per_s': float}."""
    ocfg = OptConfig(total_steps=max(steps, 2), warmup_steps=max(steps // 10, 1))
    step_fn = jax.jit(make_train_step(cfg, ocfg, rules), donate_argnums=(0, 1))

    params, opt_state = build_state(cfg, seed)
    start_step = 0
    manager = None
    if ckpt_dir:
        manager = CheckpointManager(ckpt_dir, every_steps=ckpt_every, keep=3)
        if resume and latest_step(ckpt_dir) is not None:
            state, meta = restore_checkpoint(
                ckpt_dir, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = int(meta["step"])
            print(f"resumed from step {start_step}")

    pc = PipelineConfig(seq_len=seq_len, global_batch=batch,
                        vocab_size=cfg.vocab_size,
                        docs_per_window=max(batch * 16, 512),
                        prefetch_depth=prefetch_depth, seed=seed)
    to_model = make_lm_batch_fn(cfg)
    feed = PrefetchQueue(iter(InputPipeline(pc)), depth=pc.prefetch_depth,
                         stage_fn=lambda blk: jax.device_put(to_model(blk)))

    watchdog = StragglerWatchdog(window=16, threshold=3.0)
    losses = []
    t_start = time.time()
    for step in range(start_step, steps):
        t0 = time.time()
        mb = next(feed)
        params, opt_state, metrics = step_fn(params, opt_state, mb)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt_step = time.time() - t0
        watchdog.observe(step, dt_step)
        if manager is not None:
            manager.maybe_save(step + 1,
                               {"params": params, "opt": opt_state},
                               extra_meta={"arch": cfg.name})
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"{dt_step*1e3:.0f} ms")
    feed.close()
    if manager is not None:
        manager.maybe_save(steps, {"params": params, "opt": opt_state},
                           extra_meta={"arch": cfg.name}, force=True)
        manager.wait()
    wall = time.time() - t_start
    done = steps - start_step
    return {"losses": losses, "steps_done": done,
            "tokens_per_s": done * batch * seq_len / max(wall, 1e-9),
            "straggler_events": len(watchdog.events),
            "params": params, "opt_state": opt_state}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.batch % max(cfg.grad_accum, 1):
        cfg = cfg.replace(grad_accum=1)
    res = train_loop(cfg, steps=args.steps, batch=args.batch,
                     seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                     resume=args.resume, seed=args.seed)
    print(f"done: {res['steps_done']} steps, "
          f"{res['tokens_per_s']:.0f} tok/s, "
          f"loss {res['losses'][0]:.4f} -> {res['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
