"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — jax locks the device count on first backend init,
and only dryrun.py (which sets XLA_FLAGS first) may ask for 512 host devices.
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Slices jax.devices() to the mesh size so the single-pod mesh builds even
    when dryrun.py forced 512 placeholder devices."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            f"run via launch/dryrun.py (it forces 512 host devices)")
    from .jax_compat import axis_types_kwargs
    return jax.make_mesh(shape, axes, devices=devices[:n],
                         **axis_types_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: Optional[int] = 1):
    """Small mesh over however many local devices exist (tests/examples).

    ``model=None`` builds a data-only 1-axis ``(data,)`` mesh — the shape the
    sharded-execution mesh route needs on single-device CPU CI, where asking
    for a phantom model axis would double the device requirement."""
    from .jax_compat import make_mesh
    if model is None:
        shape, axes = (data,), ("data",)
    else:
        shape, axes = (data, model), ("data", "model")
    n = 1
    for s in shape:
        n *= s
    if n > len(jax.devices()):
        raise ValueError(f"need {n} devices, have {len(jax.devices())}")
    return make_mesh(shape, axes, devices=jax.devices()[:n])


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
