"""Trip-count-aware cost walker over compiled (post-SPMD) HLO text.

XLA's built-in HloCostAnalysis counts a while-loop body ONCE, which
undercounts scanned programs (layer scan x microbatch scan) by orders of
magnitude and misses the collectives inside the loops.  This walker parses
the optimized HLO text, builds a per-computation symbol table of instruction
shapes, and computes

    flops(comp)      — dots/convs at 2*M*N*K, elementwise at 1/element,
                       fusions recurse into the called computation,
                       while loops multiply body+cond by the trip count
                       (read from the loop-bound constant in the condition);
    hbm_bytes(comp)  — operand+result sizes of every non-control instruction
                       at fusion granularity (fusion internals don't touch
                       HBM); dynamic-update-slice counts 2x the update slice
                       (in-place semantics), not the full buffer;
    collectives      — per-kind operand bytes AND ring-model wire bytes
                       (all-reduce 2(g-1)/g, all-gather/all-to-all (g-1)/g,
                       reduce-scatter (g-1)x result), trip-multiplied.

All numbers are PER DEVICE: the parsed module is the partitioned program.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"          # name
    r"((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s+"  # shape
    r"([\w\-]+)\("                                   # opcode
)
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")
_CONST_INT_RE = re.compile(r"=\s*s(?:32|64)\[\]\s+constant\((\d+)\)")
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DIMLABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->")
_FEATURE_GROUPS_RE = re.compile(r"feature_group_count=(\d+)")

_CONTROL_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "partition-id", "replica-id",
                "iota", "rng-bit-generator", "rng",
                "custom-call", "infeed", "outfeed", "domain",
                "opt-barrier"}

_TRANSCENDENTAL = {"exponential", "tanh", "log", "power", "divide", "sqrt",
                   "rsqrt", "sine", "cosine", "logistic", "expm1", "log1p",
                   "atan2", "erf", "cbrt", "exponential-minus-one"}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) of a shape string (tuples summed)."""
    elems = 0
    byts = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


@dataclass
class CollectiveTotals:
    operand_bytes: Dict[str, float] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, float] = field(default_factory=dict)

    def add(self, kind: str, operand_b: float, wire_b: float,
            mult: float) -> None:
        self.operand_bytes[kind] = (self.operand_bytes.get(kind, 0.0)
                                    + operand_b * mult)
        self.wire_bytes[kind] = self.wire_bytes.get(kind, 0.0) + wire_b * mult
        self.counts[kind] = self.counts.get(kind, 0.0) + mult

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: CollectiveTotals = field(default_factory=CollectiveTotals)
    while_trip_counts: List[int] = field(default_factory=list)


def parse_computations(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
                comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, opcode = m.group(1), m.group(2), m.group(3)
        rest = line[m.end():]
        # operand list = refs before the closing paren of the call
        depth = 1
        i = 0
        while i < len(rest) and depth > 0:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        opnd_str, attrs = rest[: i - 1], rest[i:]
        instr = Instr(name, shape, opcode,
                      _OPERAND_RE.findall(opnd_str), attrs, line)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _operand_shape(comp: Computation, ref: str) -> str:
    ins = comp.by_name.get(ref)
    return ins.shape if ins is not None else ""


def _trip_count(cond: Computation) -> int:
    """Loop bound = the largest integer constant in the condition region
    (JAX counter loops compare the induction var against the bound)."""
    best = 1
    for ins in cond.instrs:
        m = _CONST_INT_RE.search(ins.line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _while_trip(walker: "HloCostWalker", ins: Instr) -> int:
    """Trip count of a while instruction: prefer XLA's own
    backend_config known_trip_count; fall back to the condition constant."""
    m = _KNOWN_TRIP_RE.search(ins.attrs)
    if m:
        return int(m.group(1))
    cond = _COND_RE.search(ins.attrs)
    if cond and cond.group(1) in walker.comps:
        return _trip_count(walker.comps[cond.group(1)])
    return 1


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems, _ = shape_elems_bytes(ins.shape)
    lhs_shape = _operand_shape(comp, ins.operands[0]) if ins.operands else ""
    m = _CONTRACT_RE.search(ins.attrs)
    k = 1
    if m and lhs_shape:
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _conv_flops(comp: Computation, ins: Instr) -> float:
    out_elems, _ = shape_elems_bytes(ins.shape)
    rhs_shape = (_operand_shape(comp, ins.operands[1])
                 if len(ins.operands) > 1 else "")
    m = _DIMLABELS_RE.search(ins.attrs)
    if not (m and rhs_shape):
        return 2.0 * out_elems
    labels = m.group(2)             # e.g. '0io' / '01io'
    sm = _SHAPE_RE.search(rhs_shape)
    dims = [int(d) for d in sm.group(2).split(",") if d] if sm else []
    spatial = 1
    cin = 1
    for ch, d in zip(labels, dims):
        if ch.isdigit():
            spatial *= d
        elif ch == "i":
            cin = d
    return 2.0 * out_elems * spatial * cin


def _group_size(ins: Instr, n_partitions: int) -> int:
    m = _GROUPS_BRACKET_RE.search(ins.attrs)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACE_RE.search(ins.attrs)
    if m:
        ids = [x for x in m.group(1).split(",") if x]
        return max(1, len(ids))
    return max(1, n_partitions)


def _bf16_native_factor(comp: Computation, ins: Instr) -> float:
    """0.5 when an f32 collective's payload is really bf16 data upcast by
    XLA CPU's float-normalization (bf16 has no CPU ALUs) — TPU would run the
    collective natively in bf16 at half the bytes.

    Detected from either side:
      producer: convert(bf16->f32) (or a wrapped-convert fusion) feeds it;
      consumer: its result is immediately converted/narrowed back to bf16.
    """
    if not ins.shape.startswith("f32") or not ins.operands:
        return 1.0
    src = comp.by_name.get(ins.operands[0])
    if src is not None:
        if src.opcode == "convert" and src.operands:
            orig = comp.by_name.get(src.operands[0])
            if orig is not None and orig.shape.startswith("bf16"):
                return 0.5
        if src.opcode == "fusion" and "convert" in src.name:
            for ref in src.operands:
                o = comp.by_name.get(ref)
                if o is not None and o.shape.startswith("bf16"):
                    return 0.5
    # consumer side: f32 result only used as bf16
    consumers = [c for c in comp.instrs if ins.name in c.operands]
    if consumers and all(
            (c.opcode == "convert" and c.shape.startswith("bf16"))
            or (c.opcode == "fusion" and "convert" in c.name
                and c.shape.startswith("bf16"))
            for c in consumers):
        return 0.5
    return 1.0


def _consumed_slice_only(walker, comp: Computation, ins: Instr,
                         depth: int = 0) -> bool:
    """True if ``ins``'s value is only ever consumed through slices — the
    all-reduce + dynamic-slice pattern TPU's ReduceScatterCreator rewrites
    to a true reduce-scatter.  Follows get-tuple-element and fusion
    parameters one level deep."""
    if depth > 3:
        return False
    consumers = [c for c in comp.instrs if ins.name in c.operands]
    if not consumers:
        return False
    for c in consumers:
        if c.opcode == "dynamic-slice":
            continue
        if c.opcode == "get-tuple-element":
            if not _consumed_slice_only(walker, comp, c, depth + 1):
                return False
            continue
        if c.opcode == "fusion" and walker is not None:
            m = _CALLS_RE.search(c.attrs)
            called = walker.comps.get(m.group(1)) if m else None
            if called is None:
                return False
            ok = True
            for i, ref in enumerate(c.operands):
                if ref != ins.name:
                    continue
                pname = None
                for inner in called.instrs:
                    if inner.opcode == "parameter" and \
                            f"parameter({i})" in inner.line:
                        pname = inner.name
                        break
                if pname is None:
                    ok = False
                    break
                # chase CPU-legalization convert/bitcast/copy chains before
                # requiring the slice
                frontier = [pname]
                hops = 0
                found_slice = False
                while frontier and hops < 8:
                    hops += 1
                    nxt = []
                    for fn_ in frontier:
                        cons_ = [x for x in called.instrs
                                 if fn_ in x.operands]
                        if not cons_:
                            ok = False
                            break
                        for x in cons_:
                            if x.opcode == "dynamic-slice":
                                found_slice = True
                            elif x.opcode in ("convert", "bitcast", "copy"):
                                nxt.append(x.name)
                            else:
                                ok = False
                                break
                        if not ok:
                            break
                    if not ok:
                        break
                    frontier = nxt
                if not ok or not found_slice:
                    ok = False
                    break
            if not ok:
                return False
            continue
        return False
    return True


def _collective_cost(comp: Computation, ins: Instr, kind: str,
                     n_partitions: int, assume_bf16: bool = False,
                     walker=None) -> Tuple[float, float]:
    """-> (operand_bytes, ring wire_bytes) per device for one execution.

    ``assume_bf16``: the model's params/compute/grads are all bf16 (grok,
    jamba) — every f32 collective in the CPU-legalized module is an upcast
    artifact; TPU moves half the bytes."""
    _, out_b = shape_elems_bytes(ins.shape)
    factor = _bf16_native_factor(comp, ins)
    if factor == 1.0 and assume_bf16 and ins.shape.startswith("f32"):
        factor = 0.5
    if factor == 1.0 and walker is not None \
            and walker.activation_leading_dim is not None \
            and ins.shape.startswith("f32"):
        # activation-shaped f32 payload (leading dim = microbatch): the
        # model computes these in bf16; the f32 width is CPU legalization
        m_ = _SHAPE_RE.search(ins.shape)
        if m_:
            dims = [int(d) for d in m_.group(2).split(",") if d]
            if len(dims) >= 3 and dims[0] == walker.activation_leading_dim:
                factor = 0.5
    out_b *= factor
    g = _group_size(ins, n_partitions)
    if kind == "all-gather":
        op_b = out_b / g
        wire = out_b * (g - 1) / g
    elif kind == "all-reduce":
        op_b = out_b
        # CPU GSPMD lowers a sharded reduction as all-reduce + dynamic-slice;
        # TPU's ReduceScatterCreator pass rewrites that pair to a true
        # reduce-scatter at HALF the wire bytes — price it as RS when a
        # result (or tuple element) is only consumed through slices.
        if ins.shape.startswith("(") and walker is not None:
            elem_sizes = [shape_elems_bytes(f"{dt_}[{dims}]")[1]
                          for dt_, dims in _SHAPE_RE.findall(ins.shape)]
            bf = (out_b / sum(elem_sizes)) if sum(elem_sizes) else 1.0
            gtes = [(c, int(re.search(r"index=(\d+)", c.attrs).group(1)))
                    for c in comp.instrs
                    if c.opcode == "get-tuple-element"
                    and ins.name in c.operands
                    and re.search(r"index=(\d+)", c.attrs)]
            wire = 0.0
            for c, idx in gtes:
                if idx < len(elem_sizes):
                    f_ = (1.0 if _consumed_slice_only(walker, comp, c)
                          else 2.0)
                    wire += f_ * elem_sizes[idx] * bf * (g - 1) / g
        else:
            slice_only = _consumed_slice_only(walker, comp, ins)
            wire = (1.0 if slice_only else 2.0) * out_b * (g - 1) / g
    elif kind == "reduce-scatter":
        op_b = out_b * g
        wire = out_b * (g - 1)
    elif kind == "all-to-all":
        op_b = out_b
        wire = out_b * (g - 1) / g
    else:  # collective-permute
        op_b = out_b
        wire = out_b
    return op_b, wire


class HloCostWalker:
    def __init__(self, hlo_text: str, n_partitions: int,
                 assume_bf16: bool = False,
                 activation_leading_dim: Optional[int] = None):
        """``activation_leading_dim``: per-device microbatch size — f32
        collectives whose first dim equals it (rank>=3) carry bf16
        activations upcast by CPU float-normalization; price at bf16."""
        self.comps = parse_computations(hlo_text)
        self.n_partitions = n_partitions
        self.assume_bf16 = assume_bf16
        self.activation_leading_dim = activation_leading_dim
        self._flops_cache: Dict[str, float] = {}
        self._bytes_cache: Dict[str, float] = {}
        self.cost = HloCost()

    # ------------------------------------------------------------- flops
    def comp_flops(self, name: str) -> float:
        if name in self._flops_cache:
            return self._flops_cache[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0
        self._flops_cache[name] = 0.0          # cycle guard
        total = 0.0
        for ins in comp.instrs:
            total += self.instr_flops(comp, ins)
        self._flops_cache[name] = total
        return total

    def instr_flops(self, comp: Computation, ins: Instr) -> float:
        op = ins.opcode
        if op in _CONTROL_OPS or op.endswith("-done"):
            return 0.0
        if op == "while":
            body = _BODY_RE.search(ins.attrs)
            cond = _COND_RE.search(ins.attrs)
            trip = _while_trip(self, ins)
            self.cost.while_trip_counts.append(trip)
            sub = 0.0
            if body:
                sub += self.comp_flops(body.group(1))
            if cond:
                sub += self.comp_flops(cond.group(1))
            return trip * sub
        if op == "fusion":
            m = _CALLS_RE.search(ins.attrs)
            return self.comp_flops(m.group(1)) if m else 0.0
        if op in ("call", "async-start"):
            m = _TO_APPLY_RE.search(ins.attrs) or _CALLS_RE.search(ins.attrs)
            return self.comp_flops(m.group(1)) if m else 0.0
        if op == "conditional":
            flops = [self.comp_flops(c)
                     for c in re.findall(r"%([\w.\-]+)", ins.attrs)
                     if c in self.comps]
            return max(flops) if flops else 0.0
        if op == "dot":
            return _dot_flops(comp, ins)
        if op == "convolution":
            return _conv_flops(comp, ins)
        if op in ("reduce", "reduce-window"):
            in_elems = sum(shape_elems_bytes(_operand_shape(comp, o))[0]
                           for o in ins.operands[:1])
            return float(in_elems)
        out_elems, _ = shape_elems_bytes(ins.shape)
        if op in _TRANSCENDENTAL:
            return float(out_elems)
        if op in ("add", "subtract", "multiply", "maximum", "minimum",
                  "and", "or", "xor", "select", "compare", "clamp",
                  "negate", "abs", "floor", "ceil", "round-nearest-afz",
                  "round-nearest-even", "sign", "not"):
            return float(out_elems)
        return 0.0

    # ------------------------------------------------------------- bytes
    def comp_bytes(self, name: str) -> float:
        if name in self._bytes_cache:
            return self._bytes_cache[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0
        self._bytes_cache[name] = 0.0
        total = 0.0
        for ins in comp.instrs:
            total += self.instr_bytes(comp, ins)
        self._bytes_cache[name] = total
        return total

    def instr_bytes(self, comp: Computation, ins: Instr) -> float:
        op = ins.opcode
        if op in _CONTROL_OPS or op.endswith("-done"):
            return 0.0
        if op == "while":
            body = _BODY_RE.search(ins.attrs)
            cond = _COND_RE.search(ins.attrs)
            trip = _while_trip(self, ins)
            sub = 0.0
            if body:
                sub += self.comp_bytes(body.group(1))
            if cond:
                sub += self.comp_bytes(cond.group(1))
            return trip * sub
        if op == "conditional":
            byts = [self.comp_bytes(c)
                    for c in re.findall(r"%([\w.\-]+)", ins.attrs)
                    if c in self.comps]
            return max(byts) if byts else 0.0
        if op == "call":
            m = _TO_APPLY_RE.search(ins.attrs) or _CALLS_RE.search(ins.attrs)
            return self.comp_bytes(m.group(1)) if m else 0.0
        if op == "dynamic-update-slice":
            # in-place: traffic = 2x the update slice, not the full buffer
            upd = (_operand_shape(comp, ins.operands[1])
                   if len(ins.operands) > 1 else ins.shape)
            _, ub = shape_elems_bytes(upd)
            return 2.0 * ub
        if op == "convert":
            # dtype converts are fused into consumers on TPU (free); on CPU
            # they materialize as f32-legalization twins of bf16 buffers
            return 0.0
        if op in ("dynamic-slice", "gather"):
            # reads only the sliced/gathered rows, not the full operand
            _, out_b = shape_elems_bytes(ins.shape)
            return 2.0 * out_b
        if op == "scatter":
            upd = (_operand_shape(comp, ins.operands[2])
                   if len(ins.operands) > 2 else ins.shape)
            _, ub = shape_elems_bytes(upd)
            return 3.0 * ub          # read update + read/write target slices
        if op == "fusion":
            return self._fusion_bytes(comp, ins)
        # dot/conv/copy/collective/...: operands + result
        _, out_b = shape_elems_bytes(ins.shape)
        in_b = sum(shape_elems_bytes(_operand_shape(comp, o))[1]
                   for o in ins.operands)
        return float(in_b + out_b)

    def _fusion_bytes(self, comp: Computation, ins: Instr) -> float:
        """HBM traffic of one fusion: result + actually-read operand bytes.

        A fusion parameter consumed ONLY by dynamic-slice/gather reads just
        the sliced rows (the scan-xs access pattern), not the whole buffer;
        a parameter feeding the root dynamic-update-slice as the target
        buffer is updated in place (0 read, the written slice is counted via
        the root).  Everything else reads fully.
        """
        m = _CALLS_RE.search(ins.attrs)
        called = self.comps.get(m.group(1)) if m else None
        _, out_b = shape_elems_bytes(ins.shape)
        if called is None:
            in_b = sum(shape_elems_bytes(_operand_shape(comp, o))[1]
                       for o in ins.operands)
            return float(in_b + out_b)
        # pure-convert fusion (wrapped_convert_computation): free on TPU
        body_ops = [i.opcode for i in called.instrs
                    if i.opcode not in ("parameter", "constant")]
        if body_ops and all(o in ("convert", "copy", "bitcast", "tuple",
                                  "get-tuple-element") for o in body_ops):
            return 0.0
        # map parameter index -> inner name
        param_name: Dict[int, str] = {}
        for inner in called.instrs:
            if inner.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", inner.line)
                if pm:
                    param_name[int(pm.group(1))] = inner.name
        root = None
        for inner in called.instrs:
            if "ROOT" in inner.line:
                root = inner
                break
        if root is None and called.instrs:
            root = called.instrs[-1]

        def _chase(ins_):
            # follow convert/bitcast/copy chains (CPU bf16-legalization wraps)
            seen_ = 0
            while (ins_ is not None and seen_ < 8
                   and ins_.opcode in ("convert", "bitcast", "copy")
                   and ins_.operands):
                ins_ = called.by_name.get(ins_.operands[0])
                seen_ += 1
            return ins_

        rooted = _chase(root)
        root_is_dus = rooted is not None and \
            rooted.opcode == "dynamic-update-slice"
        dus_target = None
        if root_is_dus and rooted.operands:
            tgt = _chase(called.by_name.get(rooted.operands[0]))
            if tgt is not None and tgt.opcode == "parameter":
                dus_target = tgt.name
        if root_is_dus and len(rooted.operands) > 1:
            _, ub = shape_elems_bytes(
                _operand_shape(called, rooted.operands[1]))
            out_b = 2.0 * ub         # in-place: write+read of the slice only
        total = float(out_b)
        for i, outer_ref in enumerate(ins.operands):
            pname = param_name.get(i)
            if pname is None:
                continue
            consumers = [c for c in called.instrs if pname in c.operands]
            if pname == dus_target and len(consumers) == 1:
                continue             # aliased in-place target: no read
            if consumers and all(c.opcode in ("dynamic-slice", "gather")
                                 for c in consumers):
                total += sum(shape_elems_bytes(c.shape)[1]
                             for c in consumers)
                continue
            total += shape_elems_bytes(_operand_shape(comp, outer_ref))[1]
        return total

    # ------------------------------------------------------- collectives
    def _walk_collectives(self, name: str, mult: float,
                          seen_stack: Tuple[str, ...] = ()) -> None:
        comp = self.comps.get(name)
        if comp is None or name in seen_stack:
            return
        for ins in comp.instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVE_KINDS:
                op_b, wire = _collective_cost(comp, ins, base,
                                              self.n_partitions,
                                              self.assume_bf16, self)
                self.cost.collectives.add(base, op_b, wire, mult)
            elif op == "while":
                body = _BODY_RE.search(ins.attrs)
                trip = _while_trip(self, ins)
                if body:
                    self._walk_collectives(body.group(1), mult * trip,
                                           seen_stack + (name,))
            elif op == "fusion":
                m = _CALLS_RE.search(ins.attrs)
                if m:
                    self._walk_collectives(m.group(1), mult,
                                           seen_stack + (name,))
            elif op in ("call", "conditional"):
                for c in re.findall(r"%([\w.\-]+)", ins.attrs):
                    if c in self.comps:
                        self._walk_collectives(c, mult, seen_stack + (name,))

    # -------------------------------------------------------------- run
    def run(self) -> HloCost:
        self.cost.flops = self.comp_flops("__entry__")
        self.cost.hbm_bytes = self.comp_bytes("__entry__")
        self._walk_collectives("__entry__", 1.0)
        return self.cost


def analyze_hlo_text(hlo_text: str, n_partitions: int,
                     assume_bf16: bool = False,
                     activation_leading_dim: Optional[int] = None) -> HloCost:
    return HloCostWalker(hlo_text, n_partitions, assume_bf16,
                         activation_leading_dim).run()


def cpu_bf16_inflation_bytes(hlo_text: str) -> int:
    """XLA's CPU backend has no bf16 ALUs: the float-normalization pass
    rewrites bf16 arithmetic to f32, materializing f32 twins of bf16 buffers
    (converts + f32 while-carry copies) that DO NOT exist on TPU.  Estimate
    the inflation as the total size of f32 buffers produced by
    convert(bf16 -> f32) with identical dims — subtracting this from the CPU
    temp size approximates the TPU temp footprint.
    """
    comps = parse_computations(hlo_text)
    total = 0
    seen = set()
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        for ins in comp.instrs:
            if ins.opcode != "convert" or not ins.shape.startswith("f32"):
                continue
            src = comp.by_name.get(ins.operands[0]) if ins.operands else None
            src_shape = src.shape if src is not None else ""
            if not src_shape.startswith("bf16"):
                continue
            m_out = _SHAPE_RE.search(ins.shape)
            m_in = _SHAPE_RE.search(src_shape)
            if m_out and m_in and m_out.group(2) == m_in.group(2):
                key = (name, ins.name)
                if key not in seen:
                    seen.add(key)
                    total += shape_elems_bytes(ins.shape)[1]
    return total
