"""Compatibility shims for jax API drift.

``axis_types=`` on ``jax.make_mesh`` and ``jax.set_mesh`` landed after the
0.4.x series; this repo must run both on the container's pinned jax and on
current releases installed by CI, so mesh construction goes through these
helpers instead of the raw API.
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax

_make_mesh = getattr(jax, "make_mesh", None)     # absent before jax 0.4.35
HAS_AXIS_TYPES = (
    _make_mesh is not None
    and "axis_types" in inspect.signature(_make_mesh).parameters
    and hasattr(jax.sharding, "AxisType"))


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` where supported, ``{}`` otherwise."""
    if HAS_AXIS_TYPES:
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_mesh(shape: Sequence[int], axes: Sequence[str], devices=None):
    """``jax.make_mesh`` with Auto axis types when the API supports them;
    falls back to ``jax.sharding.Mesh`` over a device grid on older jax."""
    axes = tuple(axes)
    if _make_mesh is not None:
        return _make_mesh(tuple(shape), axes, devices=devices,
                          **axis_types_kwargs(len(axes)))
    import numpy as np
    devices = list(devices) if devices is not None else jax.devices()
    n = 1
    for s in shape:
        n *= s
    grid = np.array(devices[:n]).reshape(tuple(shape))
    return jax.sharding.Mesh(grid, axes)


def set_mesh(mesh):
    """``jax.set_mesh(mesh)`` where available; otherwise the mesh's own
    context manager (sufficient for jit-with-NamedSharding paths)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """``jax.shard_map`` (new API, ``check_vma=``) falling back to
    ``jax.experimental.shard_map`` (old API, ``check_rep=``)."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
