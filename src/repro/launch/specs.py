"""ShapeDtypeStruct stand-ins + PartitionSpecs for every dry-run cell.

No device allocation happens here: params/opt-state/caches/batches are all
ShapeDtypeStructs fed to jax.jit(...).lower() (the shannon/kernels pattern).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models.layers import Rules
from ..models.transformer import make_cache_shapes, param_shapes, param_specs
from ..train.optimizer import opt_state_shapes, opt_state_specs
from ..train.sharding import make_rules


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


def limit_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes from dims they do not divide (NamedSharding rejects
    uneven in_shardings — e.g. hubert's vocab=504 over model=16)."""
    dims = tuple(shape.shape) if hasattr(shape, "shape") else tuple(shape)
    entries = list(spec) + [None] * (len(dims) - len(spec))
    out = []
    for d, e in zip(dims, entries):
        out.append(e if d % _axis_size(mesh, e) == 0 else None)
    return P(*out)


def limit_specs_tree(spec_tree, shape_tree, mesh):
    return jax.tree.map(lambda s, sh: limit_spec(s, sh, mesh),
                        spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for one cell (train/prefill: the full
    window; decode: one new token against a seq_len cache)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        S_in = 1
    else:
        S_in = S
    out: Dict[str, Any] = {}
    if cfg.family == "audio":
        # modality frontend is a STUB: precomputed frame embeddings
        out["frames"] = jax.ShapeDtypeStruct((B, S_in, cfg.d_model),
                                             jnp.dtype(cfg.compute_dtype))
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, S_in), jnp.int32)
        return out
    out["tokens"] = jax.ShapeDtypeStruct((B, S_in), jnp.int32)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["vision"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vision_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return out


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, rules: Rules
                 ) -> Dict[str, Any]:
    specs: Dict[str, Any] = {}
    for name in batch_shapes(cfg, shape):
        if name in ("tokens", "labels"):
            specs[name] = rules.spec("batch", None)
        else:                                    # frames / vision: [B, T, d]
            specs[name] = rules.spec("batch", None, None)
    return specs


def kv_repeat_for(cfg: ModelConfig, model_n: int) -> int:
    """TP kv-head replication factor: smallest r with (kh*r) % model_n == 0
    and h % (kh*r) == 0 (query regrouping must stay even).  1 if none."""
    kh, h = cfg.n_kv_heads, cfg.n_heads
    if not kh or not h or kh % model_n == 0:
        return 1
    if model_n % kh == 0:
        r = model_n // kh
        if h % (kh * r) == 0:
            return r
    return 1


def cell_specs(cfg: ModelConfig, shape: ShapeConfig, mesh
               ) -> Dict[str, Any]:
    """Everything dryrun/train/serve need for one (arch x shape x mesh) cell:
    shapes (ShapeDtypeStruct trees) + shardings (NamedSharding trees).
    NOTE: returns the possibly-updated cfg under 'cfg' (kv_repeat applied) —
    callers must use it for the model functions."""
    r = kv_repeat_for(cfg, mesh.shape.get("model", 1))
    if r > 1:
        cfg = cfg.replace(kv_repeat=r)
    profile = shape.kind
    if shape.kind == "decode" and shape.seq_len >= 262_144:
        profile = "long"
    rules = make_rules(mesh, profile, cfg)
    ns = lambda spec: NamedSharding(mesh, spec)

    p_shapes = param_shapes(cfg)
    p_spec = limit_specs_tree(param_specs(cfg, rules), p_shapes, mesh)
    p_shard = jax.tree.map(ns, p_spec, is_leaf=lambda x: isinstance(x, P))

    b_shapes = batch_shapes(cfg, shape)
    b_spec = limit_specs_tree(batch_pspecs(cfg, shape, rules), b_shapes, mesh)
    out: Dict[str, Any] = {
        "cfg": cfg,
        "rules": rules,
        "profile": profile,
        "param_shapes": p_shapes,
        "param_specs": p_spec,
        "param_shardings": p_shard,
        "batch_shapes": b_shapes,
        "batch_shardings": jax.tree.map(ns, b_spec,
                                        is_leaf=lambda x: isinstance(x, P)),
    }
    if shape.kind == "train":
        out["opt_shapes"] = opt_state_shapes(p_shapes, cfg)
        opt_spec = limit_specs_tree(opt_state_specs(p_spec),
                                    out["opt_shapes"], mesh)
        out["opt_shardings"] = jax.tree.map(
            ns, opt_spec, is_leaf=lambda x: isinstance(x, P))
    if shape.kind == "decode":
        out["cache_shapes"] = make_cache_shapes(
            cfg, shape.global_batch, shape.seq_len, rules)
        cache_spec = limit_specs_tree(
            make_cache_shapes(cfg, shape.global_batch, shape.seq_len, rules,
                              as_spec=True),
            out["cache_shapes"], mesh)
        out["cache_shardings"] = jax.tree.map(
            ns, cache_spec, is_leaf=lambda x: isinstance(x, P))
    return out
