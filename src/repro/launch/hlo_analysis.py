"""Roofline-term extraction from compiled dry-run artifacts.

XLA's cost_analysis() counts while-loop bodies ONCE — useless for scanned
programs (layer scan x microbatch scan undercount ~500x) — and collective
bytes are not in cost_analysis at all.  Both come from the trip-count-aware
HLO walker in hlo_cost.py instead; the raw XLA numbers are kept in the
artifact for comparison.

Roofline terms (TPU v5e), all per-device (the parsed module is the
partitioned per-device program):
    compute    = flops_per_device / 197e12
    memory     = hbm_bytes_per_device / 819e9
    collective = collective_wire_bytes_per_device / 50e9
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from .hlo_cost import HloCost, analyze_hlo_text
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float          # ring-model wire bytes
    collective_operand_bytes_per_device: float
    collective_bytes_by_kind: Dict[str, float]
    collective_count_by_kind: Dict[str, float]
    n_devices: int
    model_flops: float = 0.0                    # 6*N_active*D global
    xla_flops: float = 0.0                      # raw cost_analysis (once-counted)
    xla_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def t_collective_latency(self) -> float:
        """Latency floor: every collective pays ~2us of ICI launch/hop
        latency regardless of payload — dominant when a program issues
        millions of tiny collectives (the SSM bwd per-step C-grad AR)."""
        n = sum(self.collective_count_by_kind.values())
        return n * 2e-6

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips) — how much compiled compute is
        'useful'; catches remat/redundancy waste."""
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOPs per device per bound-second vs peak — the MFU
        the compiled program could at best achieve (serial-term model)."""
        if self.t_bound <= 0:
            return 0.0
        useful_per_dev = self.model_flops / max(self.n_devices, 1)
        return useful_per_dev / self.t_bound / PEAK_FLOPS_BF16

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_operand_bytes_per_device":
                self.collective_operand_bytes_per_device,
            "collective_bytes_by_kind": dict(self.collective_bytes_by_kind),
            "collective_count_by_kind": dict(self.collective_count_by_kind),
            "n_devices": self.n_devices,
            "model_flops": self.model_flops,
            "xla_flops_once_counted": self.xla_flops,
            "xla_bytes_once_counted": self.xla_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_collective_latency_s": self.t_collective_latency,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze_compiled(compiled, n_devices: int,
                     model_flops: float = 0.0,
                     assume_bf16: bool = False,
                     activation_leading_dim=None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):            # older API: one dict per device
        cost = cost[0]
    hc: HloCost = analyze_hlo_text(compiled.as_text(), n_devices,
                                   assume_bf16, activation_leading_dim)
    return Roofline(
        flops_per_device=hc.flops,
        bytes_per_device=hc.hbm_bytes,
        collective_bytes_per_device=hc.collectives.total_wire_bytes,
        collective_operand_bytes_per_device=hc.collectives.total_operand_bytes,
        collective_bytes_by_kind=dict(hc.collectives.wire_bytes),
        collective_count_by_kind=dict(hc.collectives.counts),
        n_devices=n_devices,
        model_flops=model_flops,
        xla_flops=float(cost.get("flops", 0.0)),
        xla_bytes=float(cost.get("bytes accessed", 0.0)))


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D for train (fwd+bwd), 2*N*D for inference, with
    N = active params (MoE: top-k experts only) and D = tokens processed."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
