import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import — jax locks the
#   device count on first backend init.  Do NOT set this globally.

# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production meshes, print memory/cost analysis, and persist the roofline
# terms for §Roofline.
#
# This proves the distribution config is coherent without real hardware:
# sharding mismatches, OOM-at-compile and unsupported collectives all surface
# here as hard failures.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all               # single-pod
#   PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod   # 2x16x16

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from ..configs import ARCH_IDS, get_config, get_shapes
from ..models.transformer import decode_step, forward_prefill
from ..train.optimizer import OptConfig
from ..train.train_step import make_train_step
from .hlo_analysis import analyze_compiled, model_flops_for
from .mesh import make_production_mesh
from .specs import cell_specs

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")


def lower_cell(arch_id: str, shape_name: str, mesh, *,
               overrides: Optional[Dict[str, Any]] = None,
               grad_rs: bool = False):
    """Lower one cell.  Returns (lowered, cfg, shape, n_devices).
    ``grad_rs``: constrain per-microbatch grads to the param sharding
    (reduce-scatter accumulation — §Perf lever)."""
    cfg = get_config(arch_id)
    shape = get_shapes(arch_id)[shape_name]
    if overrides:
        cfg = cfg.replace(**overrides)
    specs = cell_specs(cfg, shape, mesh)
    cfg = specs["cfg"]              # kv_repeat applied for this mesh
    rules = specs["rules"]

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(
                cfg, OptConfig(), rules,
                grad_pspecs=specs["param_specs"] if grad_rs else None)
            fn = jax.jit(step,
                         in_shardings=(specs["param_shardings"],
                                       specs["opt_shardings"],
                                       specs["batch_shardings"]),
                         out_shardings=(specs["param_shardings"],
                                        specs["opt_shardings"], None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(specs["param_shapes"], specs["opt_shapes"],
                               specs["batch_shapes"])
        elif shape.kind == "prefill":
            fn = jax.jit(lambda p, b: forward_prefill(p, b, cfg, rules),
                         in_shardings=(specs["param_shardings"],
                                       specs["batch_shardings"]))
            lowered = fn.lower(specs["param_shapes"], specs["batch_shapes"])
        else:  # decode
            fn = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg, rules),
                         in_shardings=(specs["param_shardings"],
                                       specs["cache_shardings"],
                                       specs["batch_shardings"]),
                         out_shardings=(None, specs["cache_shardings"]),
                         donate_argnums=(1,))
            lowered = fn.lower(specs["param_shapes"], specs["cache_shapes"],
                               specs["batch_shapes"])
    return lowered, cfg, shape


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, save: bool = True,
             overrides: Optional[Dict[str, Any]] = None,
             grad_rs: bool = False, tag: str = "") -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    lowered, cfg, shape = lower_cell(arch_id, shape_name, mesh,
                                     overrides=overrides, grad_rs=grad_rs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    all_bf16 = (cfg.param_dtype == "bfloat16"
                and cfg.opt_state_dtype == "bfloat16"
                and cfg.compute_dtype == "bfloat16")
    # per-device microbatch size: identifies activation-shaped f32
    # collectives that run bf16-native on TPU (CPU legalization upcast)
    data_total = mesh.shape["data"] * mesh.shape.get("pod", 1)
    mb_dim = None
    if cfg.compute_dtype == "bfloat16" and not all_bf16:
        mb_dim = max(1, shape.global_batch // data_total
                     // (cfg.grad_accum if shape.kind == "train" else 1))
    roof = analyze_compiled(compiled, n_dev,
                            model_flops=model_flops_for(cfg, shape),
                            assume_bf16=all_bf16,
                            activation_leading_dim=mb_dim)
    from .hlo_cost import cpu_bf16_inflation_bytes
    bf16_infl = cpu_bf16_inflation_bytes(compiled.as_text())
    rec: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "tag": tag, "assume_bf16": all_bf16,
        "activation_leading_dim": mb_dim,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                         + mem.output_size_in_bytes
                                         + mem.temp_size_in_bytes
                                         - getattr(mem, "alias_size_in_bytes", 0)),
            # XLA CPU legalizes bf16 math to f32, materializing f32 twins of
            # bf16 buffers that do not exist on TPU; subtracting them
            # approximates the TPU temp footprint (see hlo_cost)
            "cpu_bf16_inflation_bytes": int(bf16_infl),
            "tpu_corrected_peak_bytes": int(mem.argument_size_in_bytes
                                            + mem.output_size_in_bytes
                                            + max(mem.temp_size_in_bytes
                                                  - bf16_infl, 0)
                                            - getattr(mem, "alias_size_in_bytes", 0)),
        },
        "roofline": roof.to_dict(),
    }
    if verbose:
        m = rec["memory"]
        r = rec["roofline"]
        print(f"[{mesh_name}] {arch_id} x {shape_name}"
              f"{(' [' + tag + ']') if tag else ''}")
        print(f"  lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print(f"  memory/device: args {m['argument_bytes']/2**30:.2f} GiB"
              f" + temp {m['temp_bytes']/2**30:.2f} GiB"
              f" - aliased {m['alias_bytes']/2**30:.2f} GiB"
              f" -> peak {m['peak_bytes_per_device']/2**30:.2f} GiB"
              f" (tpu-corrected {m['tpu_corrected_peak_bytes']/2**30:.2f}"
              f" GiB, HBM 16 GiB)")
        print(f"  flops/dev {r['flops_per_device']:.3e}"
              f"  bytes/dev {r['bytes_per_device']:.3e}"
              f"  coll bytes/dev {r['collective_bytes_per_device']:.3e}")
        print(f"  t_compute {r['t_compute_s']*1e3:.2f} ms"
              f"  t_memory {r['t_memory_s']*1e3:.2f} ms"
              f"  t_collective {r['t_collective_s']*1e3:.2f} ms"
              f"  -> bottleneck: {r['bottleneck']}")
        print(f"  MODEL_FLOPS/HLO_FLOPS {r['useful_flops_fraction']:.3f}"
              f"  roofline fraction {r['roofline_fraction']:.3f}")
        sys.stdout.flush()
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        base = f"{arch_id}_{shape_name}_{mesh_name}{suffix}".replace("/", "-")
        with open(os.path.join(ARTIFACT_DIR, base + ".json"), "w") as f:
            json.dump(rec, f, indent=2)
        # compressed HLO text: lets the roofline analysis be re-run after
        # hlo_cost changes without recompiling every cell
        import zstandard
        with open(os.path.join(ARTIFACT_DIR, base + ".hlo.zst"), "wb") as f:
            f.write(zstandard.ZstdCompressor(level=3).compress(
                compiled.as_text().encode()))
    return rec


def reanalyze_artifacts() -> int:
    """Recompute every saved artifact's roofline record from its stored HLO
    (after hlo_cost changes) — no recompilation."""
    import zstandard
    from .hlo_analysis import Roofline
    from .hlo_cost import analyze_hlo_text, cpu_bf16_inflation_bytes
    n = 0
    for fname in sorted(os.listdir(ARTIFACT_DIR)):
        if not fname.endswith(".json"):
            continue
        jpath = os.path.join(ARTIFACT_DIR, fname)
        hpath = jpath[:-5] + ".hlo.zst"
        if not os.path.exists(hpath):
            continue
        with open(jpath) as f:
            rec = json.load(f)
        with open(hpath, "rb") as f:
            hlo = zstandard.ZstdDecompressor().decompress(f.read()).decode()
        n_dev = rec["roofline"]["n_devices"]
        if "assume_bf16" not in rec:
            c = get_config(rec["arch"])
            rec["assume_bf16"] = (c.param_dtype == "bfloat16"
                                  and c.opt_state_dtype == "bfloat16"
                                  and c.compute_dtype == "bfloat16")
        if "activation_leading_dim" not in rec:
            c = get_config(rec["arch"])
            data_total = 16 * (2 if rec["mesh"] == "2x16x16" else 1)
            rec["activation_leading_dim"] = (
                None if rec["assume_bf16"] else
                max(1, rec["global_batch"] // data_total
                    // (c.grad_accum if rec["kind"] == "train" else 1)))
        hc = analyze_hlo_text(hlo, n_dev, assume_bf16=rec["assume_bf16"],
                              activation_leading_dim=rec[
                                  "activation_leading_dim"])
        roof = Roofline(
            flops_per_device=hc.flops,
            bytes_per_device=hc.hbm_bytes,
            collective_bytes_per_device=hc.collectives.total_wire_bytes,
            collective_operand_bytes_per_device=(
                hc.collectives.total_operand_bytes),
            collective_bytes_by_kind=dict(hc.collectives.wire_bytes),
            collective_count_by_kind=dict(hc.collectives.counts),
            n_devices=n_dev,
            model_flops=rec["roofline"]["model_flops"],
            xla_flops=rec["roofline"].get("xla_flops_once_counted", 0.0),
            xla_bytes=rec["roofline"].get("xla_bytes_once_counted", 0.0))
        rec["roofline"] = roof.to_dict()
        infl = cpu_bf16_inflation_bytes(hlo)
        m = rec["memory"]
        m["cpu_bf16_inflation_bytes"] = int(infl)
        m["tpu_corrected_peak_bytes"] = int(
            m["argument_bytes"] + m["output_bytes"]
            + max(m["temp_bytes"] - infl, 0) - m["alias_bytes"])
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=2)
        n += 1
    print(f"reanalyzed {n} artifacts")
    return n


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch_id in ARCH_IDS:
            for shape_name in get_shapes(arch_id):
                cells.append((arch_id, shape_name))
    else:
        if not args.arch:
            ap.error("--arch required unless --all")
        shapes = get_shapes(args.arch)
        names = [args.shape] if args.shape else list(shapes)
        cells = [(args.arch, s) for s in names]

    failures = []
    for arch_id, shape_name in cells:
        try:
            run_cell(arch_id, shape_name, multi_pod=args.multi_pod,
                     save=not args.no_save)
        except Exception:
            failures.append((arch_id, shape_name))
            traceback.print_exc()
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells passed"
          f" ({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'})")
    for f in failures:
        print("  FAILED:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
