# Launchers: mesh construction, dry-run (lower+compile proof), roofline
# analysis, and the train/serve drivers.
#
# NOTE: repro.launch.dryrun must be the FIRST repro import in its process —
# it sets XLA_FLAGS for 512 placeholder devices before jax initializes.
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS_BF16", "make_production_mesh"]
