"""Cost-based adaptive optimizer — statistics-driven dataflow rewriting and
re-partitioning.

The partitioner (Algorithm 1) and runtime planner run once, up front, with
static ``est_output_bytes`` guesses; a mis-estimated selectivity or a skewed
source leaves pool width, channel depths and tree cuts wrong for the whole
run.  This module closes the loop:

1. **Statistics** — ``run_calibration`` executes the flow over a small source
   prefix (separate caches, sinks suppressed) and harvests per-component
   observations: rows in/out, selectivity, per-row time, emitted cache bytes.
   ``FlowStatistics.from_flow`` harvests the same numbers from the
   instrumented counters of any prior engine run instead.

2. **Rewriting** — ``CostBasedOptimizer`` applies provably row-safe graph
   transformations whose *profitability* (never their correctness) is judged
   from the measured statistics:

   - *filter commute*: hop a row-dropping ``Filter`` ahead of an adjacent
     row-preserving component (Lookup / Expression / Converter / Project)
     when the filter's declared read set is disjoint from the neighbour's
     produced columns, so the expensive neighbour processes fewer rows;
   - *expression fusion*: collapse chains of adjacent ``Expression``
     components into one fused activity, removing per-activity
     miscellaneous time (the t0 of Theorem 1) from the pipeline;
   - *stage-boundary insert/remove*: add a ``StageBoundary`` cut where the
     observed bytes and stage times justify cross-tree overlap under the
     streaming executor, and remove an existing cut whose observed edge
     bytes no longer pay for the per-split copy.

   Each rule REFUSES when safety cannot be proven: undeclared read/write
   sets, non-row-preserving or block/semi-block neighbours, fan-in/fan-out,
   order-sensitive members, ``chunk_sensitive`` sources (whose calibration
   prefix is not representative of full-run data).

3. **Re-planning** — ``measured_edge_bytes`` projects the observed
   per-component output bytes onto the REWRITTEN flow's inter-tree edges so
   ``plan_runtime`` sizes the pool and channel depths from measurements
   instead of source-size guesses, and ``suggest_pipeline_degree`` feeds the
   observed activity times through Algorithm 3 / Theorem 1.

The engine exposes all of this as ``OptimizeOptions(optimize_level=2)``; the
metadata store records the before/after partitions, plans and the applied
rewrite list (``MetadataStore.register_adaptive``).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .component import ComponentType, SourceComponent
from .graph import Dataflow
from .partitioner import ExecutionTreeGraph
from .planner import build_plan, choose_degree, discover_segments

#: estimated seconds to copy one byte across a tree->tree transition —
#: used only to weigh boundary-cut profitability, not correctness
COPY_SECONDS_PER_BYTE = 1.0 / (4 * 1024 ** 3)
#: a stage cut is never inserted for streams smaller than this
MIN_STREAM_BYTES = 1 * 1024 * 1024
#: commute only filters observed to actually drop rows
COMMUTE_SELECTIVITY_MAX = 0.999


# ---------------------------------------------------------------------------
#  Statistics
# ---------------------------------------------------------------------------
@dataclass
class ComponentStats:
    """Observed per-component numbers, scaled to the full input."""
    rows_in: int = 0
    rows_out: int = 0
    busy_time: float = 0.0
    calls: int = 0
    out_bytes: int = 0            # bytes of the caches this component emitted

    @property
    def selectivity(self) -> float:
        """rows_out / rows_in (1.0 when nothing was observed)."""
        return self.rows_out / self.rows_in if self.rows_in > 0 else 1.0

    @property
    def per_row_time(self) -> float:
        return self.busy_time / self.rows_in if self.rows_in > 0 else 0.0

    def spec(self) -> dict:
        return {"rows_in": self.rows_in, "rows_out": self.rows_out,
                "busy_time": self.busy_time, "calls": self.calls,
                "out_bytes": self.out_bytes,
                "selectivity": self.selectivity,
                "per_row_time": self.per_row_time}


@dataclass
class FlowStatistics:
    """Per-component statistics for one flow, scaled to the full input."""
    components: Dict[str, ComponentStats] = field(default_factory=dict)
    sample_rows: int = 0          # calibration prefix size (0 => full run)
    scale: float = 1.0            # full_rows / sample_rows applied already

    def get(self, name: str) -> Optional[ComponentStats]:
        return self.components.get(name)

    def spec(self) -> dict:
        return {"sample_rows": self.sample_rows, "scale": self.scale,
                "components": {n: s.spec()
                               for n, s in sorted(self.components.items())}}

    @classmethod
    def from_flow(cls, flow: Dataflow, scale: float = 1.0) -> "FlowStatistics":
        """Harvest the instrumented counters left on the components by a
        prior engine run (cheapest statistics source: re-planning a flow
        that already ran once costs nothing extra)."""
        out = cls(scale=scale)
        for name, comp in flow.vertices.items():
            bk = comp.get_backend()
            row_bytes = _est_row_bytes(comp, bk)
            out.components[name] = ComponentStats(
                rows_in=int(comp.rows_in * scale),
                rows_out=int(comp.rows_out * scale),
                busy_time=comp.busy_time * scale,
                calls=comp.calls,
                out_bytes=int(comp.rows_out * scale * row_bytes))
        return out


def _est_row_bytes(comp, backend) -> int:
    """Approximate bytes per emitted row (source columns as a proxy for the
    flow's working row width when the component doesn't know better)."""
    est = comp.est_output_bytes()
    if est is not None and comp.rows_out > 0:
        return max(1, est // max(comp.rows_out, 1))
    return 64          # conservative default row width


# ---------------------------------------------------------------------------
#  Calibration — run a source prefix through the flow, sinks suppressed
# ---------------------------------------------------------------------------
def run_calibration(flow: Dataflow, sample_rows: int = 4096,
                    backend=None) -> FlowStatistics:
    """Execute the flow sequentially over a prefix of every source (separate
    caches, ordinary-scheme semantics) and harvest scaled statistics.

    Sinks are counted but NOT written (``SinkComponent.write`` is skipped) so
    calibration never pollutes the run's results.  Component counters are
    reset before and after — the real run starts from clean instrumentation.
    """
    flow.validate()
    flow.reset_stats()
    if backend is not None:
        for comp in flow.vertices.values():
            comp.backend = backend

    out_bytes: Dict[str, int] = {n: 0 for n in flow.vertices}
    states: Dict[str, list] = {
        n: c.new_state() for n, c in flow.vertices.items()
        if c.ctype in (ComponentType.BLOCK, ComponentType.SEMI_BLOCK)}

    def push(name: str, cache) -> None:
        comp = flow.component(name)
        if comp.ctype in (ComponentType.BLOCK, ComponentType.SEMI_BLOCK):
            comp.accumulate(states[name], cache)
            return
        if comp.ctype == ComponentType.SINK:
            # count rows without writing — calibration must not leak into
            # the sink's buffered results
            comp.rows_in += cache.n
            comp.rows_out += cache.n
            comp.calls += 1
            return
        outs = comp.process(cache, shared=False)
        out_bytes[name] += sum(c.nbytes() for c in outs)
        route(name, outs)

    def route(name: str, outs) -> None:
        succs = flow.succ(name)
        per_port = len(outs) == len(succs) and len(outs) > 1
        for i, u in enumerate(succs):
            src = outs[i] if per_port else outs[0]
            push(u, src.copy())

    total_rows = 0
    for sname in flow.sources():
        src = flow.component(sname)
        if not isinstance(src, SourceComponent):
            raise TypeError(f"source {sname!r} is not a SourceComponent")
        total_rows = max(total_rows, src.total_rows())
        taken = 0
        chunk = max(1, min(sample_rows, 4096))
        for cache in src.chunks(chunk):
            out_bytes[sname] += cache.nbytes()
            route(sname, [cache])
            taken += cache.n
            if taken >= sample_rows:
                break
    for name in flow.topo_order():
        comp = flow.component(name)
        if comp.ctype in (ComponentType.BLOCK, ComponentType.SEMI_BLOCK):
            out = comp.finish(states[name])
            out_bytes[name] += out.nbytes()
            route(name, [out])

    sample = min(sample_rows, total_rows) if total_rows else sample_rows
    scale = total_rows / sample if sample > 0 else 1.0
    stats = FlowStatistics(sample_rows=sample, scale=scale)
    for name, comp in flow.vertices.items():
        stats.components[name] = ComponentStats(
            rows_in=int(comp.rows_in * scale),
            rows_out=int(comp.rows_out * scale),
            busy_time=comp.busy_time * scale,
            calls=comp.calls,
            out_bytes=int(out_bytes[name] * scale))
    flow.reset_stats()
    return stats


# ---------------------------------------------------------------------------
#  Rewrite rules
# ---------------------------------------------------------------------------
@dataclass
class Rewrite:
    """One applied graph transformation (recorded in the metadata store)."""
    rule: str                  # "filter-commute" | "fuse-expressions" |
    #                            "insert-boundary" | "remove-boundary"
    detail: str

    def spec(self) -> dict:
        return {"rule": self.rule, "detail": self.detail}


@dataclass
class Refusal:
    """One rewrite the optimizer REFUSED for safety, with the reason —
    surfaced on ``EngineRun.refusals`` so silently-disabled optimizations
    (e.g. an undeclared lambda read set) are visible instead of just absent.
    Refusals whose reason contains ``"undeclared"`` are exactly the ones the
    expression DSL eliminates (provenance derived from the AST)."""
    rule: str
    detail: str

    def spec(self) -> dict:
        return {"rule": self.rule, "detail": self.detail}


def _is_chain_edge(flow: Dataflow, u: str, v: str) -> bool:
    return ((u, v) in flow.edges and flow.out_degree(u) == 1
            and flow.in_degree(v) == 1)


def _chunk_sensitive_sources(flow: Dataflow) -> bool:
    return any(isinstance(c, SourceComponent) and c.chunk_sensitive
               for c in flow.vertices.values())


def fuse_segments_flow(flow: Dataflow) -> List[Rewrite]:
    """Segment fusion: collapse every maximal fusable row-synchronized chain
    (``planner.discover_segments``) into a single ``FusedSegment`` activity
    executed as ONE backend dispatch per chunk (``Backend.compile_segment``).

    Purely structural — safety comes from the chain shape and the row-local
    §3 contract of the members, not from statistics — so it applies at any
    optimize level when enabled (``OptimizeOptions.fuse_segments`` /
    ``REPRO_FUSION=1``).  Refuses across block / semi-block components,
    fan-in/fan-out, explicit ``StageBoundary`` cuts, order-sensitive and
    chunk-sensitive members (the discovery rules).

    Chains are discovered THROUGH a terminal ``Aggregate`` consumer
    (``discover_segments(through_aggregates=True)``): the aggregate never
    joins the fused kernel, but its presence lets the segment defer its
    combined keep-mask (``FusedSegment.defer_mask_to``) — deferral-capable
    backends then skip the per-chunk compact, the mask rides downstream as a
    device column, and ``Aggregate.finish`` applies it once after the merge.
    """
    from ..etl.components import FusedSegment   # deferred (layering)
    out: List[Rewrite] = []
    for chain in discover_segments(flow, through_aggregates=True):
        tail = flow.component(chain[-1])
        agg = (tail if getattr(tail, "segment_terminal_aggregate", False)
               else None)
        members = chain[:-1] if agg is not None else chain
        comps = [flow.component(n) for n in members]
        fused = FusedSegment.from_components(comps)
        flow.collapse_chain(members, fused)
        out.append(Rewrite("fuse-segment",
                           f"{'+'.join(members)} -> {fused.name} "
                           f"({len(members)} dispatches -> 1)"))
        if agg is not None:
            fused.defer_mask_to(agg)
            out.append(Rewrite(
                "fuse-segment-aggregate",
                f"{fused.name} defers keep-mask to {agg.name} "
                f"(per-chunk mask sync -> one at finish)"))
    if out:
        flow.validate()
    return out


class CostBasedOptimizer:
    """Rewrites a ``Dataflow`` IN PLACE from measured statistics.

    Every rule is row-safe by construction — the statistics only decide
    *profitability*.  ``optimize()`` iterates the rules to a fixpoint
    (bounded) and returns the applied ``Rewrite`` records.
    """

    def __init__(self, flow: Dataflow, stats: FlowStatistics, *,
                 streaming: bool = False,
                 min_stream_bytes: int = MIN_STREAM_BYTES,
                 copy_seconds_per_byte: float = COPY_SECONDS_PER_BYTE,
                 max_passes: int = 8,
                 max_boundary_inserts: int = 1,
                 fuse_segments: bool = False):
        self.flow = flow
        self.stats = stats
        self.streaming = streaming
        self.min_stream_bytes = min_stream_bytes
        self.copy_seconds_per_byte = copy_seconds_per_byte
        self.max_passes = max_passes
        #: run segment fusion (fuse_segments_flow) after the statistics-
        #: driven rules settle, so commutes/cuts see individual activities
        self.fuse_segments = fuse_segments
        # the overlap model (min(T_up, T_down) gained per cut) reasons about
        # ONE producer/consumer pair; chained cuts do not compose gains, so
        # inserts are capped per optimize() round
        self.max_boundary_inserts = max_boundary_inserts
        self._inserted = 0
        self.rewrites: List[Rewrite] = []
        #: rewrites refused for safety, with reasons (deduplicated across
        #: the fixpoint passes) — zero "undeclared" entries on DSL-built
        #: flows is an acceptance gate
        self.refusals: List[Refusal] = []
        self._refused_keys: set = set()

    def _refuse(self, rule: str, detail: str) -> None:
        key = (rule, detail)
        if key not in self._refused_keys:
            self._refused_keys.add(key)
            self.refusals.append(Refusal(rule, detail))

    # ------------------------------------------------------------- driver
    def optimize(self) -> List[Rewrite]:
        for _ in range(self.max_passes):
            changed = (self._commute_filters()
                       or self._fuse_expressions()
                       or self._boundary_rules())
            if not changed:
                break
        if self.fuse_segments:
            # structural segment fusion LAST: the statistics-driven rules
            # above reason about individual activities
            self.rewrites.extend(fuse_segments_flow(self.flow))
        self.flow.validate()
        return self.rewrites

    # ------------------------------------------- rule 1: filter commute
    def can_commute(self, up: str, filt: str) -> Tuple[bool, str]:
        """Row-safety of hoisting ``filt`` ahead of its upstream ``up``.
        Returns (ok, reason-when-refused)."""
        flow = self.flow
        f = flow.component(filt)
        u = flow.component(up)
        if not _is_chain_edge(flow, up, filt):
            return False, "not a simple chain segment"
        if u.ctype != ComponentType.ROW_SYNC:
            return False, f"upstream {up!r} is {u.ctype.value}, not row-sync"
        if not u.row_preserving:
            return False, f"upstream {up!r} is not row-preserving"
        if u.tree_boundary:
            return False, f"upstream {up!r} is an explicit stage cut"
        if u.order_sensitive or f.order_sensitive:
            return False, "order-sensitive neighbour"
        reads = f.consumed_columns()
        if reads is None:
            return False, f"filter {filt!r} has an undeclared read set"
        if f.produced_columns() != frozenset():
            # only pure row-droppers commute: a component that also ADDS
            # columns could feed something its new upstream needs
            return False, f"{filt!r} produces columns — not a pure filter"
        writes = u.produced_columns()
        if writes is None:
            return False, f"upstream {up!r} has an undeclared write set"
        overlap = reads & writes
        if overlap:
            return False, (f"filter reads columns produced by {up!r}: "
                           f"{sorted(overlap)}")
        if flow.in_degree(up) != 1:
            return False, f"upstream {up!r} has fan-in"
        return True, ""

    def _commute_filters(self) -> bool:
        flow = self.flow
        for name in list(flow.topo_order()):
            comp = flow.vertices.get(name)
            if comp is None or comp.ctype != ComponentType.ROW_SYNC:
                continue
            # a filter is any non-row-preserving row-sync activity with a
            # declared read set (it drops rows, never adds columns)
            if comp.row_preserving:
                continue
            if comp.consumed_columns() is None:
                if comp.produced_columns() == frozenset():
                    # a would-be commute candidate silently disabled by an
                    # opaque predicate — exactly what the DSL eliminates
                    self._refuse("filter-commute",
                                 f"filter {name!r} has an undeclared read "
                                 f"set — no commute considered")
                continue
            preds = flow.pred(name)
            if len(preds) != 1:
                continue
            up = preds[0]
            ok, why = self.can_commute(up, name)
            if not ok:
                self._refuse("filter-commute", f"{name} over {up}: {why}")
                continue
            s_f = self.stats.get(name)
            s_u = self.stats.get(up)
            if s_f is None or s_u is None:
                continue            # no measurements: keep the flow as given
            if s_f.selectivity > COMMUTE_SELECTIVITY_MAX:
                continue            # filter observed to drop ~nothing
            # benefit: the hopped component stops processing dropped rows
            saved = (1.0 - s_f.selectivity) * s_u.rows_in * s_u.per_row_time
            if saved <= 0:
                continue
            flow.swap_adjacent(up, name)
            self.rewrites.append(Rewrite(
                "filter-commute",
                f"{name} ahead of {up} "
                f"(selectivity={s_f.selectivity:.3f}, "
                f"saves~{saved * 1e3:.2f}ms)"))
            return True
        return False

    # ------------------------------------------ rule 2: expression fusion
    def can_fuse(self, a: str, b: str) -> Tuple[bool, str]:
        from ..etl.components import Expression, FusedExpression
        flow = self.flow
        ca, cb = flow.component(a), flow.component(b)
        if not isinstance(ca, (Expression, FusedExpression)) or \
                not isinstance(cb, (Expression, FusedExpression)):
            return False, "both components must be Expressions"
        if not _is_chain_edge(flow, a, b):
            return False, "not a simple chain segment"
        if ca.order_sensitive or cb.order_sensitive:
            return False, "order-sensitive neighbour"
        if ca.tree_boundary or cb.tree_boundary:
            return False, "explicit stage cut between expressions"
        return True, ""

    def _fuse_expressions(self) -> bool:
        from ..etl.components import FusedExpression
        flow = self.flow
        for (a, b) in list(flow.edges):
            if a not in flow.vertices or b not in flow.vertices:
                continue
            ok, _ = self.can_fuse(a, b)
            if not ok:
                continue
            ca, cb = flow.component(a), flow.component(b)
            fused = FusedExpression.fuse(ca, cb)
            # splice pred(a) -> fused -> succ(b) IN PLACE: edge positions
            # carry per-port routing order for fan-out predecessors and
            # successors, so each rewired edge keeps its slot
            p = flow.pred(a)[0] if flow.pred(a) else None
            flow.vertices.pop(a)
            flow.vertices.pop(b)
            flow.vertices[fused.name] = fused
            new_edges = []
            for e in flow.edges:
                if e == (p, a):
                    new_edges.append((p, fused.name))
                elif e == (a, b):
                    continue
                elif e[0] == b:
                    new_edges.append((fused.name, e[1]))
                else:
                    new_edges.append(e)
            flow.edges = new_edges
            flow._reindex()
            self.rewrites.append(Rewrite(
                "fuse-expressions", f"{a} + {b} -> {fused.name}"))
            return True
        return False

    # ------------------------------- rule 3: stage-boundary insert/remove
    def can_cut(self, u: str, v: str) -> Tuple[bool, str]:
        """Row-safety of inserting a StageBoundary on edge u -> v."""
        flow = self.flow
        if (u, v) not in flow.edges:
            return False, "no such edge"
        cu, cv = flow.component(u), flow.component(v)
        if cu.ctype not in (ComponentType.ROW_SYNC,):
            return False, f"{u!r} is {cu.ctype.value}; cut only after row-sync"
        if cu.tree_boundary:
            return False, f"{u!r} is already a stage cut"
        if cv.ctype.roots_tree or cv.tree_boundary:
            return False, f"{v!r} already roots a tree — cut is redundant"
        if cv.ctype == ComponentType.SINK and flow.in_degree(v) > 1:
            return False, "shared sink"
        if _chunk_sensitive_sources(flow):
            # a chunk-sensitive source's calibration prefix used different
            # chunk boundaries than the real run will — the byte statistics
            # driving this cut are not representative
            return False, "chunk-sensitive source"
        # streamable_tree_ids needs the downstream members order-insensitive
        down = self._downstream_members(v)
        if any(flow.component(n).order_sensitive for n in down):
            return False, "order-sensitive downstream member"
        return True, ""

    def _downstream_members(self, start: str) -> List[str]:
        """Row-sync members reachable from ``start`` without crossing a
        tree-rooting component (the would-be streamed tree)."""
        out, frontier = [], [start]
        seen = set()
        while frontier:
            n = frontier.pop()
            if n in seen:
                continue
            seen.add(n)
            c = self.flow.component(n)
            if c.ctype.roots_tree or (c.tree_boundary and n != start):
                continue
            out.append(n)
            frontier.extend(self.flow.succ(n))
        return out

    def _boundary_rules(self) -> bool:
        return self._remove_boundary() or (self.streaming
                                           and self._insert_boundary())

    def _remove_boundary(self) -> bool:
        flow = self.flow
        for name in list(flow.vertices):
            comp = flow.vertices.get(name)
            if comp is None or not comp.tree_boundary:
                continue
            if flow.in_degree(name) != 1 or flow.out_degree(name) != 1:
                continue
            up = flow.pred(name)[0]
            s_up = self.stats.get(up)
            if s_up is None:
                continue
            if s_up.out_bytes >= self.min_stream_bytes and self.streaming:
                continue            # the cut still pays for itself
            flow.remove_passthrough(name)
            self.rewrites.append(Rewrite(
                "remove-boundary",
                f"{name} (observed {s_up.out_bytes / 1e6:.2f}MB "
                f"< {self.min_stream_bytes / 1e6:.1f}MB threshold"
                + ("" if self.streaming else "; streaming off") + ")"))
            return True
        return False

    def _insert_boundary(self) -> bool:
        """Insert the single most profitable cut: the edge where overlapping
        the two stages under the streaming executor buys the most, net of
        the per-split copy cost.  Capped at ``max_boundary_inserts`` per
        round — the overlap gain of chained cuts does not compose."""
        from .component import StageBoundary
        flow = self.flow
        if self._inserted >= self.max_boundary_inserts:
            return False
        best = None          # (net_gain, u, v)
        for (u, v) in flow.edges:
            ok, _ = self.can_cut(u, v)
            if not ok:
                continue
            if not _is_chain_edge(flow, u, v):
                continue
            s_u = self.stats.get(u)
            if s_u is None or s_u.out_bytes < self.min_stream_bytes:
                continue
            t_up = self._upstream_time(u)
            t_down = self._downstream_time(v)
            overlap = min(t_up, t_down)
            copy_cost = s_u.out_bytes * self.copy_seconds_per_byte
            net = overlap - copy_cost
            if net > 0 and (best is None or net > best[0]):
                best = (net, u, v)
        if best is None:
            return False
        _, u, v = best
        cut_name = f"autocut_{u}"
        if cut_name in flow.vertices:
            return False
        flow.insert_between(u, v, StageBoundary(cut_name))
        self._inserted += 1
        self.rewrites.append(Rewrite(
            "insert-boundary",
            f"{cut_name} on {u} -> {v} (net~{best[0] * 1e3:.2f}ms)"))
        return True

    def _upstream_time(self, end: str) -> float:
        """Total observed busy time of ``end`` and everything upstream of it
        inside the same would-be stage."""
        total, frontier, seen = 0.0, [end], set()
        while frontier:
            n = frontier.pop()
            if n in seen:
                continue
            seen.add(n)
            s = self.stats.get(n)
            if s is not None:
                total += s.busy_time
            frontier.extend(self.flow.pred(n))
        return total

    def _downstream_time(self, start: str) -> float:
        total, frontier, seen = 0.0, [start], set()
        while frontier:
            n = frontier.pop()
            if n in seen:
                continue
            seen.add(n)
            s = self.stats.get(n)
            if s is not None:
                total += s.busy_time
            frontier.extend(self.flow.succ(n))
        return total


# ---------------------------------------------------------------------------
#  Re-planning from measurements
# ---------------------------------------------------------------------------
def measured_edge_bytes(flow: Dataflow, g_tau: ExecutionTreeGraph,
                        stats: FlowStatistics) -> Dict[Tuple[int, int], int]:
    """Observed bytes crossing each inter-tree edge of the (possibly
    rewritten) flow: the sum of the measured output bytes of the dataflow
    edges feeding the transition.  Components the statistics have never seen
    (e.g. a freshly inserted StageBoundary) inherit their predecessor's
    observation."""
    def observed_out(name: str) -> int:
        seen = set()
        while name not in seen:
            seen.add(name)
            s = stats.get(name)
            if s is not None and s.calls > 0:
                return s.out_bytes
            preds = flow.pred(name)
            if len(preds) != 1:
                break
            name = preds[0]
        return 0

    out: Dict[Tuple[int, int], int] = {}
    for (u, v) in flow.edges:
        a = g_tau.tree_of.get(u)
        b = g_tau.tree_of.get(v)
        if a is None or b is None or a == b:
            continue
        out[(a, b)] = out.get((a, b), 0) + observed_out(u)
    # edges with no dataflow observation at all fall back to zero and the
    # planner's floor of depth >= 1 still applies
    for e in g_tau.edges:
        out.setdefault(e, 0)
    return out


def suggest_pipeline_degree(stats: FlowStatistics, num_splits: int,
                            cores: Optional[int] = None) -> int:
    """Algorithm 3 over MEASURED activity times: build the cost-model plan
    from the calibration statistics and pick a practical degree, capped at
    the split count (more in-flight splits than splits is meaningless)."""
    times = {n: s.busy_time for n, s in stats.components.items()
             if s.busy_time > 0 and s.calls > 0}
    if not times or stats.sample_rows <= 0:
        return max(1, num_splits)
    # FlowStatistics.busy_time is ALREADY extrapolated to the full input, so
    # build_plan must not scale again: hand it sample_rows == full_rows.
    rows = max(int(stats.sample_rows * stats.scale), 1)
    # per-call busy of the cheapest activity approximates the per-activity
    # miscellaneous time t0 (we have no zero-row run during a live rewrite);
    # per-CALL overhead does not grow with the input, so unscale it
    t0_est = min(s.busy_time / max(s.calls, 1)
                 for s in stats.components.values()
                 if s.calls > 0 and s.busy_time > 0) / max(stats.scale, 1e-9)
    plan = build_plan(times, misc_total=t0_est * len(times),
                      sample_rows=rows, full_rows=rows,
                      m_prime=max(1, num_splits))
    cores = cores if cores is not None else (os.cpu_count() or 1)
    return max(1, min(choose_degree(plan, cores=cores), num_splits))
