"""Algorithm 1 — partition a dataflow graph G into an execution-tree graph G_tau.

Definition 2: an execution tree T(V', E') is a subgraph of G whose root has
in-degree 0 *within the tree*; vertices with out-degree 0 are leaves.  Block
and semi-block components always ROOT a new tree, because they must
accumulate rows in their own cache before processing (paper §3/§4.1);
everything row-synchronized streams inside its parent's tree on a shared
cache.  Extension: a component with ``tree_boundary`` set (StageBoundary)
also roots a new tree even though it is row-synchronized — an explicit stage
cut that the streaming executor pipes splits across as they arrive.

Faithfulness note: the paper's pseudocode recurses `DFS(G, G_tau, u, T)` even
after rooting a new tree T' at u (line 17-21).  Taken literally that would
attach u's row-synchronized descendants to the OLD tree, contradicting
Figure 6 (e.g. `sort` streams inside T_2 rooted at the `sum` aggregator).  We
recurse with T' for block/semi-block u — the behaviour Figure 6 depicts — and
test exactly that shape in tests/test_core_partitioner.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .component import Component, ComponentType
from .graph import Dataflow


class ExecutionTree:
    """One partition: a root plus its streamed (row-sync / sink) descendants."""

    def __init__(self, tree_id: int, root: str):
        self.tree_id = tree_id
        self.root = root
        self.members: List[str] = [root]       # topo-ordered within the tree
        self.edges: List[Tuple[str, str]] = [] # intra-tree edges

    def add_member(self, u: str, parent: str) -> None:
        self.members.append(u)
        self.edges.append((parent, u))

    def activities(self, flow: Dataflow) -> List[Component]:
        return [flow.component(n) for n in self.members]

    def __repr__(self) -> str:
        return f"ExecutionTree(#{self.tree_id}, root={self.root!r}, members={self.members})"


class ExecutionTreeGraph:
    """G_tau(V_tau, E_tau): vertices are execution trees, edges are the
    tree->tree transitions that require a COPY (paper §4.1)."""

    def __init__(self, flow: Dataflow):
        self.flow = flow
        self.trees: List[ExecutionTree] = []
        self.edges: List[Tuple[int, int]] = []        # (tree_id, tree_id)
        self.tree_of: Dict[str, int] = {}             # component -> tree_id

    def new_tree(self, root: str) -> ExecutionTree:
        t = ExecutionTree(len(self.trees), root)
        self.trees.append(t)
        self.tree_of[root] = t.tree_id
        return t

    def add_edge(self, src_tree: int, dst_tree: int) -> None:
        e = (src_tree, dst_tree)
        if e not in self.edges:
            self.edges.append(e)

    def tree(self, tid: int) -> ExecutionTree:
        return self.trees[tid]

    def topo_tree_order(self) -> List[int]:
        indeg = {t.tree_id: 0 for t in self.trees}
        for a, b in self.edges:
            indeg[b] += 1
        ready = sorted([t for t, d in indeg.items() if d == 0])
        order: List[int] = []
        while ready:
            t = ready.pop(0)
            order.append(t)
            for a, b in self.edges:
                if a == t:
                    indeg[b] -= 1
                    if indeg[b] == 0:
                        ready.append(b)
        if len(order) != len(self.trees):
            raise ValueError("execution-tree graph has a cycle")
        return order

    def upstream_trees(self, tid: int) -> List[int]:
        return [a for a, b in self.edges if b == tid]

    def __repr__(self) -> str:
        return f"ExecutionTreeGraph(|V_tau|={len(self.trees)}, E_tau={self.edges})"


def streamable_tree_ids(flow: Dataflow, g_tau: ExecutionTreeGraph) -> set:
    """Trees whose input splits may be consumed AS THEY ARRIVE by the
    streaming executor: the root streams (row-sync / sink — an explicit
    stage boundary, not a source and not block/semi-block), exactly one
    cross-tree dataflow edge feeds the tree and it targets the root (unique,
    consecutive split indices), and no member is ``order_sensitive`` —
    arrival order is arbitrary, and an order-sensitive activity fed out of
    order could fill the admission gate with later splits and stall."""
    out = set()
    for tree in g_tau.trees:
        root = flow.component(tree.root)
        if root.ctype.roots_tree or flow.in_degree(tree.root) == 0:
            continue
        inbound = [(u, v) for (u, v) in flow.edges
                   if g_tau.tree_of.get(u) != tree.tree_id
                   and g_tau.tree_of.get(v) == tree.tree_id]
        if len(inbound) != 1 or inbound[0][1] != tree.root:
            continue
        if any(flow.component(n).order_sensitive for n in tree.members):
            continue
        out.add(tree.tree_id)
    return out


def partition(flow: Dataflow) -> ExecutionTreeGraph:
    """Algorithm 1.  DFS from every in-degree-0 vertex; block/semi-block
    vertices root new trees; row-synchronized vertices join the current tree.

    A semi-block component reachable from several trees gets ONE tree (rooted
    at itself) with an inter-tree edge from each upstream tree.
    """
    flow.validate()
    g_tau = ExecutionTreeGraph(flow)
    visited: Dict[str, bool] = {n: False for n in flow.vertices}

    def dfs(v: str, tree: ExecutionTree) -> None:
        visited[v] = True
        for u in flow.succ(v):
            u_comp = flow.component(u)
            u_type = u_comp.ctype
            if not (u_type.roots_tree or u_comp.tree_boundary):
                # row-synchronized (or sink): joins the current tree
                if not visited[u]:
                    tree.add_member(u, parent=v)
                    g_tau.tree_of[u] = tree.tree_id
                    dfs(u, tree)
                else:
                    # already a member of SOME tree. Intra-tree diamond joins
                    # are excluded by validation (in-degree>1 => semi-block),
                    # so this can only happen across trees; record the edge.
                    g_tau.add_edge(tree.tree_id, g_tau.tree_of[u])
            else:
                # block/semi-block (or an explicit stage boundary): roots a
                # new execution tree
                if not visited[u]:
                    visited[u] = True
                    t_new = g_tau.new_tree(u)
                    g_tau.add_edge(tree.tree_id, t_new.tree_id)
                    dfs(u, t_new)            # paper typo fixed: recurse with T'
                else:
                    g_tau.add_edge(tree.tree_id, g_tau.tree_of[u])

    for v in flow.topo_order():
        if flow.in_degree(v) == 0 and not visited[v]:
            visited[v] = True
            tree = g_tau.new_tree(v)
            dfs(v, tree)
    return g_tau
