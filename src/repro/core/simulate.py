"""Discrete-event simulator for pipeline execution on a k-core machine.

This container exposes ONE physical core, so the paper's *parallel* speedups
(Fig 12-14) cannot materialize in wall-clock here.  The simulator replays
measured per-(activity, split) costs under the same execution semantics as
`core/pipeline.py` — grid-DAG precedence with list scheduling on k cores —
which is exactly the cost model Theorem 1 assumes.  EXPERIMENTS.md reports
simulated (8-core) curves next to real 1-core measurements and the paper's
numbers.

Task (i, s) = activity i processing split s.  Precedence:
  (i-1, s): the split must have passed the previous activity;
  (i, s-1): an activity processes one split at a time, in order.
Admission: at most m' splits in flight (BlockingQueue(m')).
Contention model: when the in-flight thread count exceeds the core count,
each task pays a switching overhead `switch_cost * excess_threads` — the
mechanism the paper blames for the decline past 8 pipelines (§5.1).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class SimResult:
    makespan: float
    sequential_time: float
    speedup: float
    core_busy: np.ndarray          # per-core busy seconds
    avg_cpu_usage: float           # mean utilization across cores


def simulate_tree(costs: np.ndarray, cores: int = 8,
                  m_prime: Optional[int] = None,
                  switch_cost: float = 0.0) -> SimResult:
    """Simulate pipeline execution of an execution tree.

    ``costs``: array [n_activities, m_splits] of seconds per task.
    ``m_prime``: admission bound (defaults to m_splits = paper's m=m' case).
    """
    costs = np.asarray(costs, dtype=np.float64)
    n, m = costs.shape
    if m_prime is None:
        m_prime = m
    m_prime = max(1, min(m_prime, m))

    seq_time = float(costs.sum())
    done = np.full((n, m), np.inf)
    # event heap of (time, kind, payload): core frees / split admitted
    core_free = [0.0] * cores          # availability time per core
    core_busy = np.zeros(cores)

    # split s can be admitted when at most m'-1 of splits < s are unfinished.
    # A split is finished when it clears the last activity.
    finish_split = np.full(m, np.inf)

    # schedule greedily in precedence order; contention via latest-available
    # core.  admit_time[s] = inf until the BlockingQueue slot opens: the
    # first m' splits are admitted at t=0, later ones when s-m' finishes.
    admit_time = np.full(m, np.inf)
    admit_time[:m_prime] = 0.0
    for s in range(m):
        if s >= m_prime:
            # wait for the (s - m')th in-flight split to finish
            admit_time[s] = np.partition(finish_split[:s], s - m_prime)[s - m_prime]
        for i in range(n):
            ready = admit_time[s]
            if i > 0:
                ready = max(ready, done[i - 1, s])
            if s > 0:
                ready = max(ready, done[i, s - 1])
            # live consumer threads at `ready`: splits admitted (queue slot
            # held) whose last activity has not finished — including those
            # still waiting for a busy activity (paper: blocked in wait())
            in_flight = int(np.sum((admit_time <= ready)
                                   & (finish_split > ready)))
            overhead = switch_cost * max(0, in_flight - cores)
            # earliest available core
            k = int(np.argmin(core_free))
            start = max(ready, core_free[k])
            dur = costs[i, s] + overhead
            done[i, s] = start + dur
            core_free[k] = done[i, s]
            core_busy[k] += dur
        finish_split[s] = done[n - 1, s]

    makespan = float(done[n - 1, :].max())
    usage = float(core_busy.sum() / (cores * makespan)) if makespan > 0 else 0.0
    return SimResult(makespan=makespan, sequential_time=seq_time,
                     speedup=seq_time / makespan if makespan > 0 else float("inf"),
                     core_busy=core_busy, avg_cpu_usage=usage)


def speedup_curve(per_activity_cost: Sequence[float], total_rows: int,
                  degrees: Sequence[int], cores: int = 8,
                  t0: float = 0.0, switch_cost: float = 0.0) -> Dict[int, float]:
    """Paper Fig-12-style curve: speedup vs number of pipelines (m = m').

    ``per_activity_cost``: net seconds per activity for the FULL input; each
    split of degree m costs net/m + t0 (the Theorem-1 linear model)."""
    out: Dict[int, float] = {}
    net = np.asarray(per_activity_cost, dtype=np.float64)
    for m in degrees:
        costs = np.tile((net / m + t0)[:, None], (1, m))
        res = simulate_tree(costs, cores=cores, m_prime=m,
                            switch_cost=switch_cost)
        # speedup vs the m=1 (non-pipeline) execution including misc time
        seq = float(net.sum() + t0 * len(net))
        out[m] = seq / res.makespan
    return out


def cpu_usage_curve(per_activity_cost: Sequence[float],
                    degrees: Sequence[int], cores: int = 8,
                    t0: float = 0.0, switch_cost: float = 0.0) -> Dict[int, float]:
    """Paper Fig-13-style curve: average CPU usage vs number of pipelines."""
    out: Dict[int, float] = {}
    net = np.asarray(per_activity_cost, dtype=np.float64)
    for m in degrees:
        costs = np.tile((net / m + t0)[:, None], (1, m))
        res = simulate_tree(costs, cores=cores, m_prime=m,
                            switch_cost=switch_cost)
        out[m] = res.avg_cpu_usage
    return out


def multithreading_curve(bottleneck_cost: float, other_cost: float,
                         thread_counts: Sequence[int], cores: int = 8,
                         parallel_fraction: float = 0.95,
                         switch_cost: float = 0.0) -> Dict[int, float]:
    """Paper Fig-14-style curve: inside-component multithreading speedup.
    Amdahl-style with core saturation and over-threading penalty."""
    out: Dict[int, float] = {}
    base = bottleneck_cost + other_cost
    for t in thread_counts:
        eff = min(t, cores)
        par = bottleneck_cost * parallel_fraction / eff
        ser = bottleneck_cost * (1 - parallel_fraction)
        penalty = switch_cost * max(0, t - cores) * bottleneck_cost
        out[t] = base / (par + ser + other_cost + penalty)
    return out
