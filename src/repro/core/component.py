"""Dataflow components and the paper's §3 classification.

- ROW_SYNCHRONIZED: row-at-a-time processing; mutates a shared cache in place
  (filter, lookup, splitter, expression, format converter, projector, ...).
- BLOCK: accumulates ALL rows from a SINGLE upstream before any output
  (aggregations: sum/avg/min/max, sort, ...).  Roots a new execution tree.
- SEMI_BLOCK: accumulates rows from MULTIPLE upstreams until a condition is
  met (union, merge, ...).  Roots a new execution tree.
- SOURCE / SINK: dataflow entry (emits caches) / exit (consumes caches).
  Sources behave like roots; sinks are row-synchronized consumers.
"""
from __future__ import annotations

import enum
import threading
import time
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence

import numpy as np

from ..obs import trace as obs_trace
from . import faults
from .shared_cache import SharedCache, concat_caches


class ComponentType(enum.Enum):
    SOURCE = "source"
    ROW_SYNC = "row-synchronized"
    SEMI_BLOCK = "semi-block"
    BLOCK = "block"
    SINK = "sink"

    @property
    def roots_tree(self) -> bool:
        """Block and semi-block components root a new execution tree
        (Algorithm 1); sources do too, by virtue of in-degree 0."""
        return self in (ComponentType.BLOCK, ComponentType.SEMI_BLOCK)

    @property
    def streams(self) -> bool:
        return self in (ComponentType.ROW_SYNC, ComponentType.SINK)


class Component:
    """Base class.  An *activity* (the paper uses component/activity
    interchangeably) is the `process_*` method of a component.

    Thread-safety protocol (paper Algorithm 2 lines 6-11): each component owns
    a `busy` flag + Condition; pipeline consumer threads serialize access so a
    component processes one shared cache at a time, in split order when
    `order_sensitive` is set.
    """

    ctype: ComponentType = ComponentType.ROW_SYNC
    #: True if downstream semantics require split arrival order (e.g. before a
    #: Merge) — the pipeline then hands caches to this component in order.
    order_sensitive: bool = False
    #: True forces this component to root a new execution tree even when it is
    #: row-synchronized (an explicit stage cut — see StageBoundary).  The
    #: streaming executor pipes splits across such a boundary as they arrive.
    tree_boundary: bool = False
    #: True when the component maps each input row to exactly one output row
    #: in the same position (adds/overwrites columns only — Lookup,
    #: Expression, Converter, Project, StageBoundary).  Such components may be
    #: hopped by a commuting Filter (core/optimizer.py); row-dropping
    #: (Filter), row-reordering (Sort) and accumulate components must keep
    #: False.
    row_preserving: bool = False
    #: True when a failed per-chunk dispatch may be replayed in place after
    #: rewinding the cache to its pre-dispatch snapshot (the fault-tolerance
    #: replay contract).  Row-synchronized components qualify: they only
    #: mutate the cache handed to them.  Components with side effects beyond
    #: the cache — sinks (external writes), block/semi-block accumulators
    #: (state consumed by ``finish``), sources (chunk generation is re-driven
    #: by run-level replay) — must keep False; their transient failures
    #: escalate to run-level retry instead.
    replay_safe: bool = True
    #: sharded-execution role, set by the shard runtime for the duration of a
    #: sharded run on first-layer block/semi-block cut components only:
    #: ``"partial"`` — finish() is intercepted to stash a per-shard partial
    #: and emit an empty schema-shaped cache; ``"merge"`` — finish() combines
    #: the stashed partials into the exact serial result.  ``None`` (the
    #: default) leaves finish() untouched.
    shard_role: Optional[str] = None

    def __init__(self, name: str):
        self.name = name
        self.busy = False
        self.cond = threading.Condition()
        self.next_split = 0          # order enforcement for order_sensitive
        #: operator backend this component dispatches its kernels through;
        #: None => the process default (REPRO_BACKEND env var / "numpy").
        #: Engines assign the run's backend here before executing.
        self.backend = None
        # instrumentation
        self.rows_in = 0
        self.rows_out = 0
        self.busy_time = 0.0
        self.calls = 0

    def get_backend(self):
        """The active operator backend (core/backend/) for this component."""
        if self.backend is not None:
            return self.backend
        from .backend import get_default_backend     # deferred (cycle-free)
        return get_default_backend()

    # ------------------------------------------------------------ row-sync
    def process(self, cache: SharedCache, shared: bool = True) -> List[SharedCache]:
        """Process one cache.  With ``shared=True`` the component MUST mutate
        in place (shared caching scheme); with ``shared=False`` the engine has
        already handed it a private copy.  Returns the list of output caches
        (usually the same object; splitters return several)."""
        t0 = time.perf_counter()
        n_in = cache.n
        split = cache.split_index
        faults.inject("chunk", component=self.name, split=split)
        out = self._run(cache)
        t1 = time.perf_counter()
        self.busy_time += t1 - t0
        self.calls += 1
        self.rows_in += n_in
        n_out = sum(c.n for c in out)
        self.rows_out += n_out
        if obs_trace.ACTIVE.get():
            obs_trace.on_dispatch(self.name, t0, t1, split, n_in, n_out)
        return out

    def _run(self, cache: SharedCache) -> List[SharedCache]:  # pragma: no cover
        raise NotImplementedError

    # --------------------------------------------------- inside-component MT
    #: Override to True on heavy components that support §4.3 multithreading.
    supports_multithreading: bool = False

    def process_range(self, cache: SharedCache, rows: slice) -> Dict[str, np.ndarray]:
        """Process a sub-range of rows (inside-component parallelization).
        Returns the output columns for that range; the engine's row-order
        synchronizer merges ranges back in input order."""
        raise NotImplementedError

    # ------------------------------------------------------------ block/semi
    def new_state(self):
        """Per-execution accumulation state for block/semi-block components."""
        return []

    def accumulate(self, state, cache: SharedCache) -> None:
        t0 = time.perf_counter()
        faults.inject("chunk", component=self.name, split=cache.split_index)
        state.append(cache)
        t1 = time.perf_counter()
        self.busy_time += t1 - t0
        self.rows_in += cache.n
        if obs_trace.ACTIVE.get():
            obs_trace.on_accumulate(self.name, t0, t1, cache.n)

    def finish(self, state) -> SharedCache:
        """Consume accumulated caches, emit the result as one cache."""
        raise NotImplementedError

    # --------------------------------------------------- segment fusion
    def segment_ops(self) -> Optional[list]:
        """Declarative description of this component as fusable segment ops
        (see ``etl.components.FusedSegment``), or ``None`` when the component
        cannot join a fused segment (blocks, sinks, sources, anything with
        side effects or non-row-local semantics).  Row-synchronized
        components that implement this are row-local by the paper's §3
        contract: each output row depends only on its own input row."""
        return None

    # --------------------------------------------------- column provenance
    def produced_columns(self) -> Optional[frozenset]:
        """Columns this component ADDS or OVERWRITES on the cache.  ``None``
        means unknown — the cost-based optimizer then refuses any rewrite
        that needs the answer.  Pure pass-throughs return an empty set."""
        return None

    def consumed_columns(self) -> Optional[frozenset]:
        """Columns this component READS.  ``None`` means unknown (e.g. an
        undeclared predicate lambda) — rewrites requiring disjointness with a
        neighbour's outputs are refused."""
        return None

    def output_schema(self, incols: frozenset) -> Optional[frozenset]:
        """The column set this component emits given input columns
        ``incols`` — the static schema-propagation hook behind
        ``planner.infer_schema`` (build-time read validation in the Session
        API).  ``None`` means unknown; the inference pass then stops
        validating downstream of this component."""
        return None

    # ------------------------------------------------------------------ misc
    def est_output_bytes(self) -> Optional[int]:
        """Cache-size metadata: estimated total bytes this component emits
        over a full run, when knowable up front (sources know their table
        size).  ``None`` means unknown — the planner then falls back to the
        component's input estimate."""
        return None

    def reset_stats(self) -> None:
        self.rows_in = self.rows_out = 0
        self.busy_time = 0.0
        self.calls = 0
        self.next_split = 0
        # an aborted run may leave the flag set; clearing it here keeps the
        # flow reusable after a permanent fault
        self.busy = False

    def spec(self) -> Dict[str, str]:
        """Metadata-store component specification."""
        return {"name": self.name, "type": self.ctype.value,
                "class": type(self).__name__}

    # --------------------------------------------------------------- pickling
    # The process shard route ships whole flows to spawned workers.  Locks
    # and backends don't pickle; both are reconstructed on load (the worker
    # re-resolves the backend from its own environment).
    _UNPICKLABLE = ("cond", "backend", "_shard_ctx")

    def __getstate__(self):
        state = dict(self.__dict__)
        for k in self._UNPICKLABLE:
            state.pop(k, None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self.cond = threading.Condition()
        self.backend = None

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


class SourceComponent(Component):
    """Emits the input row set as a stream of caches (chunks)."""

    ctype = ComponentType.SOURCE
    replay_safe = False          # chunk draws re-run at run level

    #: True when the DATA this source emits depends on chunk boundaries
    #: (e.g. an RNG-per-chunk synthetic source).  The executor then never
    #: realigns the chunk size to a backend's preferred batch size
    #: (RuntimePlan.chunk_rows) — only an explicit OptimizeOptions.chunk_rows
    #: may change it.
    chunk_sensitive: bool = False

    def chunks(self, chunk_rows: int) -> Iterator[SharedCache]:  # pragma: no cover
        raise NotImplementedError

    def total_rows(self) -> int:  # pragma: no cover
        raise NotImplementedError


class SinkComponent(Component):
    """Consumes caches (writes results).  Row-synchronized semantics."""

    ctype = ComponentType.SINK
    replay_safe = False          # external writes are side effects

    def output_schema(self, incols: frozenset) -> frozenset:
        return incols            # a sink writes exactly what it receives

    def _run(self, cache: SharedCache) -> List[SharedCache]:
        self.write(cache)
        return [cache]

    def write(self, cache: SharedCache) -> None:  # pragma: no cover
        raise NotImplementedError


class BlockComponent(Component):
    """Accumulate-all-then-emit (single upstream)."""

    ctype = ComponentType.BLOCK
    replay_safe = False          # accumulated state is consumed by finish()

    def finish(self, state) -> SharedCache:
        raise NotImplementedError


class SemiBlockComponent(Component):
    """Accumulate from multiple upstreams, then emit."""

    ctype = ComponentType.SEMI_BLOCK
    replay_safe = False          # accumulated state is consumed by finish()

    def finish(self, state) -> SharedCache:
        raise NotImplementedError


class FnComponent(Component):
    """Row-synchronized component from a plain function
    ``fn(cache) -> None`` (mutates in place)."""

    def __init__(self, name: str, fn: Callable[[SharedCache], None]):
        super().__init__(name)
        self.fn = fn

    def _run(self, cache: SharedCache) -> List[SharedCache]:
        self.fn(cache)
        return [cache]


class StageBoundary(Component):
    """Explicit execution-tree boundary: a row-synchronized pass-through that
    the partitioner roots a new tree at (Algorithm 1 extended).

    Marks a stage cut in the dataflow — DOD-ETL-style stage decoupling.  The
    streaming executor connects the two trees with a bounded split channel
    and the downstream tree consumes splits AS THEY ARRIVE, overlapping the
    stages; the cut costs one copy per split (paper §4.1 tree->tree
    transition).  Useful to bound a stage's working set, isolate a slow
    stage behind backpressure, or (eventually) place stages on different
    workers."""

    tree_boundary = True
    row_preserving = True

    def _run(self, cache: SharedCache) -> List[SharedCache]:
        return [cache]

    def produced_columns(self) -> frozenset:
        return frozenset()

    def consumed_columns(self) -> frozenset:
        return frozenset()

    def output_schema(self, incols: frozenset) -> frozenset:
        return incols
