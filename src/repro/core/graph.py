"""Dataflow DAG — Definition 1: G(V, E), V = activities over row sets,
E = logical transitions."""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .component import Component, ComponentType


class Dataflow:
    """A directed acyclic graph of components."""

    def __init__(self, name: str = "dataflow"):
        self.name = name
        self.vertices: Dict[str, Component] = {}
        self.edges: List[Tuple[str, str]] = []
        self._succ: Dict[str, List[str]] = {}
        self._pred: Dict[str, List[str]] = {}

    # ------------------------------------------------------------- building
    def add(self, comp: Component) -> Component:
        if comp.name in self.vertices:
            raise ValueError(f"duplicate component name {comp.name!r}")
        self.vertices[comp.name] = comp
        self._succ[comp.name] = []
        self._pred[comp.name] = []
        return comp

    def connect(self, u, v) -> None:
        """Add edge u -> v.  Accepts names or components."""
        un = u if isinstance(u, str) else u.name
        vn = v if isinstance(v, str) else v.name
        for n in (un, vn):
            if n not in self.vertices:
                raise KeyError(f"unknown component {n!r}")
        self.edges.append((un, vn))
        self._succ[un].append(vn)
        self._pred[vn].append(un)

    def chain(self, *comps) -> None:
        """Convenience: add (if needed) and connect comps in sequence."""
        prev = None
        for c in comps:
            if (c.name if isinstance(c, Component) else c) not in self.vertices:
                self.add(c)
            if prev is not None:
                self.connect(prev, c)
            prev = c

    # ------------------------------------------------------------- surgery
    #
    # In-place graph rewriting used by the cost-based optimizer
    # (core/optimizer.py).  Every method keeps the edge list and the
    # succ/pred indices consistent by rebuilding the indices from the edge
    # list — surgery is rare (a handful per run) so clarity wins over
    # incremental updates.
    def _reindex(self) -> None:
        self._succ = {n: [] for n in self.vertices}
        self._pred = {n: [] for n in self.vertices}
        for u, v in self.edges:
            self._succ[u].append(v)
            self._pred[v].append(u)

    def insert_between(self, u: str, v: str, comp: Component) -> Component:
        """Splice ``comp`` onto the edge u -> v (u -> comp -> v)."""
        if (u, v) not in self.edges:
            raise KeyError(f"no edge {u!r} -> {v!r}")
        self.add(comp)
        self.edges[self.edges.index((u, v))] = (u, comp.name)
        self.edges.append((comp.name, v))
        self._reindex()
        return comp

    def remove_passthrough(self, name: str) -> Component:
        """Remove a single-in / single-out component, reconnecting its
        predecessor directly to its successor."""
        if self.in_degree(name) != 1 or self.out_degree(name) != 1:
            raise ValueError(
                f"remove_passthrough({name!r}): needs in-degree 1 and "
                f"out-degree 1, got {self.in_degree(name)}/{self.out_degree(name)}")
        p, s = self._pred[name][0], self._succ[name][0]
        comp = self.vertices.pop(name)
        # splice IN PLACE: a predecessor's successor ORDER is semantic (the
        # pipeline routes splitter output ports positionally), so the
        # reconnect edge must take the removed edge's position, not be
        # appended after p's other outbound edges
        self.edges[self.edges.index((p, name))] = (p, s)
        self.edges.remove((name, s))
        self._reindex()
        return comp

    def collapse_chain(self, names: Sequence[str], comp: Component) -> Component:
        """Replace a simple chain ``names[0] -> ... -> names[-1]`` with the
        single component ``comp`` (segment fusion).  Every link must be a
        simple chain edge (out-degree 1 into in-degree 1); the head keeps its
        single inbound edge's position and the tail's outbound edges keep
        their slots (successor order is semantic for per-port routing)."""
        names = list(names)
        if len(names) < 2:
            raise ValueError("collapse_chain: need at least two components")
        for a, b in zip(names, names[1:]):
            if (a, b) not in self.edges:
                raise KeyError(f"no edge {a!r} -> {b!r}")
            if self.out_degree(a) != 1 or self.in_degree(b) != 1:
                raise ValueError(
                    f"collapse_chain: {a!r} -> {b!r} is not a simple chain "
                    f"segment")
        head, tail = names[0], names[-1]
        chain = set(names)
        for n in names:
            self.vertices.pop(n)
        self.vertices[comp.name] = comp
        new_edges = []
        for (a, b) in self.edges:
            if a in chain and b in chain:
                continue
            elif b == head:
                new_edges.append((a, comp.name))
            elif a == tail:
                new_edges.append((comp.name, b))
            else:
                new_edges.append((a, b))
        self.edges = new_edges
        self._reindex()
        return comp

    def swap_adjacent(self, u: str, v: str) -> None:
        """Swap two chained components: ... -> u -> v -> ... becomes
        ... -> v -> u -> ... .  Requires the pair to form a simple chain
        segment (edge u->v, out-degree(u) == 1, in-degree(v) == 1); the
        caller (optimizer) is responsible for SEMANTIC safety."""
        if (u, v) not in self.edges:
            raise KeyError(f"no edge {u!r} -> {v!r}")
        if self.out_degree(u) != 1 or self.in_degree(v) != 1:
            raise ValueError(
                f"swap_adjacent({u!r}, {v!r}): not a simple chain segment")
        new_edges = []
        for (a, b) in self.edges:
            if (a, b) == (u, v):
                new_edges.append((v, u))
            else:
                # redirect u's inbound edges to v, v's outbound edges to u
                a2 = u if a == v else a
                b2 = v if b == u else b
                new_edges.append((a2, b2))
        self.edges = new_edges
        self._reindex()

    # ------------------------------------------------------------- queries
    def succ(self, name: str) -> List[str]:
        return self._succ[name]

    def pred(self, name: str) -> List[str]:
        return self._pred[name]

    def in_degree(self, name: str) -> int:
        return len(self._pred[name])

    def out_degree(self, name: str) -> int:
        return len(self._succ[name])

    def sources(self) -> List[str]:
        return [n for n in self.vertices if self.in_degree(n) == 0]

    def sinks(self) -> List[str]:
        return [n for n in self.vertices if self.out_degree(n) == 0]

    def component(self, name: str) -> Component:
        return self.vertices[name]

    # ---------------------------------------------------------- validation
    def topo_order(self) -> List[str]:
        indeg = {n: self.in_degree(n) for n in self.vertices}
        ready = sorted([n for n, d in indeg.items() if d == 0])
        order: List[str] = []
        ready_set = list(ready)
        while ready_set:
            n = ready_set.pop(0)
            order.append(n)
            for s in self._succ[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready_set.append(s)
        if len(order) != len(self.vertices):
            raise ValueError(f"dataflow {self.name!r} has a cycle")
        return order

    def validate(self) -> None:
        self.topo_order()  # acyclicity
        for n, comp in self.vertices.items():
            d_in, d_out = self.in_degree(n), self.out_degree(n)
            t = comp.ctype
            if t == ComponentType.SOURCE and d_in != 0:
                raise ValueError(f"source {n!r} has incoming edges")
            if d_in > 1 and t not in (ComponentType.SEMI_BLOCK, ComponentType.SINK):
                raise ValueError(
                    f"{n!r} ({t.value}) has in-degree {d_in}; only semi-block "
                    f"components may merge multiple upstreams (paper §3)")
            if t == ComponentType.BLOCK and d_in > 1:
                raise ValueError(f"block component {n!r} must have a single upstream")
            if t == ComponentType.SINK and d_out != 0:
                raise ValueError(f"sink {n!r} has outgoing edges")
            if d_in == 0 and t not in (ComponentType.SOURCE,):
                raise ValueError(f"{n!r} has in-degree 0 but is not a source")

    def reset_stats(self) -> None:
        for c in self.vertices.values():
            c.reset_stats()

    def __len__(self) -> int:
        return len(self.vertices)

    def __repr__(self) -> str:
        return (f"Dataflow({self.name!r}, |V|={len(self.vertices)}, "
                f"|E|={len(self.edges)})")
