"""Dataflow DAG — Definition 1: G(V, E), V = activities over row sets,
E = logical transitions."""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .component import Component, ComponentType


class Dataflow:
    """A directed acyclic graph of components."""

    def __init__(self, name: str = "dataflow"):
        self.name = name
        self.vertices: Dict[str, Component] = {}
        self.edges: List[Tuple[str, str]] = []
        self._succ: Dict[str, List[str]] = {}
        self._pred: Dict[str, List[str]] = {}

    # ------------------------------------------------------------- building
    def add(self, comp: Component) -> Component:
        if comp.name in self.vertices:
            raise ValueError(f"duplicate component name {comp.name!r}")
        self.vertices[comp.name] = comp
        self._succ[comp.name] = []
        self._pred[comp.name] = []
        return comp

    def connect(self, u, v) -> None:
        """Add edge u -> v.  Accepts names or components."""
        un = u if isinstance(u, str) else u.name
        vn = v if isinstance(v, str) else v.name
        for n in (un, vn):
            if n not in self.vertices:
                raise KeyError(f"unknown component {n!r}")
        self.edges.append((un, vn))
        self._succ[un].append(vn)
        self._pred[vn].append(un)

    def chain(self, *comps) -> None:
        """Convenience: add (if needed) and connect comps in sequence."""
        prev = None
        for c in comps:
            if (c.name if isinstance(c, Component) else c) not in self.vertices:
                self.add(c)
            if prev is not None:
                self.connect(prev, c)
            prev = c

    # ------------------------------------------------------------- queries
    def succ(self, name: str) -> List[str]:
        return self._succ[name]

    def pred(self, name: str) -> List[str]:
        return self._pred[name]

    def in_degree(self, name: str) -> int:
        return len(self._pred[name])

    def out_degree(self, name: str) -> int:
        return len(self._succ[name])

    def sources(self) -> List[str]:
        return [n for n in self.vertices if self.in_degree(n) == 0]

    def sinks(self) -> List[str]:
        return [n for n in self.vertices if self.out_degree(n) == 0]

    def component(self, name: str) -> Component:
        return self.vertices[name]

    # ---------------------------------------------------------- validation
    def topo_order(self) -> List[str]:
        indeg = {n: self.in_degree(n) for n in self.vertices}
        ready = sorted([n for n, d in indeg.items() if d == 0])
        order: List[str] = []
        ready_set = list(ready)
        while ready_set:
            n = ready_set.pop(0)
            order.append(n)
            for s in self._succ[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready_set.append(s)
        if len(order) != len(self.vertices):
            raise ValueError(f"dataflow {self.name!r} has a cycle")
        return order

    def validate(self) -> None:
        self.topo_order()  # acyclicity
        for n, comp in self.vertices.items():
            d_in, d_out = self.in_degree(n), self.out_degree(n)
            t = comp.ctype
            if t == ComponentType.SOURCE and d_in != 0:
                raise ValueError(f"source {n!r} has incoming edges")
            if d_in > 1 and t not in (ComponentType.SEMI_BLOCK, ComponentType.SINK):
                raise ValueError(
                    f"{n!r} ({t.value}) has in-degree {d_in}; only semi-block "
                    f"components may merge multiple upstreams (paper §3)")
            if t == ComponentType.BLOCK and d_in > 1:
                raise ValueError(f"block component {n!r} must have a single upstream")
            if t == ComponentType.SINK and d_out != 0:
                raise ValueError(f"sink {n!r} has outgoing edges")
            if d_in == 0 and t not in (ComponentType.SOURCE,):
                raise ValueError(f"{n!r} has in-degree 0 but is not a source")

    def reset_stats(self) -> None:
        for c in self.vertices.values():
            c.reset_stats()

    def __len__(self) -> int:
        return len(self.vertices)

    def __repr__(self) -> str:
        return (f"Dataflow({self.name!r}, |V|={len(self.vertices)}, "
                f"|E|={len(self.edges)})")
