"""Metadata store (§2): schema info of sources and processing components,
dataflow specifications, job/task planning info.  Import/export XML (as the
paper's implementation used) and JSON."""
from __future__ import annotations

import json
import xml.etree.ElementTree as ET
from typing import Dict, List, Optional

from .graph import Dataflow
from .partitioner import ExecutionTreeGraph

#: EngineRun.spec scalar fields serialized as XML attributes, with the
#: coercion applied on import (everything is a string in XML)
_RUN_INT_FIELDS = ("copies", "bytes_copied", "h2d_transfers", "h2d_bytes",
                   "d2h_transfers", "d2h_bytes", "dispatch_calls",
                   "arena_hits", "arena_misses", "arena_bytes_reused",
                   "shards")
_RUN_FLOAT_FIELDS = ("wall_time",)
_RUN_STR_FIELDS = ("engine", "backend", "run_id", "created", "git_sha",
                   "trace_file")


class MetadataStore:
    def __init__(self) -> None:
        self.component_specs: Dict[str, Dict[str, str]] = {}
        self.dataflows: Dict[str, dict] = {}
        self.partitions: Dict[str, dict] = {}
        self.runtime_plans: Dict[str, dict] = {}
        #: per-flow observed component statistics (core/optimizer.py)
        self.statistics: Dict[str, dict] = {}
        #: per-flow adaptive-optimization record: statistics snapshot, the
        #: applied rewrites, and the BEFORE (static) / AFTER (rewritten)
        #: partitionings + runtime plans side by side
        self.adaptive: Dict[str, dict] = {}
        #: per-flow instrumentation of the LAST engine run (EngineRun.spec):
        #: wall time, copies, h2d/d2h transfer counts+bytes, dispatch calls,
        #: CacheArena hit/miss/bytes-reused — the per-run cache statistics
        self.runs: Dict[str, dict] = {}

    # ----------------------------------------------------------- register
    def register_flow(self, flow: Dataflow) -> None:
        for name, comp in flow.vertices.items():
            self.component_specs[name] = comp.spec()
        self.dataflows[flow.name] = {
            "name": flow.name,
            "vertices": [comp.spec() for comp in flow.vertices.values()],
            "edges": [list(e) for e in flow.edges],
        }

    def register_partitioning(self, flow: Dataflow,
                              g_tau: ExecutionTreeGraph) -> None:
        self.partitions[flow.name] = {
            "trees": [{"id": t.tree_id, "root": t.root, "members": t.members}
                      for t in g_tau.trees],
            "edges": [list(e) for e in g_tau.edges],
        }

    def register_runtime_plan(self, flow: Dataflow, plan) -> None:
        """Record the executor sizing plan (pool width, per-edge channel
        depths + cache-size estimates) chosen for a run of ``flow``."""
        self.runtime_plans[flow.name] = plan.spec()

    def register_statistics(self, flow: Dataflow, stats) -> None:
        """Record the observed per-component statistics (rows in/out,
        selectivity, per-row time, cache bytes) collected by a calibration
        prefix or harvested from a prior run (``FlowStatistics.spec``)."""
        self.statistics[flow.name] = stats.spec()

    def register_run(self, flow: Dataflow, run) -> None:
        """Record one engine run's scalar instrumentation
        (``EngineRun.spec``): wall time, copy/transfer counters and the
        CacheArena reuse statistics attributed to that run."""
        self.runs[flow.name] = run.spec()

    @staticmethod
    def _partition_spec(g_tau) -> dict:
        return {
            "trees": [{"id": t.tree_id, "root": t.root, "members": t.members}
                      for t in g_tau.trees],
            "edges": [list(e) for e in g_tau.edges],
        }

    def register_adaptive(self, flow: Dataflow, *, stats, rewrites,
                          before_partition, before_plan,
                          after_partition, after_plan) -> None:
        """Record one adaptive (optimize_level=2) planning round: what was
        measured, which rewrites were applied, and the static-vs-rewritten
        partitioning + runtime plan side by side."""
        self.adaptive[flow.name] = {
            "statistics": stats.spec(),
            "rewrites": [r.spec() for r in rewrites],
            "before": {"partition": self._partition_spec(before_partition),
                       "plan": before_plan.spec()},
            "after": {"partition": self._partition_spec(after_partition),
                      "plan": after_plan.spec()},
        }

    def type_of(self, component_name: str) -> Optional[str]:
        spec = self.component_specs.get(component_name)
        return spec["type"] if spec else None

    # ---------------------------------------------------------------- XML
    def to_xml(self) -> str:
        root = ET.Element("metadata")
        comps = ET.SubElement(root, "components")
        for spec in self.component_specs.values():
            ET.SubElement(comps, "component", attrib=spec)
        flows = ET.SubElement(root, "dataflows")
        for df in self.dataflows.values():
            f = ET.SubElement(flows, "dataflow", attrib={"name": df["name"]})
            for e in df["edges"]:
                ET.SubElement(f, "edge", attrib={"src": e[0], "dst": e[1]})
        parts = ET.SubElement(root, "partitions")
        for name, p in self.partitions.items():
            pf = ET.SubElement(parts, "partition", attrib={"dataflow": name})
            for t in p["trees"]:
                ET.SubElement(pf, "tree", attrib={
                    "id": str(t["id"]), "root": t["root"],
                    "members": ",".join(t["members"])})
            for e in p["edges"]:
                ET.SubElement(pf, "tree-edge",
                              attrib={"src": str(e[0]), "dst": str(e[1])})
        runs = ET.SubElement(root, "runs")
        for name, spec in self.runs.items():
            attrib = {"dataflow": name}
            for k in _RUN_STR_FIELDS + _RUN_INT_FIELDS + _RUN_FLOAT_FIELDS:
                v = spec.get(k)
                if v is not None:       # None (e.g. no git repo) => omitted
                    attrib[k] = str(v)
            if spec.get("shard_rows"):
                # per-shard source row counts of a sharded run
                attrib["shard_rows"] = ",".join(
                    str(n) for n in spec["shard_rows"])
            r = ET.SubElement(runs, "run", attrib=attrib)
            for rw in spec.get("rewrites", []):
                ET.SubElement(r, "rewrite",
                              attrib={k: str(v) for k, v in rw.items()})
            for rf in spec.get("refusals", []):
                ET.SubElement(r, "refusal",
                              attrib={k: str(v) for k, v in rf.items()})
            metrics = spec.get("metrics")
            if metrics:
                # nested counters/gauges/histograms: carried as JSON text
                m = ET.SubElement(r, "metrics")
                m.text = json.dumps(metrics, sort_keys=True)
        return ET.tostring(root, encoding="unicode")

    @classmethod
    def from_xml(cls, text: str) -> "MetadataStore":
        store = cls()
        root = ET.fromstring(text)
        for c in root.find("components") or []:
            store.component_specs[c.attrib["name"]] = dict(c.attrib)
        for f in root.find("dataflows") or []:
            store.dataflows[f.attrib["name"]] = {
                "name": f.attrib["name"],
                "vertices": [],
                "edges": [[e.attrib["src"], e.attrib["dst"]] for e in f],
            }
        for pf in root.find("partitions") or []:
            store.partitions[pf.attrib["dataflow"]] = {
                "trees": [{"id": int(t.attrib["id"]), "root": t.attrib["root"],
                           "members": t.attrib["members"].split(",")}
                          for t in pf if t.tag == "tree"],
                "edges": [[int(e.attrib["src"]), int(e.attrib["dst"])]
                          for e in pf if e.tag == "tree-edge"],
            }
        for r in root.find("runs") if root.find("runs") is not None else []:
            spec: dict = {}
            for k in _RUN_STR_FIELDS:
                if k in r.attrib:
                    spec[k] = r.attrib[k]
            for k in _RUN_INT_FIELDS:
                if k in r.attrib:
                    spec[k] = int(r.attrib[k])
            for k in _RUN_FLOAT_FIELDS:
                if k in r.attrib:
                    spec[k] = float(r.attrib[k])
            if "shard_rows" in r.attrib:
                spec["shard_rows"] = [int(n) for n in
                                      r.attrib["shard_rows"].split(",")]
            spec.setdefault("git_sha", None)
            spec.setdefault("trace_file", None)
            spec["rewrites"] = [dict(ch.attrib) for ch in r
                                if ch.tag == "rewrite"]
            spec["refusals"] = [dict(ch.attrib) for ch in r
                                if ch.tag == "refusal"]
            m = r.find("metrics")
            spec["metrics"] = json.loads(m.text) if m is not None else {}
            store.runs[r.attrib["dataflow"]] = spec
        return store

    # --------------------------------------------------------------- JSON
    def to_json(self) -> str:
        return json.dumps({"components": self.component_specs,
                           "dataflows": self.dataflows,
                           "partitions": self.partitions,
                           "runtime_plans": self.runtime_plans,
                           "statistics": self.statistics,
                           "adaptive": self.adaptive,
                           "runs": self.runs}, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "MetadataStore":
        store = cls()
        d = json.loads(text)
        store.component_specs = d.get("components", {})
        store.dataflows = d.get("dataflows", {})
        store.partitions = d.get("partitions", {})
        store.runtime_plans = d.get("runtime_plans", {})
        store.statistics = d.get("statistics", {})
        store.adaptive = d.get("adaptive", {})
        store.runs = d.get("runs", {})
        return store
