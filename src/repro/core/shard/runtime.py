"""ShardRunner: the multi-pass coordinator for one sharded run.

Execution model (all routes): N "shard passes" over the SAME flow object
— sources re-pointed at shard k's row partition, every cut component in
``partial`` mode — then ONE "merge" pass with empty sources and cuts in
``merge`` mode, which reassembles the exact serial result from the
stashed partials (see ``merge.py``).  Between passes only transient
pipeline state resets (``next_split``/``busy``), so compiled segment
kernels, device-resident DimTables and arena buffers stay warm exactly
like the serving loop.

Routes (``ShardPlan.impl``):

``inline``   shard passes run sequentially in-process — the always-
             available correctness route (and the fallback rung).
``process``  shard passes fan out to spawned worker processes, each
             shipped a pickled flow carrying ONLY its shard's source rows
             (scatter, not broadcast); workers return partial stashes +
             sink harvests + their exact CacheStats snapshot.  Falls back
             to ``inline`` (recorded degradation) for unpicklable flows,
             broken pools, or when a scoped fault plan / tracer is active
             (contextvar scopes cannot cross a process boundary).
``mesh``     inline passes, but Aggregate second-stage merges run through
             a jax ``shard_map`` reduction over a data-only host mesh
             (``launch/mesh.py``).

Fault tolerance: each shard pass is wrapped in ``faults.inject("shard")``
plus transient-retry with whole-shard replay — the pass's stashes and
sink writes roll back, the shard's source partition is re-installed, and
completed shards stay untouched.  The merge pass replays the same way
(stashes are read non-destructively).

Observability: each shard pass runs under its own nested
``cache_stats_scope`` (the run scope sums them automatically) and — when
the run is traced — a nested per-shard sub-``Tracer`` that exports as its
own shard-tagged Perfetto pid.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ...obs import trace as obs_trace
from .. import config, faults
from ..executor import SharedWorkerPool, StreamingExecutor
from ..shared_cache import SharedCache, absorb_external, cache_stats_scope
from .merge import ShardContext
from .partitioner import shard_tables, table_bytes, table_rows
from .planner import ShardPlan


@dataclass
class ShardResult:
    """What the engine folds into the EngineRun after a sharded execute."""
    shards: int
    impl: str                                  # route actually used
    mode: str
    shard_rows: List[int] = field(default_factory=list)
    #: per-shard exact CacheStats snapshots (process route: the worker's)
    shard_stats: List[Dict[str, int]] = field(default_factory=list)
    merge_stats: Dict[str, int] = field(default_factory=dict)
    #: worker-process counters the parent scope never saw (added to the run)
    extra_stats: Dict[str, int] = field(default_factory=dict)
    scatter_bytes: int = 0                     # max bytes shipped to one shard
    source_bytes: int = 0                      # total source bytes
    shuffle_bytes: int = 0                     # stashed partial bytes
    replays: int = 0                           # whole-shard replays taken
    #: dispatch calls made on worker-process flow copies (process route);
    #: the parent flow's own counters never see them
    worker_dispatch: int = 0
    pool_stats: Dict[str, int] = field(default_factory=dict)
    streamed_edges: List = field(default_factory=list)
    channel_hwm: int = 0


def _sum_stats(*snaps: Dict[str, int]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for snap in snaps:
        for k, v in snap.items():
            out[k] = out.get(k, 0) + v
    return out


class ShardRunner:
    def __init__(self, flow, g_tau, options, runtime_plan, plan: ShardPlan,
                 tracer=None):
        self.flow = flow
        self.g_tau = g_tau
        self.options = options
        self.runtime_plan = runtime_plan
        self.plan = plan
        self.tracer = tracer
        self.pool: Optional[SharedWorkerPool] = None

    # ------------------------------------------------------------- helpers
    def _reset_transient(self) -> None:
        for comp in self.flow.vertices.values():
            comp.next_split = 0
            comp.busy = False

    def _sinks(self):
        return [self.flow.component(s) for s in self.flow.sinks()]

    def _drop_sink_writes(self) -> None:
        for sink in self._sinks():
            for cache in sink.drain():
                cache.recycle()

    def _run_executor(self, res: ShardResult) -> None:
        executor = StreamingExecutor(self.flow, self.g_tau, self.options,
                                     self.runtime_plan, pool=self.pool)
        try:
            executor.execute()
        finally:
            res.channel_hwm = max(res.channel_hwm, executor.channel_hwm())
            res.streamed_edges = list(executor.streamed_edges)
            executor.shutdown()          # no-op: the pool is shared

    # ------------------------------------------------------------ execute
    def execute(self) -> ShardResult:
        flow, plan = self.flow, self.plan
        res = ShardResult(shards=plan.shards, impl=plan.impl, mode=plan.mode)
        sources = [(name, flow.component(name)) for name in plan.sources]
        orig = {name: comp.columns for name, comp in sources}
        res.source_bytes = sum(table_bytes(t) for t in orig.values())
        parts = shard_tables(orig, plan.shards, plan.mode, plan.key)
        res.scatter_bytes = max(
            (sum(table_bytes(t) for t in p.values()) for p in parts),
            default=0)
        harvest: Dict[str, List[SharedCache]] = {
            s.name: [] for s in self._sinks()}

        impl = plan.impl
        if impl == "process":
            impl = self._process_preflight(impl)
        combiner = None
        if impl == "mesh":
            from .mesh import make_combiner
            combiner = make_combiner()
            if combiner is None:
                faults.record_degradation("shard_impl", "mesh", "inline",
                                          component=flow.name)
                impl = "inline"
        res.impl = impl
        ctx = ShardContext(combiner=combiner)
        cuts = [flow.component(name) for name in plan.cuts]
        try:
            for comp in cuts:
                comp.shard_role = "partial"
                comp._shard_ctx = ctx
            if impl == "process":
                self._run_process_passes(parts, ctx, harvest, res)
            else:
                self.pool = SharedWorkerPool(
                    self.runtime_plan.pool_width,
                    name=f"{flow.name}-shard")
                self._run_inline_passes(sources, parts, ctx, harvest, res)
            if self.pool is None:
                self.pool = SharedWorkerPool(
                    self.runtime_plan.pool_width,
                    name=f"{flow.name}-shard")
            # ---------------------------------------------- merge pass
            for comp in cuts:
                comp.shard_role = "merge"
            for name, comp in sources:
                comp.set_data({k: v[:0] for k, v in orig[name].items()})
            ctx.begin_merge()
            with cache_stats_scope() as mstats, \
                    obs_trace.span("phase", "shard-merge",
                                   shards=plan.shards, impl=impl,
                                   mode=plan.mode):
                self._with_replay(
                    "merge", lambda: self._merge_attempt(res), ctx, res,
                    rollback=self._drop_sink_writes)
            res.merge_stats = mstats.snapshot()
            # ------------------------------------------- sink reassembly
            for sink in self._sinks():
                buf = sink.drain()
                if buf:
                    # cut-fed sink: the merge pass wrote the serial result;
                    # shard-pass harvests were schema-empties
                    sink.reinject(buf)
                    for cache in harvest[sink.name]:
                        cache.recycle()
                else:
                    # row-synchronized-fed sink: the harvested shard-pass
                    # caches, renumbered shard-major, ARE the serial rows
                    for i, cache in enumerate(harvest[sink.name]):
                        cache.split_index = i
                    sink.reinject(harvest[sink.name])
                harvest[sink.name] = []
        finally:
            for comp in cuts:
                comp.shard_role = None
                if hasattr(comp, "_shard_ctx"):
                    del comp._shard_ctx
            for name, comp in sources:
                comp.set_data(orig[name])
            for caches in harvest.values():
                for cache in caches:
                    cache.recycle()
            if self.pool is not None:
                res.pool_stats = self.pool.stats()
                self.pool.shutdown()
        res.shuffle_bytes = ctx.shuffle_bytes
        return res

    def _merge_attempt(self, res: ShardResult) -> None:
        self._reset_transient()
        self._run_executor(res)

    # -------------------------------------------------------- shard replay
    def _with_replay(self, label: str, attempt_fn, ctx: ShardContext,
                     res: ShardResult, rollback=None,
                     inject_split: Optional[int] = None) -> None:
        """Run one pass with transient-failure replay: roll back the pass's
        stashes/sink writes, then rerun, up to ``REPRO_RETRY_MAX`` times."""
        attempt, delay = 0, config.retry_backoff()
        while True:
            try:
                # merge attempts inject with split=None — the coordinator
                # pass is a chaos target too, and its replay is covered
                faults.inject("shard", component=self.flow.name,
                              split=inject_split)
                attempt_fn()
                return
            except BaseException as e:
                if (faults.classify(e) != "transient"
                        or attempt >= config.retry_max()):
                    raise
                faults.record_retry(f"shard.{self.flow.name}.{label}",
                                    attempt, delay)
                res.replays += 1
                if inject_split is not None:
                    ctx.rollback_pass(inject_split)
                self._drop_sink_writes()
                if rollback is not None:
                    rollback()
                if delay > 0.0:
                    time.sleep(delay)
                delay = min(delay * 2.0 if delay else 0.0,
                            faults.RETRY_BACKOFF_CAP_S)
                attempt += 1

    # ------------------------------------------------------- inline / mesh
    def _run_inline_passes(self, sources, parts, ctx: ShardContext,
                           harvest, res: ShardResult) -> None:
        for k in range(self.plan.shards):
            sub = None
            if self.tracer is not None:
                sub = obs_trace.Tracer(
                    name=f"{self.flow.name}[shard{k}]", measuring=False)
                sub.meta = dict(self.tracer.meta, shard=k,
                                flow=f"{self.flow.name}[shard{k}]")
                self.tracer.shard_tracers.append(sub)

            def one_pass(k=k):
                for name, comp in sources:
                    comp.set_data(parts[k][name])
                self._reset_transient()
                ctx.begin_pass(k)
                with obs_trace.span("phase", f"shard-{k}", shard=k):
                    self._run_executor(res)

            with cache_stats_scope() as sstats, \
                    (obs_trace.trace_scope(sub) if sub is not None
                     else nullcontext()):
                self._with_replay(str(k), one_pass, ctx, res,
                                  inject_split=k)
                for sink in self._sinks():
                    # drain() yields arrival order; streamed splits can
                    # finish out of order, and the shard-major renumber at
                    # reassembly erases split_index — restore split order
                    # here so serial ordering survives
                    harvest[sink.name].extend(
                        sorted(sink.drain(), key=lambda c: c.split_index))
            res.shard_stats.append(sstats.snapshot())
            res.shard_rows.append(
                sum(table_rows(t) for t in parts[k].values()))

    # ------------------------------------------------------------- process
    def _process_preflight(self, impl: str) -> str:
        """Scoped fault plans / tracers live in contextvars and cannot
        follow work into a spawned process; degrade to inline so their
        semantics (deterministic injection, exact event capture) hold."""
        if faults._SCOPES.get() or obs_trace.ACTIVE.get():
            faults.record_degradation("shard_impl", "process", "inline",
                                      component=self.flow.name)
            return "inline"
        return impl

    def _run_process_passes(self, parts, ctx: ShardContext, harvest,
                            res: ShardResult) -> None:
        from . import proc
        payloads = proc.build_payloads(self.flow, self.options,
                                       self.plan, parts)
        if payloads is None:            # unpicklable flow
            faults.record_degradation("shard_impl", "process", "inline",
                                      component=self.flow.name)
            res.impl = "inline"
            sources = [(n, self.flow.component(n)) for n in self.plan.sources]
            self.pool = SharedWorkerPool(self.runtime_plan.pool_width,
                                         name=f"{self.flow.name}-shard")
            self._run_inline_passes(sources, parts, ctx, harvest, res)
            return
        try:
            shard_payloads = proc.run_passes(self.flow, payloads, ctx, res)
        except proc.ProcessRouteUnavailable as e:
            faults.record_degradation("shard_impl", "process", "inline",
                                      component=self.flow.name, error=str(e))
            res.impl = "inline"
            sources = [(n, self.flow.component(n)) for n in self.plan.sources]
            self.pool = SharedWorkerPool(self.runtime_plan.pool_width,
                                         name=f"{self.flow.name}-shard")
            self._run_inline_passes(sources, parts, ctx, harvest, res)
            return
        for k, payload in enumerate(shard_payloads):
            ctx.absorb(payload["agg"], payload["generic"])
            for name, entries in payload["sinks"].items():
                # workers ship sink caches in arrival order; sort by the
                # original split index so the shard-major renumber at
                # reassembly preserves serial ordering
                for (split_index, cols, n) in sorted(
                        entries, key=lambda e: e[0]):
                    harvest[name].append(SharedCache(cols, n, split_index))
            res.shard_stats.append(payload["stats"])
            res.shard_rows.append(payload["rows"])
            res.worker_dispatch += payload.get("dispatch", 0)
        res.extra_stats = _sum_stats(*res.shard_stats)
        # the workers' counters never hit this process's collectors; fold
        # them into the global stats and every active scope (the engine's
        # run scope included) so sharded runs attribute identically to
        # in-process ones
        absorb_external(res.extra_stats)
