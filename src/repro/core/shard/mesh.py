"""Mesh route: Aggregate second-stage merges through jax ``shard_map``.

``make_combiner`` builds the device-mesh reducer the merge pass hands to
``Aggregate.shard_merge``: concatenated per-shard partial rows are
aligned to dense ``[groups]`` vectors (group ids from ``np.unique`` over
the key tuple — lexicographic, matching the backend's group order),
scattered over the ``(data,)`` axis of a host mesh
(``launch.mesh.make_host_mesh(model=None)``), locally segment-reduced on
each device, and combined with ``psum``/``pmin``/``pmax``.

Exactness contract: outputs are cast back to the stage-1 partial dtypes,
and when jax runs without x64 the combiner refuses (returns ``None`` —
the caller falls back to the host ``reduce_partials``) any input whose
values would not round-trip through the 32-bit canonical dtypes.  Rows
padded to a multiple of the device count carry the op identity and land
in group 0, so they never perturb a real group.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np


def _canon_dtype(dtype: np.dtype, x64: bool) -> np.dtype:
    """The dtype jax will actually compute in."""
    if x64 or dtype.itemsize <= 4 or dtype.kind not in "iuf":
        return dtype
    return np.dtype({"i": np.int32, "u": np.uint32, "f": np.float32}[dtype.kind])


def _round_trips(v: np.ndarray, cd: np.dtype) -> bool:
    if cd == v.dtype or v.size == 0:
        return True
    return bool(np.array_equal(v.astype(cd).astype(v.dtype), v))


def _identity(op: str, dtype: np.dtype):
    if op == "sum":
        return dtype.type(0)
    if dtype.kind in "iu":
        info = np.iinfo(dtype)
        return info.max if op == "min" else info.min
    return dtype.type(np.inf if op == "min" else -np.inf)


def make_combiner() -> Optional[Callable]:
    """A ``combine(cat, group_names, ops)`` closure with the same contract
    as ``merge.reduce_partials`` — except it may return ``None`` per call
    (unsafe dtypes), in which case the caller uses the host reduce.
    Returns ``None`` outright when jax or a device mesh is unavailable."""
    try:
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from ...launch.jax_compat import shard_map
        from ...launch.mesh import make_host_mesh
        devices = jax.devices()
        if not devices:
            return None
        D = len(devices)
        mesh = make_host_mesh(data=D, model=None)
    except Exception:
        return None
    x64 = bool(getattr(jax.config, "jax_enable_x64", False))

    def _mesh_reduce(v: np.ndarray, inv: np.ndarray, n_groups: int,
                     op: str) -> np.ndarray:
        ident = _identity(op, v.dtype)

        def local(vv, ii):
            if op == "sum":
                acc = jnp.zeros((n_groups,), dtype=vv.dtype).at[ii].add(vv)
                return jax.lax.psum(acc, "data")
            full = jnp.full((n_groups,), ident, dtype=vv.dtype)
            if op == "min":
                return jax.lax.pmin(full.at[ii].min(vv), "data")
            return jax.lax.pmax(full.at[ii].max(vv), "data")

        f = shard_map(local, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=P(), check_vma=False)
        return np.asarray(f(jnp.asarray(v), jnp.asarray(inv)))

    def combine(cat: Dict[str, np.ndarray], group_names: Sequence[str],
                ops: Dict[str, str]
                ) -> Optional[Tuple[list, Dict[str, np.ndarray]]]:
        keys = [np.asarray(cat[g]) for g in group_names]
        vals = {p: np.asarray(cat[p]) for p in ops}
        n = len(next(iter(vals.values()))) if vals else 0
        if n == 0:
            return None
        for arr in (*keys, *vals.values()):
            if arr.dtype.kind not in "iufb":
                return None
            if not _round_trips(arr, _canon_dtype(arr.dtype, x64)):
                return None
        if keys:
            uniq, inv = np.unique(np.stack(keys, axis=1), axis=0,
                                  return_inverse=True)
            n_groups = len(uniq)
            group_cols = [uniq[:, j].astype(k.dtype, copy=False)
                          for j, k in enumerate(keys)]
        else:
            inv, n_groups, group_cols = np.zeros(n, np.int64), 1, []
        pad = (-n) % D
        inv_p = np.concatenate(
            [inv.reshape(-1), np.zeros(pad, inv.dtype)]).astype(np.int32)
        part_cols: Dict[str, np.ndarray] = {}
        for p, op in ops.items():
            v = vals[p]
            cd = _canon_dtype(v.dtype, x64)
            v_p = np.concatenate(
                [v.astype(cd, copy=False),
                 np.full(pad, _identity(op, cd), dtype=cd)])
            out = _mesh_reduce(v_p, inv_p, n_groups, op)
            part_cols[p] = out.astype(v.dtype, copy=False)
        return group_cols, part_cols

    return combine
