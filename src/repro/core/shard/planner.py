"""ShardPlanner: decide whether / how to shard a run.

``plan_shards`` is the single entry point the engine calls.  It either
returns a ``ShardPlan`` (shard count, partitioning mode + key, resolved
impl route, the flow's cut components) or ``None`` for the serial path —
recording a ``shard_plan`` degradation when sharding was requested but the
flow cannot support it, so the fallback is observable rather than silent.

The auto shard count (``shards=0`` / ``REPRO_SHARDS=0``) mirrors how
``plan_runtime`` picks pipeline degree: bounded by the hardware core
count and the split count, and by a minimum rows-per-shard floor so tiny
inputs never pay multi-pass overhead for nothing.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .. import config, faults
from ..component import ComponentType

#: below this many rows per shard, extra shards cost more than they win
MIN_SHARD_ROWS = 4096
#: auto mode never picks more than this many shards
MAX_AUTO_SHARDS = 8


@dataclass
class ShardPlan:
    """One sharded run's layout, as chosen by ``plan_shards``."""
    shards: int
    impl: str                              # resolved: process | mesh | inline
    mode: str                              # "range" | "hash"
    key: Tuple[str, ...] = ()              # hash key columns (mode == "hash")
    sources: List[str] = field(default_factory=list)
    cuts: List[str] = field(default_factory=list)

    def spec(self) -> Dict[str, object]:
        return {"shards": self.shards, "impl": self.impl, "mode": self.mode,
                "key": list(self.key), "sources": list(self.sources),
                "cuts": list(self.cuts)}


def choose_shards(total_rows: int, num_splits: int,
                  cores: Optional[int] = None) -> int:
    """Auto shard count — same shape as ``planner.choose_degree``: capped
    by hardware parallelism and by the split count (more shards than
    splits just idles), with a rows-per-shard floor."""
    hw = cores if cores is not None else (os.cpu_count() or 1)
    by_rows = max(1, total_rows // MIN_SHARD_ROWS)
    return max(1, min(hw, max(num_splits, 1), by_rows, MAX_AUTO_SHARDS))


def _degrade(requested: int, reason: str, component=None) -> None:
    faults.record_degradation("shard_plan", f"shards={requested}", "serial",
                              component=component)
    _ = reason        # reasons surface via the degradation component field


def _first_contact(flow) -> Tuple[Set[str], bool]:
    """Walk from every source through row-synchronized components only.
    Returns (cut components reached first, whether any sink is reachable
    without crossing a cut)."""
    firsts: Set[str] = set()
    sink_direct = False
    seen: Set[str] = set()
    stack = list(flow.sources())
    while stack:
        name = stack.pop()
        if name in seen:
            continue
        seen.add(name)
        for succ in flow.succ(name):
            comp = flow.component(succ)
            if comp.ctype.roots_tree:
                firsts.add(succ)
            elif comp.ctype is ComponentType.SINK:
                sink_direct = True
            else:
                stack.append(succ)
    return firsts, sink_direct


def _pick_mode(flow, sources: List[str]) -> Tuple[str, Tuple[str, ...]]:
    """``hash`` when every source→sink path first meets an Aggregate keyed
    on integer source columns (all first-layer aggregates sharing one key
    set) — then shards are group-disjoint and even float partials merge
    exactly.  Everything else takes ``range``, whose shard-order reassembly
    preserves serial row order."""
    firsts, sink_direct = _first_contact(flow)
    if sink_direct or not firsts:
        return "range", ()
    keys: Set[Tuple[str, ...]] = set()
    for name in firsts:
        comp = flow.component(name)
        if not (hasattr(comp, "shard_partial") and hasattr(comp, "group_by")):
            return "range", ()
        if not comp.group_by:
            return "range", ()       # global aggregate: nothing to key on
        keys.add(tuple(comp.group_by))
    if len(keys) != 1:
        return "range", ()
    key = keys.pop()
    for sname in sources:
        cols = flow.component(sname).columns
        for k in key:
            col = cols.get(k)
            if col is None or np.asarray(col).dtype.kind not in "iub":
                return "range", ()
    return "hash", key


def plan_shards(flow, g_tau, requested: int, impl: str, opts,
                backend) -> Optional[ShardPlan]:
    """Decide the shard layout for one run, or ``None`` for serial.

    ``requested`` is the resolved shard count (0 = auto); ``impl`` the
    requested route (``auto`` resolves here: ``mesh`` on the jax backend,
    ``inline`` otherwise — ``process`` only when asked for, since spawning
    workers is a policy choice, not a default)."""
    if requested == 1:
        return None
    if impl not in config.SHARD_IMPLS:
        raise ValueError(f"unknown shard impl {impl!r}; "
                         f"expected one of {config.SHARD_IMPLS}")
    sources = list(flow.sources())
    if not sources:
        _degrade(requested, "no sources")
        return None
    for sname in sources:
        comp = flow.component(sname)
        if not (hasattr(comp, "set_data") and hasattr(comp, "total_rows")
                and hasattr(comp, "columns")):
            _degrade(requested, "unshardable source", component=sname)
            return None
        if getattr(comp, "chunk_sensitive", False):
            _degrade(requested, "chunk-sensitive source", component=sname)
            return None
    for sink in flow.sinks():
        comp = flow.component(sink)
        if not (hasattr(comp, "drain") and hasattr(comp, "clear")):
            _degrade(requested, "unshardable sink", component=sink)
            return None
        trees = {g_tau.tree_of[p] for p in flow.pred(sink)}
        trees.add(g_tau.tree_of[sink])
        if len(trees) > 1:
            # a sink shared across trees interleaves shard-pass and
            # merge-pass rows; the reassembly rule has no serial order for
            # that, so it stays on the serial path
            _degrade(requested, "cross-tree sink", component=sink)
            return None
    total_rows = sum(flow.component(s).total_rows() for s in sources)
    n = requested
    if n == 0:
        n = choose_shards(total_rows, opts.num_splits, cores=opts.cores)
    if n <= 1:
        return None
    if impl == "auto":
        impl = "mesh" if getattr(backend, "name", "") == "jax" else "inline"
    mode, key = _pick_mode(flow, sources)
    cuts = [t.root for t in g_tau.trees
            if flow.component(t.root).ctype.roots_tree]
    return ShardPlan(shards=n, impl=impl, mode=mode, key=key,
                     sources=sources, cuts=cuts)
