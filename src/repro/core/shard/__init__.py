"""Sharded execution subsystem (ROADMAP item 1).

One process behind the GIL (or one jax device) caps the framework's
parallelism; this package scales a dataflow ACROSS shards while keeping
every sink byte-identical to the serial route:

  ShardPlanner   hash/range-partitions the source rows over N shards
                 (``planner.plan_shards`` — N chosen from the same
                 signals ``plan_runtime`` uses for pipeline degree)
  shard workers  run the FULL per-shard flow: in-process passes
                 (``inline``), spawned worker processes shipping a
                 picklable flow spec (``process``), or inline passes with
                 a jax ``shard_map`` device-mesh merge (``mesh``) —
                 selected by ``REPRO_SHARD_IMPL`` / OptimizeOptions
  partial→shuffle→merge
                 block/semi-block cut components stash per-shard partials
                 (Aggregate reuses the serving ``(sum,count)`` partial
                 machinery) and a single coordinator merge pass combines
                 them into the exact serial result (``merge.py``)

The runtime composes with the existing layers: ``OptimizedEngine.run``
drives it under the run's ``cache_stats_scope``/Tracer (per-shard scopes
and shard-tagged Perfetto pids merge into one ``EngineRun``), and
``faults.py`` chunk/edge retries escalate to whole-shard replay from the
shard's source snapshot instead of aborting the run.
"""
from .merge import ShardContext
from .partitioner import hash_shard_ids, range_bounds, shard_tables
from .planner import ShardPlan, choose_shards, plan_shards
from .runtime import ShardResult, ShardRunner

__all__ = [
    "ShardContext", "ShardPlan", "ShardResult", "ShardRunner",
    "choose_shards", "hash_shard_ids", "plan_shards", "range_bounds",
    "shard_tables",
]
