"""Partial→shuffle→merge state for sharded runs.

Every block/semi-block tree root (a "cut") gets a ``shard_role`` for the
run's duration and the executor routes its ``finish`` through the shared
``ShardContext`` here:

``partial`` (shard passes)
    Aggregate-like cuts (anything with ``shard_partial``) reduce their
    accumulated input to a keyed partial table — the serving
    ``(sum,count)`` decomposition from PR 8 — and stash it.  Every other
    cut (Sort/Union/Merge/custom) stashes its raw accumulated caches as
    host snapshots tagged ``(pass, src_tree, split)``.  Both return an
    empty schema-shaped cache, so downstream components see the run's
    shape but no rows: no full-table broadcast ever crosses a shard
    boundary, only partials ("shuffle" is the stash hand-off to the
    coordinator).

``merge`` (one final coordinator pass over empty sources)
    Aggregate cuts second-stage-reduce the stashed partials (plus any
    partials from their own final-pass input, for cut-ancestored
    aggregates).  Generic cuts reassemble their serial input: per source
    tree, either the stashed shard rows in (shard, split) order — a
    row-synchronized-fed tree, whose final-pass deliveries are empty — or
    the final-pass deliveries themselves (a cut-ancestored tree, already
    serial-exact).  Split indices are renumbered sequentially so the real
    ``finish`` sees exactly the serial accumulation order.

The merge pass is replayable: stashes are read without being consumed and
reconstructed caches copy the stashed arrays (``finish`` mutates its
input in place), so a transient merge-pass fault just reruns the pass.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..shared_cache import SharedCache

#: (pass_k, src_tree, split_index, host columns, n_rows)
GenericStash = Tuple[int, int, int, Dict[str, np.ndarray], int]


def reduce_partials(cat: Dict[str, np.ndarray], group_names: Sequence[str],
                    ops: Dict[str, str]
                    ) -> Tuple[List[np.ndarray], Dict[str, np.ndarray]]:
    """Host second-stage reduce over concatenated per-shard partial tables.

    Deterministic dtype-preserving numpy (``reduceat`` over a stable
    lexsort): value partials re-reduce with their own op, count partials
    sum — keeping each partial's stage-1 dtype, so e.g. an int64 count
    stays int64 exactly as the serial one-shot reduce emits it."""
    keys = [np.asarray(cat[g]) for g in group_names]
    if not keys:
        out: Dict[str, np.ndarray] = {}
        for p, op in ops.items():
            v = np.asarray(cat[p])
            if op == "sum":
                out[p] = np.array([v.sum()], dtype=v.dtype)
            elif op == "min":
                out[p] = np.array([v.min()], dtype=v.dtype)
            elif op == "max":
                out[p] = np.array([v.max()], dtype=v.dtype)
            else:
                raise ValueError(f"unmergeable second-stage op {op!r}")
        return [], out
    n = len(keys[0])
    order = np.lexsort(keys[::-1])
    sk = [k[order] for k in keys]
    boundary = np.zeros(n, dtype=bool)
    if n:
        boundary[0] = True
    for k in sk:
        boundary[1:] |= k[1:] != k[:-1]
    starts = np.flatnonzero(boundary)
    group_cols = [k[starts] for k in sk]
    part_cols: Dict[str, np.ndarray] = {}
    for p, op in ops.items():
        v = np.asarray(cat[p])[order]
        if op == "sum":
            part_cols[p] = np.add.reduceat(v, starts)
        elif op == "min":
            part_cols[p] = np.minimum.reduceat(v, starts)
        elif op == "max":
            part_cols[p] = np.maximum.reduceat(v, starts)
        else:
            raise ValueError(f"unmergeable second-stage op {op!r}")
    return group_cols, part_cols


class ShardContext:
    """Shared stash + finish-interception for one sharded run.

    Installed on every cut component as ``_shard_ctx`` alongside
    ``shard_role``; cut finishes run on pool threads, so stash mutation is
    lock-guarded.  ``combiner`` is the optional mesh-route second-stage
    reducer (``mesh.make_combiner``) Aggregate cuts merge through."""

    def __init__(self, combiner: Optional[Callable] = None):
        self._lock = threading.Lock()
        self.pass_k: Optional[int] = None        # None => merge pass
        self.combiner = combiner
        #: cut name -> [(pass_k, partial table)]
        self.agg_partials: Dict[str, List[Tuple[int, dict]]] = {}
        #: cut name -> [GenericStash]
        self.generic: Dict[str, List[GenericStash]] = {}
        #: bytes stashed for the coordinator merge (the "shuffle" volume)
        self.shuffle_bytes = 0

    # ------------------------------------------------------------- passes
    def begin_pass(self, k: int) -> None:
        self.pass_k = k

    def begin_merge(self) -> None:
        self.pass_k = None

    def rollback_pass(self, k: int) -> None:
        """Drop everything pass ``k`` stashed — a failed shard replays from
        its source snapshot, and completed shards' stashes stay intact."""
        with self._lock:
            for lst in self.agg_partials.values():
                lst[:] = [e for e in lst if e[0] != k]
            for lst in self.generic.values():
                lst[:] = [e for e in lst if e[0] != k]

    def absorb(self, cut_aggs: Dict[str, List[Tuple[int, dict]]],
               cut_generic: Dict[str, List[GenericStash]]) -> None:
        """Fold a process-route worker's stashes into the coordinator."""
        with self._lock:
            for name, lst in cut_aggs.items():
                self.agg_partials.setdefault(name, []).extend(lst)
                for _, t in lst:
                    self.shuffle_bytes += sum(
                        np.asarray(v).nbytes for v in t.values())
            for name, lst in cut_generic.items():
                self.generic.setdefault(name, []).extend(lst)
                for e in lst:
                    self.shuffle_bytes += sum(
                        np.asarray(v).nbytes for v in e[3].values())

    def export(self) -> Tuple[dict, dict]:
        """The stashes, for shipping from a process-route worker."""
        with self._lock:
            return dict(self.agg_partials), dict(self.generic)

    # ------------------------------------------------------ interception
    def intercept_finish(self, root, state: List[SharedCache],
                         tags: List[Tuple[int, int]]) -> SharedCache:
        """Replacement for ``root.finish(state)`` while ``shard_role`` is
        set.  ``tags`` carries the executor's ``(src_tree, split_index)``
        per accumulated cache, in accumulation order."""
        if root.shard_role == "partial":
            if hasattr(root, "shard_partial"):
                return self._partial_agg(root, state)
            return self._partial_generic(root, state, tags)
        if hasattr(root, "shard_partial"):
            return self._merge_agg(root, state)
        return self._merge_generic(root, state, tags)

    # ---------------------------------------------------------- partials
    def _partial_agg(self, root, state: List[SharedCache]) -> SharedCache:
        part = root.shard_partial(state)          # consumes + recycles state
        if part is not None:
            with self._lock:
                self.agg_partials.setdefault(root.name, []).append(
                    (self.pass_k, part))
                self.shuffle_bytes += sum(
                    np.asarray(v).nbytes for v in part.values())
        return root.shard_empty()

    def _partial_generic(self, root, state: List[SharedCache],
                         tags: List[Tuple[int, int]]) -> SharedCache:
        entries: List[GenericStash] = []
        schema: Optional[Dict[str, np.ndarray]] = None
        for (src, idx), cache in zip(tags, state):
            cols = cache.to_dict()
            if schema is None:
                schema = cols
            entries.append((self.pass_k, src, idx, cols, cache.n))
            cache.recycle()
        with self._lock:
            self.generic.setdefault(root.name, []).extend(entries)
            self.shuffle_bytes += sum(
                np.asarray(v).nbytes
                for (_, _, _, cols, n) in entries if n for v in cols.values())
        if schema is None:
            return SharedCache({}, 0)
        return SharedCache({k: v[:0] for k, v in schema.items()}, 0)

    # ------------------------------------------------------------ merges
    def _merge_agg(self, root, state: List[SharedCache]) -> SharedCache:
        with self._lock:
            stash = sorted(self.agg_partials.get(root.name, []),
                           key=lambda e: e[0])
        return root.shard_merge(state, [t for _, t in stash],
                                combiner=self.combiner)

    def _merge_generic(self, root, state: List[SharedCache],
                       tags: List[Tuple[int, int]]) -> SharedCache:
        with self._lock:
            stash = list(self.generic.get(root.name, []))
        fin: Dict[int, List[Tuple[int, SharedCache]]] = {}
        for (src, idx), cache in zip(tags, state):
            fin.setdefault(src, []).append((idx, cache))
        by_src: Dict[int, List[GenericStash]] = {}
        for e in stash:
            by_src.setdefault(e[1], []).append(e)
        ordered: List[SharedCache] = []
        dropped: List[SharedCache] = []
        split = 0
        for src in sorted(set(by_src) | set(fin)):
            st = sorted(by_src.get(src, []), key=lambda e: (e[0], e[2]))
            fn = sorted(fin.get(src, []), key=lambda e: e[0])
            if any(n for (_, _, _, _, n) in st):
                # row-synchronized-fed tree: the shard passes carried the
                # real rows; the final pass (empty sources) delivered
                # nothing worth keeping
                chosen = st
                dropped.extend(c for _, c in fn)
            elif fn:
                # cut-ancestored tree: the final-pass deliveries ARE the
                # serial input; shard-pass stashes were schema-empties
                for _, cache in fn:
                    cache.split_index = split
                    split += 1
                    ordered.append(cache)
                continue
            else:
                chosen = st       # degenerate all-empty tree: schema reps
            for (_, _, _, cols, n) in chosen:
                # copies, not views: finish() mutates in place and a merge
                # replay must reread pristine stashes
                cache = SharedCache({k: np.array(v) for k, v in cols.items()},
                                    n, split_index=split)
                split += 1
                ordered.append(cache)
        for cache in dropped:
            cache.recycle()
        return root.finish(ordered)
