"""Process route: shard passes in spawned worker processes.

The parent pickles one payload per shard — the flow with ONLY that
shard's source partition installed (scatter, never a full-table
broadcast), plus the run options — and ships it to a persistent
spawn-context ``ProcessPoolExecutor``.  Each worker rebuilds its own
backend / execution-tree graph / runtime plan (exactly the engine's
setup sequence), runs the full per-shard flow with cuts in ``partial``
mode, and returns a pickled dict::

    {"agg": ..., "generic": ...,   # ShardContext stashes (merge.py)
     "sinks": {name: [(split_index, columns, n), ...]},
     "stats": {...},               # the worker's exact CacheStats snapshot
     "rows": int}                  # source rows this shard processed

or ``{"error": {"kind", "msg"}}`` — errors cross the process boundary as
``faults.classify`` kinds rather than pickled exceptions, and the parent
re-raises the matching fault class so transient worker failures escalate
to whole-shard replay just like the inline route.

Scope rules: contextvar-scoped fault plans and tracers cannot follow
work into another process, so ``ShardRunner`` degrades process→inline
whenever either is active.  Workers additionally drop ``REPRO_FAULTS``
from their environment — a child re-parsing the env plan would keep its
own injection counts and fire extra faults the parent's plan never
recorded; under the process route, faults inject at the parent's
``shard`` site only.

The pool is module-global and reused across runs (spawning workers —
and importing jax inside them — is far too slow to pay per run) and is
shut down at interpreter exit.
"""
from __future__ import annotations

import atexit
import os
import pickle
import time
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Dict, List, Optional

from .. import config, faults


class ProcessRouteUnavailable(RuntimeError):
    """The worker pool cannot run shard passes (e.g. it broke mid-run);
    the caller falls back to the inline route."""


_POOL: Optional[ProcessPoolExecutor] = None
_POOL_WIDTH = 0


def _get_pool(width: int) -> ProcessPoolExecutor:
    global _POOL, _POOL_WIDTH
    if _POOL is None or _POOL_WIDTH < width:
        if _POOL is not None:
            _POOL.shutdown(wait=True)
        import multiprocessing as mp
        _POOL = ProcessPoolExecutor(max_workers=width,
                                    mp_context=mp.get_context("spawn"))
        _POOL_WIDTH = width
    return _POOL


def _drop_pool() -> None:
    global _POOL, _POOL_WIDTH
    if _POOL is not None:
        _POOL.shutdown(wait=False, cancel_futures=True)
        _POOL = None
        _POOL_WIDTH = 0


atexit.register(_drop_pool)


# --------------------------------------------------------------- parent side
def build_payloads(flow, options, plan, parts) -> Optional[List[bytes]]:
    """One pickled ``(flow-with-slice, options, cuts, k)`` per shard, or
    ``None`` when the flow cannot be pickled (lambda-configured
    components etc.) — the caller degrades to the inline route."""
    sources = [(name, flow.component(name)) for name in plan.sources]
    orig = {name: comp.columns for name, comp in sources}
    try:
        payloads = []
        for k in range(plan.shards):
            for name, comp in sources:
                comp.set_data(parts[k][name])
            payloads.append(pickle.dumps(
                (flow, options, list(plan.cuts), k),
                protocol=pickle.HIGHEST_PROTOCOL))
        return payloads
    except Exception:
        return None
    finally:
        for name, comp in sources:
            comp.set_data(orig[name])


def _rebuild_error(err: Dict[str, str]) -> BaseException:
    # permanent application errors surface as the ORIGINAL exception type —
    # a KeyError in a worker must reach the caller as a KeyError, exactly
    # like the serial engine; transients stay wrapped so the parent retry
    # loop classifies them deterministically even when they don't pickle
    if err.get("kind") != "transient" and err.get("exc") is not None:
        try:
            return pickle.loads(err["exc"])
        except Exception:
            pass
    cls = {"transient": faults.TransientFault,
           "poison": faults.PoisonFault}.get(err.get("kind"),
                                             faults.PermanentFault)
    return cls(err.get("msg", "shard worker failed"))


def run_passes(flow, payloads: List[bytes], ctx, res) -> List[dict]:
    """Run every shard payload on the worker pool; per-shard transient
    failures (injected at the parent's ``shard`` fault site, or classified
    out of the worker) replay that one shard.  Stashes are only absorbed
    from successful results, so a failed attempt needs no rollback."""
    width = min(len(payloads), max(1, (os.cpu_count() or 2) - 1))
    try:
        pool = _get_pool(width)
        futures = {k: pool.submit(_shard_worker, p)
                   for k, p in enumerate(payloads)}
    except BrokenProcessPool as e:
        _drop_pool()
        raise ProcessRouteUnavailable(str(e)) from e
    out: List[dict] = [None] * len(payloads)
    for k in sorted(futures):
        fut = futures[k]
        attempt, delay = 0, config.retry_backoff()
        while True:
            try:
                faults.inject("shard", component=flow.name, split=k)
                result = pickle.loads(fut.result())
                err = result.get("error")
                if err is not None:
                    raise _rebuild_error(err)
                out[k] = result
                break
            except BrokenProcessPool as e:
                _drop_pool()
                raise ProcessRouteUnavailable(str(e)) from e
            except BaseException as e:
                if (faults.classify(e) != "transient"
                        or attempt >= config.retry_max()):
                    raise
                faults.record_retry(f"shard.{flow.name}.{k}", attempt, delay)
                res.replays += 1
                if delay > 0.0:
                    time.sleep(delay)
                delay = min(delay * 2.0 if delay else 0.0,
                            faults.RETRY_BACKOFF_CAP_S)
                attempt += 1
                try:
                    fut = pool.submit(_shard_worker, payloads[k])
                except BrokenProcessPool as e2:
                    _drop_pool()
                    raise ProcessRouteUnavailable(str(e2)) from e2
    return out


# --------------------------------------------------------------- worker side
def _shard_worker(payload: bytes) -> bytes:
    """Run one shard pass in a worker process (module-level: spawn needs
    an importable reference).  Mirrors ``OptimizedEngine.run``'s setup:
    resolve backend → assign → partition → plan_runtime → execute."""
    os.environ.pop("REPRO_FAULTS", None)     # see module docstring
    try:
        flow, options, cuts, k = pickle.loads(payload)
        from ..backend import resolve_backend
        from ..engine import _assign_backend
        from ..executor import StreamingExecutor
        from ..partitioner import partition
        from ..planner import plan_runtime
        from ..shared_cache import cache_stats_scope
        from .merge import ShardContext

        bk = resolve_backend(options.backend)
        _assign_backend(flow, bk)
        g_tau = partition(flow)
        m_prime = options.pipeline_degree or options.num_splits
        runtime_plan = plan_runtime(
            flow, g_tau,
            num_splits=options.num_splits, m_prime=m_prime,
            mt_threads=options.mt_threads, cores=options.cores,
            pool_width=options.pool_width,
            channel_capacity=options.channel_capacity,
            streaming=options.streaming and options.concurrent_trees,
            backend=bk)
        shard_ctx = ShardContext()
        shard_ctx.begin_pass(k)
        for name in cuts:
            comp = flow.component(name)
            comp.shard_role = "partial"
            comp._shard_ctx = shard_ctx
        with cache_stats_scope() as stats:
            executor = StreamingExecutor(flow, g_tau, options, runtime_plan)
            try:
                executor.execute()
            finally:
                executor.shutdown()
            sinks: Dict[str, list] = {}
            for sname in flow.sinks():
                sink = flow.component(sname)
                sinks[sname] = [(c.split_index, c.to_dict(), c.n)
                                for c in sink.drain()]
            agg, generic = shard_ctx.export()
            snap = stats.snapshot()
        rows = sum(flow.component(s).total_rows() for s in flow.sources())
        # component dispatch counts live on the worker's flow copy; ship
        # them so the parent run's dispatch_calls covers shard-pass work
        dispatch = sum(c.calls for c in flow.vertices.values())
        return pickle.dumps(
            {"agg": agg, "generic": generic, "sinks": sinks,
             "stats": snap, "rows": rows, "dispatch": dispatch},
            protocol=pickle.HIGHEST_PROTOCOL)
    except BaseException as e:
        try:    # ship the exception itself when it pickles (see _rebuild_error)
            exc = pickle.dumps(e, protocol=pickle.HIGHEST_PROTOCOL)
        except Exception:
            exc = None
        return pickle.dumps(
            {"error": {"kind": faults.classify(e),
                       "msg": f"{type(e).__name__}: {e}", "exc": exc}},
            protocol=pickle.HIGHEST_PROTOCOL)
