"""Row partitioning for sharded execution.

Two schemes, chosen by the planner:

``range``  contiguous even slices (the same ``linspace`` arithmetic
           ``SharedCache.split`` uses) — always correct, because shard
           passes replay in shard order and the merge pass reassembles
           per-tree deliveries in (shard, split) order, restoring the
           serial row order exactly.
``hash``   rows scattered by a splitmix64 hash of the group-key columns
           (DOD-ETL's scheme) — group-disjoint shards, so keyed partials
           never meet across shards; the planner only picks it when every
           source→sink path runs through a first-layer Aggregate keyed on
           source columns (downstream of which row order is canonical).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

Table = Dict[str, np.ndarray]


def range_bounds(n_rows: int, shards: int) -> np.ndarray:
    """Shard boundary offsets ``[b0..bN]`` — even contiguous slices, same
    arithmetic as ``SharedCache.split`` so shard sizes match split sizes."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    return np.linspace(0, n_rows, shards + 1).astype(int)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer (wrapping uint64 arithmetic)."""
    with np.errstate(over="ignore"):
        x = x + np.uint64(0x9E3779B97F4A7C15)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))


def hash_shard_ids(key_cols: Sequence[np.ndarray], shards: int) -> np.ndarray:
    """Shard id per row from the hash of the key column tuple.  Chained
    per-column splitmix64 mixing, so (a, b) and (b, a) land differently."""
    if not key_cols:
        raise ValueError("hash partitioning needs at least one key column")
    n = len(key_cols[0])
    h = np.zeros(n, dtype=np.uint64)
    for c in key_cols:
        h = _splitmix64(h ^ np.asarray(c).astype(np.uint64, copy=False))
    return (h % np.uint64(shards)).astype(np.int64)


def shard_tables(tables: Dict[str, Table], shards: int, mode: str,
                 key: Tuple[str, ...] = ()) -> List[Dict[str, Table]]:
    """Partition every source table into per-shard tables.

    Returns one ``{source_name: {col: rows}}`` dict per shard.  Range mode
    slices each source independently into contiguous views; hash mode
    scatters by ``key`` with ``np.flatnonzero`` index takes, which preserve
    each shard's rows in original relative order (exactness of per-group
    accumulation does not depend on cross-shard order)."""
    out: List[Dict[str, Table]] = [dict() for _ in range(shards)]
    for name, table in tables.items():
        n = len(next(iter(table.values()))) if table else 0
        if mode == "range":
            bounds = range_bounds(n, shards)
            for k in range(shards):
                lo, hi = int(bounds[k]), int(bounds[k + 1])
                out[k][name] = {c: v[lo:hi] for c, v in table.items()}
        elif mode == "hash":
            ids = hash_shard_ids([table[c] for c in key], shards)
            for k in range(shards):
                idx = np.flatnonzero(ids == k)
                out[k][name] = {c: np.asarray(v)[idx]
                                for c, v in table.items()}
        else:
            raise ValueError(f"unknown shard mode {mode!r}")
    return out


def table_rows(table: Table) -> int:
    return len(next(iter(table.values()))) if table else 0


def table_bytes(table: Table) -> int:
    return sum(np.asarray(v).nbytes for v in table.values())
