"""Streaming execution runtime — one shared worker pool + bounded inter-tree
split channels.

The paper pipelines splits *within* an execution tree (Algorithm 2) but runs
*across* trees with a barrier: a downstream tree starts only after ALL
upstream trees finish, and every delivered cache is list-accumulated first.
This module generalizes the paper's bounded-queue pipelining to the whole
execution-tree graph (DOD-ETL-style on-demand streaming between stages):

- ``SharedWorkerPool`` — ONE size-bounded pool for every kind of work: tree
  coordination tasks, pipeline split consumers (Algorithm 2 line 21) and
  §4.3 inside-component row ranges.  ``width`` bounds the number of
  *runnable* workers; a task that must block (channel put/get, admission
  gate, future join, activity busy-wait) does so inside a *managed blocking*
  region which releases its slot so a compensation worker can keep the queue
  draining — the ForkJoinPool/ManagedBlocker discipline, which makes the
  bounded pool deadlock-free even at ``width=1``.

- ``ChannelGroup`` — per-inter-tree-edge bounded buffers (the Algorithm-2
  BlockingQueue(m') lifted to tree->tree edges).  Producers block when an
  edge's buffer is full (backpressure); the destination tree's coordinator
  selects across its input edges as splits arrive.

- ``RunAbort`` — run-wide cooperative cancellation: the first failing task
  trips it, every blocking site wakes and re-raises, and the engine surfaces
  the ORIGINAL exception instead of joining all threads first.

- ``StreamingExecutor`` — drives an ``ExecutionTreeGraph``:
  * source-rooted trees stream their chunk splits through the tree pipeline;
  * a tree whose root is row-synchronized (an explicit ``StageBoundary``)
    consumes upstream splits AS THEY ARRIVE and pipes them straight through
    its own pipeline — cross-tree overlap, the new capability;
  * block / semi-block roots keep the paper's accumulate-then-finish
    semantics (they need the complete input), with deliveries drained
    concurrently and ordered deterministically by (src_tree, split_index).
"""
from __future__ import annotations

import contextvars
import threading
import time
import warnings
from collections import deque
from contextlib import contextmanager, nullcontext
from typing import (TYPE_CHECKING, Callable, Dict, Iterator, List, Optional,
                    Tuple)

from ..obs import trace as obs_trace
from . import faults
from .component import SourceComponent
from .graph import Dataflow
from .partitioner import ExecutionTreeGraph, streamable_tree_ids
from .shared_cache import SharedCache, record_copy

if TYPE_CHECKING:  # pragma: no cover
    from .planner import RuntimePlan


class ExecutionAborted(RuntimeError):
    """Secondary error raised at blocking sites after the run was aborted.
    The engine re-raises the ORIGINAL exception recorded by ``RunAbort``."""


# ---------------------------------------------------------------------------
#  Run-wide cancellation
# ---------------------------------------------------------------------------
class RunAbort:
    """First-error latch + waker for every blocking site of a run."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._evt = threading.Event()
        self.exc: Optional[BaseException] = None
        self._subscribers: List[Callable[[], None]] = []

    @property
    def aborted(self) -> bool:
        return self._evt.is_set()

    def subscribe(self, wake: Callable[[], None]) -> None:
        """Register a waker called once when the run aborts (used to
        notify_all() on conditions that might be waiting forever)."""
        with self._lock:
            self._subscribers.append(wake)
            tripped = self._evt.is_set()
        if tripped:
            wake()

    def trip(self, exc: BaseException) -> None:
        """Record the first real error and wake every blocked thread."""
        with self._lock:
            if self.exc is None and not isinstance(exc, ExecutionAborted):
                self.exc = exc
            already = self._evt.is_set()
            self._evt.set()
            subs = list(self._subscribers)
        if not already or self.exc is exc:
            for wake in subs:
                wake()

    def check(self) -> None:
        if self._evt.is_set():
            raise ExecutionAborted("execution aborted") from self.exc


# ---------------------------------------------------------------------------
#  Futures + the shared worker pool
# ---------------------------------------------------------------------------
class TaskFuture:
    """Minimal future for SharedWorkerPool tasks (join is pool-aware)."""

    __slots__ = ("_pool", "_evt", "_value", "_exc")

    def __init__(self, pool: "SharedWorkerPool"):
        self._pool = pool
        self._evt = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None

    def _finish(self, value=None, exc: Optional[BaseException] = None) -> None:
        self._value = value
        self._exc = exc
        self._evt.set()

    def done(self) -> bool:
        return self._evt.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block (pool-managed) until done; never raises the task error."""
        if not self._evt.is_set():
            with self._pool.blocking():
                self._evt.wait(timeout)
        return self._evt.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self.wait(timeout):
            raise TimeoutError("task did not complete in time")
        if self._exc is not None:
            raise self._exc
        return self._value


class SharedWorkerPool:
    """Size-bounded worker pool with managed blocking.

    ``width`` bounds RUNNABLE workers (the CPU concurrency).  Any pool task
    about to block must wrap the wait in ``with pool.blocking():`` — the pool
    then excludes it from the runnable count and, if work is queued, spawns a
    compensation worker so progress never depends on a blocked slot.  Thread
    count is therefore bounded by ``width + concurrently-blocked tasks``
    rather than by thread-per-tree/thread-per-split as before.
    """

    #: default seconds ``shutdown`` waits for each worker to join before
    #: declaring it leaked
    DEFAULT_JOIN_TIMEOUT_S = 10.0

    def __init__(self, width: int, name: str = "repro-pool",
                 join_timeout: Optional[float] = None):
        self.width = max(1, int(width))
        self.name = name
        self.join_timeout = (self.DEFAULT_JOIN_TIMEOUT_S
                             if join_timeout is None else float(join_timeout))
        self.leaked_threads = 0         # workers that outlived shutdown joins
        self._cond = threading.Condition()
        self._work: deque = deque()
        self._threads: set = set()
        self._idle = 0
        self._blocked = 0
        self._shutdown = False
        self._tls = threading.local()
        self._seq = 0
        self.spawned_total = 0          # instrumentation
        self.tasks_run = 0
        self.threads_hwm = 0            # peak live worker threads
        self.runnable_hwm = 0           # peak concurrently-runnable workers

    # ------------------------------------------------------------- internals
    def _runnable(self) -> int:
        return len(self._threads) - self._blocked

    def _spawn_locked(self) -> None:
        self._seq += 1
        self.spawned_total += 1
        t = threading.Thread(target=self._worker, daemon=True,
                             name=f"{self.name}-{self._seq}")
        self._threads.add(t)
        self.threads_hwm = max(self.threads_hwm, len(self._threads))
        t.start()

    def _worker(self) -> None:
        self._tls.is_worker = True
        me = threading.current_thread()
        try:
            while True:
                with self._cond:
                    while not self._work:
                        if self._shutdown:
                            return
                        if self._runnable() > self.width:
                            return      # surplus compensation worker retires
                        self._idle += 1
                        self._cond.wait(0.2)
                        self._idle -= 1
                    fn, args, ctx, fut = self._work.popleft()
                    self.tasks_run += 1
                    self.runnable_hwm = max(self.runnable_hwm,
                                            self._runnable())
                try:
                    # run under the submitter's contextvars context so scoped
                    # instrumentation (cache_stats_scope) follows the task —
                    # nested submits re-capture transitively
                    fut._finish(value=ctx.run(fn, *args))
                except BaseException as e:  # noqa: BLE001 — goes to the future
                    fut._finish(exc=e)
        finally:
            with self._cond:
                self._threads.discard(me)
                self._cond.notify_all()

    # ------------------------------------------------------------------- API
    def submit(self, fn: Callable, *args) -> TaskFuture:
        fut = TaskFuture(self)
        ctx = contextvars.copy_context()
        with self._cond:
            if self._shutdown:
                raise RuntimeError("pool is shut down")
            self._work.append((fn, args, ctx, fut))
            if self._idle > 0:
                self._cond.notify()
            elif self._runnable() < self.width:
                self._spawn_locked()
        return fut

    def is_worker_thread(self) -> bool:
        return bool(getattr(self._tls, "is_worker", False))

    @contextmanager
    def blocking(self):
        """Managed blocking region (no-op off pool threads): the caller stops
        counting against ``width`` and a spare worker keeps the queue moving."""
        if not self.is_worker_thread():
            yield
            return
        with self._cond:
            self._blocked += 1
            if self._work and self._idle == 0 and self._runnable() < self.width:
                self._spawn_locked()
        try:
            yield
        finally:
            with self._cond:
                self._blocked -= 1

    def stats(self) -> Dict[str, int]:
        with self._cond:
            return {"width": self.width, "threads": len(self._threads),
                    "blocked": self._blocked, "spawned_total": self.spawned_total,
                    "tasks_run": self.tasks_run,
                    "threads_hwm": self.threads_hwm,
                    "runnable_hwm": self.runnable_hwm,
                    "leaked_threads": self.leaked_threads}

    def shutdown(self, wait: bool = True,
                 join_timeout: Optional[float] = None) -> None:
        """Stop the pool.  With ``wait=True`` joins each worker for up to
        ``join_timeout`` seconds (default: the pool's configured timeout);
        stragglers that fail to join are counted in ``leaked_threads``,
        reported as a ``pool_leaked_threads`` gauge on active tracers, and
        warned about — never again discarded silently."""
        with self._cond:
            self._shutdown = True
            self._cond.notify_all()
            threads = list(self._threads)
        if not wait:
            return
        timeout = (self.join_timeout if join_timeout is None
                   else float(join_timeout))
        leaked = []
        for t in threads:
            t.join(timeout=timeout)
            if t.is_alive():
                leaked.append(t.name)
        if leaked:
            self.leaked_threads += len(leaked)
            for tr in obs_trace.ACTIVE.get():
                tr.metrics.gauge_set("pool_leaked_threads",
                                     self.leaked_threads)
            warnings.warn(
                f"SharedWorkerPool {self.name!r}: {len(leaked)} worker "
                f"thread(s) did not join within {timeout:.1f}s: "
                f"{', '.join(leaked)}", RuntimeWarning, stacklevel=2)


# ---------------------------------------------------------------------------
#  Admission gate — Algorithm 2's BlockingQueue(m') on the shared pool
# ---------------------------------------------------------------------------
class AdmissionGate:
    """Bounds in-flight splits of one tree pipeline to m' (memory bound)."""

    def __init__(self, limit: int, abort: Optional[RunAbort] = None):
        self.limit = max(1, int(limit))
        self._cond = threading.Condition()
        self._inflight = 0
        self._abort = abort
        if abort is not None:
            abort.subscribe(self._wake)

    def _wake(self) -> None:
        with self._cond:
            self._cond.notify_all()

    def acquire(self, pool: Optional[SharedWorkerPool] = None) -> None:
        with self._cond:                       # fast path: slot available
            if self._abort is not None:
                self._abort.check()
            if self._inflight < self.limit:
                self._inflight += 1
                return
        ctx = pool.blocking() if pool is not None else nullcontext()
        t0 = time.perf_counter() if obs_trace.ACTIVE.get() else 0.0
        with ctx:                              # slow path: managed wait
            with self._cond:
                while self._inflight >= self.limit:
                    if self._abort is not None and self._abort.aborted:
                        self._abort.check()
                    self._cond.wait(0.2)
                if self._abort is not None:
                    self._abort.check()
                self._inflight += 1
        if t0:
            obs_trace.on_wait("gate.acquire", t0, time.perf_counter(),
                              limit=self.limit)

    def release(self) -> None:
        with self._cond:
            self._inflight -= 1
            self._cond.notify_all()


# ---------------------------------------------------------------------------
#  Bounded inter-tree channels
# ---------------------------------------------------------------------------
CLOSED = object()      # sentinel returned by ChannelGroup.get at end of stream

# a delivered split: (src_tree_id, split_index, dst_component, cache)
Delivery = Tuple[int, int, str, SharedCache]


class _EdgeBuffer:
    __slots__ = ("capacity", "items", "open")

    def __init__(self, capacity: Optional[int]):
        self.capacity = capacity          # None => unbounded (legacy mode)
        self.items: deque = deque()
        self.open = True


class ChannelGroup:
    """All inter-tree input buffers of ONE destination tree.

    Each incoming edge gets its own size-bounded buffer (per-edge queue depth
    from the planner); the buffers share a single condition so the consumer
    can select across edges as splits arrive.  Producers block on a full edge
    buffer — that is the cross-tree backpressure.
    """

    def __init__(self, pool: Optional[SharedWorkerPool] = None,
                 abort: Optional[RunAbort] = None, name: str = "chan"):
        self.name = name
        self._cond = threading.Condition()
        self._pool = pool
        self._abort = abort
        self._buffers: Dict[Tuple[int, int], _EdgeBuffer] = {}
        self._rr = 0
        self._closed_evt = threading.Event()   # set once EVERY edge is closed
        self.max_depth = 0               # instrumentation: peak buffered splits
        if abort is not None:
            abort.subscribe(self._wake)

    def _wake(self) -> None:
        with self._cond:
            self._cond.notify_all()
        self._closed_evt.set()           # release drain_on_close waiters too

    def add_edge(self, key: Tuple[int, int],
                 capacity: Optional[int] = None) -> None:
        with self._cond:
            self._buffers[key] = _EdgeBuffer(capacity)

    @property
    def edges(self) -> List[Tuple[int, int]]:
        return list(self._buffers.keys())

    def _check_abort(self) -> None:
        if self._abort is not None and self._abort.aborted:
            self._abort.check()

    # -------------------------------------------------------------- producer
    def put(self, key: Tuple[int, int], item: Delivery) -> None:
        # edge-site injection: delay rules sleep here (simulated slow edge);
        # raise rules fail the producing task, which escalates through
        # RunAbort to a run-level retry
        faults.inject("edge", component=item[2], split=item[1])
        buf = self._buffers[key]
        with self._cond:                       # fast path: space available
            self._check_abort()
            if buf.capacity is None or len(buf.items) < buf.capacity:
                buf.items.append(item)
                depth = sum(len(b.items) for b in self._buffers.values())
                self.max_depth = max(self.max_depth, depth)
                self._cond.notify_all()
                if obs_trace.ACTIVE.get():
                    obs_trace.counter("channel", self.name, depth=depth)
                return
        ctx = (self._pool.blocking() if self._pool is not None
               else nullcontext())
        t0 = time.perf_counter() if obs_trace.ACTIVE.get() else 0.0
        with ctx:                              # slow path: backpressure
            with self._cond:
                while len(buf.items) >= buf.capacity:
                    self._check_abort()
                    self._cond.wait(0.2)
                self._check_abort()
                buf.items.append(item)
                depth = sum(len(b.items) for b in self._buffers.values())
                self._cond.notify_all()
        if t0:
            obs_trace.on_wait("channel.put", t0, time.perf_counter(),
                              channel=self.name)
            obs_trace.counter("channel", self.name, depth=depth)

    def close(self, key: Tuple[int, int]) -> None:
        with self._cond:
            self._buffers[key].open = False
            self._cond.notify_all()
            if all(not b.open for b in self._buffers.values()):
                self._closed_evt.set()

    def _try_get_locked(self, keys):
        """One round-robin selection attempt; None when nothing buffered."""
        for i in range(len(keys)):
            buf = self._buffers[keys[(self._rr + i) % len(keys)]]
            if buf.items:
                self._rr = (self._rr + i + 1) % len(keys)
                item = buf.items.popleft()
                self._cond.notify_all()
                return item
        return None

    # -------------------------------------------------------------- consumer
    def get(self):
        """Next delivery from any edge (round-robin), blocking until one
        arrives; CLOSED once every edge is closed and drained."""
        with self._cond:                       # fast path: split buffered
            self._check_abort()
            keys = list(self._buffers.keys())
            item = self._try_get_locked(keys)
            if item is not None:
                return item
            if all(not b.open for b in self._buffers.values()):
                return CLOSED
        ctx = (self._pool.blocking() if self._pool is not None
               else nullcontext())
        t0 = time.perf_counter() if obs_trace.ACTIVE.get() else 0.0
        try:
            with ctx:                          # slow path: managed wait
                with self._cond:
                    while True:
                        self._check_abort()
                        item = self._try_get_locked(keys)
                        if item is not None:
                            return item
                        if all(not b.open for b in self._buffers.values()):
                            return CLOSED
                        self._cond.wait(0.2)
        finally:
            if t0:
                obs_trace.on_wait("channel.get", t0, time.perf_counter(),
                                  channel=self.name)

    def __iter__(self) -> Iterator[Delivery]:
        while True:
            item = self.get()
            if item is CLOSED:
                return
            yield item

    def drain_on_close(self) -> List[Delivery]:
        """Wait until every edge is closed, then take everything at once.
        For accumulate-semantics consumers (block / semi-block roots) this is
        cheaper than per-split wakeups — the full input must materialize
        before they can run anyway, so per-edge buffers feeding them are left
        unbounded and producers never stall on delivery."""
        if not self._closed_evt.is_set():
            ctx = (self._pool.blocking() if self._pool is not None
                   else nullcontext())
            t0 = time.perf_counter() if obs_trace.ACTIVE.get() else 0.0
            with ctx:
                self._closed_evt.wait()
            if t0:
                obs_trace.on_wait("channel.drain", t0, time.perf_counter(),
                                  channel=self.name)
        with self._cond:
            self._check_abort()
            items: List[Delivery] = []
            for buf in self._buffers.values():
                items.extend(buf.items)
                buf.items.clear()
            return items


# ---------------------------------------------------------------------------
#  The streaming executor
# ---------------------------------------------------------------------------
class StreamingExecutor:
    """Runs an execution-tree graph on one shared pool with streaming
    inter-tree channels.  Modes (from OptimizeOptions):

    - ``streaming=True`` + ``concurrent_trees=True``: all tree coordinators
      start immediately; dependencies are carried by channel closure, and
      row-synchronized (stage-boundary) roots overlap with their upstream.
    - ``streaming=False`` + ``concurrent_trees=True``: the paper's planner —
      coordinators gate on upstream completion, channels are unbounded and
      fully drained before the tree starts (legacy accumulate semantics).
    - ``concurrent_trees=False``: strict topological one-tree-at-a-time.
    """

    def __init__(self, flow: Dataflow, g_tau: ExecutionTreeGraph,
                 options, plan: "RuntimePlan",
                 pool: Optional[SharedWorkerPool] = None):
        from .pipeline import TreePipeline        # local import (cycle)
        self._TreePipeline = TreePipeline
        self.flow = flow
        self.g_tau = g_tau
        self.options = options
        self.plan = plan
        self.abort = RunAbort()
        self.pool = pool or SharedWorkerPool(plan.pool_width)
        self._owns_pool = pool is None
        self.streamed_edges: List[Tuple[int, int]] = []

        # wake every component condition on abort so busy/order waiters exit
        self.abort.subscribe(self._wake_components)

        streaming_on = bool(options.streaming) and bool(options.concurrent_trees)
        self._streamed_trees = (streamable_tree_ids(flow, g_tau)
                                if streaming_on else set())
        self._groups: Dict[int, ChannelGroup] = {}
        for (a, b) in g_tau.edges:
            grp = self._groups.get(b)
            if grp is None:
                grp = self._groups[b] = ChannelGroup(
                    self.pool, self.abort, name=f"tree{b}-in")
            # bounded depth (backpressure) only where splits are consumed as
            # they arrive; accumulate-semantics consumers need the full input
            # regardless, so their edges stay unbounded and are drained once
            depth = (plan.channel_depth.get((a, b))
                     if b in self._streamed_trees else None)
            grp.add_edge((a, b), capacity=depth)

    # ------------------------------------------------------------------ util
    def channel_hwm(self) -> int:
        """Peak buffered splits across all inter-tree channel groups."""
        return max((g.max_depth for g in self._groups.values()), default=0)

    def _wake_components(self) -> None:
        for comp in self.flow.vertices.values():
            with comp.cond:
                comp.cond.notify_all()

    # -------------------------------------------------------------- delivery
    def _deliver(self, dst: str, cache: SharedCache, split_index: int,
                 src_tree: int) -> None:
        dtid = self.g_tau.tree_of[dst]
        self._groups[dtid].put((src_tree, dtid),
                               (src_tree, split_index, dst, cache))

    # -------------------------------------------------------------- per tree
    def _source_splits(self, root: SourceComponent) -> Iterator[SharedCache]:
        opts = self.options
        total = root.total_rows()
        # explicit option wins; else the runtime plan's backend-aligned batch
        # size (unless this source's data is chunk-sensitive); else an even
        # split of the source
        planned = None if root.chunk_sensitive else self.plan.chunk_rows
        chunk = (opts.chunk_rows or planned
                 or max(1, -(-total // max(opts.num_splits, 1))))
        for i, c in enumerate(root.chunks(chunk)):
            c.split_index = i
            try:
                faults.inject("chunk", component=root.name, split=i)
            except BaseException:
                c.recycle()          # the drawn chunk must not strand buffers
                raise
            yield c

    @staticmethod
    def _copy_split(s: SharedCache) -> SharedCache:
        c = s.copy()
        record_copy(s)
        c.split_index = s.split_index
        s.recycle()          # the engine keeps only the private copy
        return c

    def _run_pipeline(self, tp, splits, process_root: bool) -> None:
        opts = self.options
        if not opts.shared_cache:
            splits = (self._copy_split(s) for s in splits)
        if opts.pipelined:
            m_prime = opts.pipeline_degree or opts.num_splits
            tp.run(splits, m_prime=m_prime, process_root=process_root)
        else:
            tp.run_sequential(splits, process_root=process_root)

    def run_tree(self, tree) -> None:
        opts = self.options
        flow = self.flow
        root = flow.component(tree.root)
        tp = self._TreePipeline(
            flow, tree, self.g_tau.tree_of, self._deliver,
            mt_config=opts.mt_threads, pool=self.pool,
            shared=opts.shared_cache, abort=self.abort)
        group = self._groups.get(tree.tree_id)

        if isinstance(root, SourceComponent):
            self._run_pipeline(tp, self._source_splits(root),
                               process_root=False)
            if group is not None:
                # cross-tree deliveries into a member of a source tree
                # (e.g. a shared sink fed by several trees)
                for (src, idx, dst, cache) in sorted(
                        group.drain_on_close(), key=lambda e: (e[0], e[1])):
                    cache.split_index = idx
                    tp.consume_at(dst, cache)
                    cache.recycle()
        elif root.ctype.roots_tree:
            # block / semi-block root: accumulate-then-finish (paper §3) —
            # deliveries taken once all upstream edges close, ordered
            # deterministically by (src_tree, split_index).
            entries = group.drain_on_close() if group is not None else []
            entries.sort(key=lambda e: (e[0], e[1]))
            state = root.new_state()
            extras: List[Delivery] = []
            out: Optional[SharedCache] = None
            # sharded runs intercept finish() on cut roots: tags records
            # each accumulated cache's (src_tree, split_index) provenance so
            # the merge pass can reassemble the serial accumulation order
            tags: List[Tuple[int, int]] = []
            try:
                for (src, idx, dst, cache) in entries:
                    if dst == tree.root:
                        tags.append((src, idx))
                        root.accumulate(state, cache)
                    else:
                        extras.append((src, idx, dst, cache))
                if root.shard_role is not None:
                    out = root._shard_ctx.intercept_finish(root, state, tags)
                else:
                    out = root.finish(state)
                state = None           # finish consumed (and recycled) it
                for (src, idx, dst, cache) in extras:
                    cache.split_index = idx
                    tp.consume_at(dst, cache)
                    cache.recycle()
                extras = []
                self._run_pipeline(tp, iter(out.split(opts.num_splits)),
                                   process_root=False)
            finally:
                # an abort between accumulate and the last consumed split
                # must not strand arena buffers: recycle whatever was not
                # handed downstream (recycle() is idempotent, so the success
                # path — where finish/consume already recycled — is a no-op)
                if state:
                    for cache in state:
                        cache.recycle()
                for (_, _, _, cache) in extras:
                    cache.recycle()
                if out is not None:
                    out.recycle()    # its splits (views) were consumed
        else:
            # row-synchronized root — an explicit stage boundary
            if tree.tree_id in self._streamed_trees and group is not None:
                self.streamed_edges.extend(group.edges)

                def arriving():
                    for (_, idx, _, cache) in group:
                        cache.split_index = idx
                        yield cache
                self._run_pipeline(tp, arriving(), process_root=True)
            else:
                entries = (group.drain_on_close()
                           if group is not None else [])
                entries.sort(key=lambda e: (e[0], e[1]))
                multi_src = len({e[0] for e in entries}) > 1

                def drained():
                    for k, (_, idx, dst, cache) in enumerate(entries):
                        cache.split_index = k if multi_src else idx
                        yield cache
                self._run_pipeline(tp, drained(), process_root=True)

    def _run_tree_guarded(self, tree) -> None:
        try:
            self.run_tree(tree)
        finally:
            # close this tree's outgoing edge buffers (even on error, so
            # downstream consumers wake and observe the abort)
            for (a, b) in self.g_tau.edges:
                if a == tree.tree_id:
                    self._groups[b].close((a, b))

    # ------------------------------------------------------------------- run
    def execute(self) -> None:
        from .scheduler import run_tree_graph     # local import (cycle)
        opts = self.options
        gate_upstream = not (opts.streaming and opts.concurrent_trees)
        try:
            run_tree_graph(self.g_tau, self._run_tree_guarded,
                           concurrent=opts.concurrent_trees,
                           pool=self.pool, abort=self.abort,
                           gate_on_upstream=gate_upstream)
        except BaseException as e:
            raise (self.abort.exc if self.abort.exc is not None else e) from None

    def shutdown(self) -> None:
        if self._owns_pool:
            self.pool.shutdown()
