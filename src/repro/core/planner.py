"""Algorithm 3 + Theorem 1 — estimate the optimal degree of pipeline
parallelization.

Cost model (paper §4.2): with m splits, staggering activity A_j of
per-split time t_j = t0 + lambda*N/m, and per-activity miscellaneous time
t0, the pipeline time is

    T_p(m) = c/m + (m-1)*t_j + n*t0
           = (c - lambda*N)/m + t0*m + lambda*N + (n-1)*t0

minimized at  m* = sqrt((c - lambda*N) / t0)          (Theorem 1)

where c = m * sum_i (t_i - t0) is the total *net* processing time of the
full input (independent of m) and N is the number of rows through A_j.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclass
class PipelinePlan:
    n: int                    # number of activities in the execution tree
    t0: float                 # avg per-activity miscellaneous time  (line 1,3)
    c: float                  # total net processing time, full input (line 3)
    lam: float                # lambda: seconds per row at the staggering activity
    N: int                    # rows processed by the staggering activity
    staggering: str           # name of A_j                          (line 3)
    activity_times: Dict[str, float] = field(default_factory=dict)
    T_s: float = 0.0          # measured sequential time on the sample
    m_star: float = 1.0       # Theorem 1 optimum                    (line 5)

    def predict_T_p(self, m: float) -> float:
        m = max(1.0, float(m))
        return ((self.c - self.lam * self.N) / m + self.t0 * m
                + self.lam * self.N + (self.n - 1) * self.t0)

    def predict_T_s(self) -> float:
        return self.c + self.n * self.t0

    def predict_speedup(self, m: float) -> float:
        tp = self.predict_T_p(m)
        return self.predict_T_s() / tp if tp > 0 else float("inf")


def theorem1_m_star(c: float, lam: float, N: float, t0: float,
                    m_max: Optional[int] = None) -> float:
    """m* = sqrt((c - lambda*N)/t0), clamped to [1, m_max] (paper: 1<=m<=|Sigma|)."""
    if t0 <= 0:
        return float(m_max or 1)
    inner = max(c - lam * N, 0.0) / t0
    m = math.sqrt(inner)
    m = max(1.0, m)
    if m_max is not None:
        m = min(m, float(m_max))
    return m


def build_plan(activity_times: Dict[str, float],
               misc_total: float,
               sample_rows: int,
               full_rows: int,
               m_prime: int,
               staggering_rows_sample: Optional[int] = None) -> PipelinePlan:
    """Algorithm 3 from measured quantities.

    ``activity_times``: per-activity busy time from the *sequential* sample
        run over m' splits                                        (line 2)
    ``misc_total``: T_0 — busy time of a zero-row run              (line 1)
    ``sample_rows`` / ``full_rows``: |D| and |Sigma|-scale factor
    ``staggering_rows_sample``: rows through A_j in the sample (defaults to
        sample_rows; differs when upstream filters drop rows).
    """
    names = list(activity_times.keys())
    times = np.array([activity_times[k] for k in names], dtype=np.float64)
    n = len(names)
    T_s = float(times.sum())
    t0 = misc_total / max(n, 1)                                   # line 3
    j = int(times.argmax())                                       # line 3
    staggering = names[j]
    scale = full_rows / max(sample_rows, 1)
    c_sample = max(T_s - misc_total, 1e-12)
    c = c_sample * scale                                          # line 3
    N_s = staggering_rows_sample or sample_rows
    N = int(round(N_s * scale))
    # line 4: lambda from the staggering activity's per-split time
    t_j_split = times[j] / max(m_prime, 1)
    lam = max(t_j_split - t0, 1e-12) * m_prime / max(N_s, 1)
    m_star = theorem1_m_star(c, lam, N, t0, m_max=full_rows)      # line 5
    return PipelinePlan(n=n, t0=t0, c=c, lam=lam, N=N, staggering=staggering,
                        activity_times=dict(activity_times), T_s=T_s,
                        m_star=m_star)


def choose_degree(plan: PipelinePlan, cores: Optional[int] = None,
                  cap: int = 64) -> int:
    """Practical degree: Theorem-1 optimum, bounded by a configured cap and
    (when known) by available cores — the paper observed the decline past the
    core count (Fig 12/13)."""
    m = int(round(plan.m_star))
    if cores is not None:
        m = min(m, max(1, cores))
    return int(min(max(m, 1), cap))
