"""Algorithm 3 + Theorem 1 — estimate the optimal degree of pipeline
parallelization, plus the runtime plan for the streaming executor.

Cost model (paper §4.2): with m splits, staggering activity A_j of
per-split time t_j = t0 + lambda*N/m, and per-activity miscellaneous time
t0, the pipeline time is

    T_p(m) = c/m + (m-1)*t_j + n*t0
           = (c - lambda*N)/m + t0*m + lambda*N + (n-1)*t0

minimized at  m* = sqrt((c - lambda*N) / t0)          (Theorem 1)

where c = m * sum_i (t_i - t0) is the total *net* processing time of the
full input (independent of m) and N is the number of rows through A_j.

Beyond the paper: ``plan_runtime`` sizes the shared worker pool and the
per-inter-tree-edge channel depths from cache-size metadata (estimated bytes
crossing each tree boundary), so backpressure bounds in-flight copies to a
memory budget while keeping enough depth to decouple producer bursts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .component import ComponentType
from .graph import Dataflow
from .partitioner import ExecutionTreeGraph


# ---------------------------------------------------------------------------
#  Segment discovery — maximal fusable row-synchronized chains
# ---------------------------------------------------------------------------
def _segment_fusable(comp) -> bool:
    """A component may join a fused segment iff it is row-synchronized,
    declares segment ops (row-local by the §3 contract), is not an explicit
    stage cut, not order-sensitive, and not chunk-sensitive (its data
    semantics must not depend on where chunk boundaries fall, because fused
    device kernels pad chunks to a bucketed batch size)."""
    return (comp.ctype == ComponentType.ROW_SYNC
            and not comp.order_sensitive
            and not comp.tree_boundary
            and not getattr(comp, "chunk_sensitive", False)
            and comp.segment_ops() is not None)


def discover_segments(flow: Dataflow,
                      through_aggregates: bool = False) -> List[List[str]]:
    """Find every maximal chain of fusable row-synchronized components.

    A chain extends across an edge u -> v only when it is a simple chain
    segment (out-degree(u) == 1, in-degree(v) == 1) and both endpoints are
    fusable; fan-in/fan-out, block / semi-block components, sinks, explicit
    ``StageBoundary`` cuts, order-sensitive and chunk-sensitive members all
    terminate (or refuse) a segment.  Only chains of length >= 2 are
    returned — fusing a single component would only rename it.

    ``through_aggregates=True`` additionally extends each found chain through
    its single downstream consumer when that consumer declares
    ``segment_terminal_aggregate`` (the ``Aggregate`` block component): the
    aggregate then appears as the chain's LAST member.  It does not join the
    fused kernel — the optimizer strips it before collapsing — but marks the
    segment for keep-mask deferral: the per-chunk compact moves into
    ``Aggregate.finish``, applied once after the merge (the d2h mask sync a
    device backend would otherwise pay per chunk disappears)."""
    chains: List[List[str]] = []
    seen: set = set()
    for name in flow.topo_order():
        if name in seen or not _segment_fusable(flow.component(name)):
            continue
        preds = flow.pred(name)
        if (len(preds) == 1 and flow.out_degree(preds[0]) == 1
                and _segment_fusable(flow.component(preds[0]))):
            continue                 # not a chain head; covered upstream
        chain = [name]
        seen.add(name)
        cur = name
        while True:
            succs = flow.succ(cur)
            if len(succs) != 1:
                break
            nxt = succs[0]
            if (flow.in_degree(nxt) != 1
                    or not _segment_fusable(flow.component(nxt))):
                break
            chain.append(nxt)
            seen.add(nxt)
            cur = nxt
        if len(chain) < 2:
            continue
        if through_aggregates:
            succs = flow.succ(cur)
            if len(succs) == 1 and flow.in_degree(succs[0]) == 1:
                nxt = flow.component(succs[0])
                if getattr(nxt, "segment_terminal_aggregate", False):
                    chain.append(succs[0])
        chains.append(chain)
    return chains


# ---------------------------------------------------------------------------
#  Static schema inference — AST/declared provenance over the whole flow
# ---------------------------------------------------------------------------
def infer_schema(flow: Dataflow, strict: bool = False):
    """Propagate column schemas through the flow from source column sets and
    each component's ``output_schema`` hook.

    Returns ``{component_name: frozenset(columns) | None}`` — the column set
    each component EMITS (``None`` once an unknown-schema component poisons
    the walk).  With the expression DSL this is exact static provenance: the
    Session front end runs it at build time so a typo'd column name fails at
    ``sink()`` with the component and the missing column named, instead of a
    ``KeyError`` deep inside a worker thread mid-run.

    ``strict=True`` additionally requires every component's declared read set
    (``consumed_columns``) to be covered by its input schema whenever both
    are known, raising ``ValueError`` otherwise."""
    schemas: Dict[str, Optional[frozenset]] = {}
    for name in flow.topo_order():
        comp = flow.component(name)
        preds = flow.pred(name)
        if not preds:
            incols: Optional[frozenset] = frozenset()
        else:
            pred_schemas = [schemas[p] for p in preds]
            if any(s is None for s in pred_schemas):
                incols = None
            else:
                # fan-in: only columns present on EVERY input branch are
                # safely readable (concat across branches requires equal
                # schemas anyway) — a union would let strict mode pass a
                # read that exists on just one branch
                incols = frozenset.intersection(*pred_schemas)
        if incols is not None:
            reads = comp.consumed_columns()
            if strict and reads is not None and preds:
                missing = reads - incols
                if missing:
                    raise ValueError(
                        f"component {name!r} reads column(s) "
                        f"{sorted(missing)} that are not in its input "
                        f"schema {sorted(incols)} — check the flow's "
                        f"expressions and column names")
            schemas[name] = comp.output_schema(incols)
        else:
            schemas[name] = None
    return schemas


@dataclass
class PipelinePlan:
    n: int                    # number of activities in the execution tree
    t0: float                 # avg per-activity miscellaneous time  (line 1,3)
    c: float                  # total net processing time, full input (line 3)
    lam: float                # lambda: seconds per row at the staggering activity
    N: int                    # rows processed by the staggering activity
    staggering: str           # name of A_j                          (line 3)
    activity_times: Dict[str, float] = field(default_factory=dict)
    T_s: float = 0.0          # measured sequential time on the sample
    m_star: float = 1.0       # Theorem 1 optimum                    (line 5)

    def predict_T_p(self, m: float) -> float:
        m = max(1.0, float(m))
        return ((self.c - self.lam * self.N) / m + self.t0 * m
                + self.lam * self.N + (self.n - 1) * self.t0)

    def predict_T_s(self) -> float:
        return self.c + self.n * self.t0

    def predict_speedup(self, m: float) -> float:
        tp = self.predict_T_p(m)
        return self.predict_T_s() / tp if tp > 0 else float("inf")


def theorem1_m_star(c: float, lam: float, N: float, t0: float,
                    m_max: Optional[int] = None) -> float:
    """m* = sqrt((c - lambda*N)/t0), clamped to [1, m_max] (paper: 1<=m<=|Sigma|).

    Degenerate calibration statistics get explicit fallbacks instead of a
    division by zero or a NaN plan: non-finite inputs -> 1 (serial); zero
    per-activity time t0 with no net work (c <= lambda*N) -> 1; zero t0 with
    real work -> the cost model says "as parallel as allowed" -> m_max."""
    if not all(math.isfinite(x) for x in (c, lam, N, t0)):
        return 1.0
    net = c - lam * N
    if t0 <= 0:
        return 1.0 if net <= 0 else float(m_max or 1)
    inner = max(net, 0.0) / t0
    m = math.sqrt(inner)
    m = max(1.0, m)
    if m_max is not None:
        m = min(m, float(m_max))
    return m


def build_plan(activity_times: Dict[str, float],
               misc_total: float,
               sample_rows: int,
               full_rows: int,
               m_prime: int,
               staggering_rows_sample: Optional[int] = None) -> PipelinePlan:
    """Algorithm 3 from measured quantities.

    ``activity_times``: per-activity busy time from the *sequential* sample
        run over m' splits                                        (line 2)
    ``misc_total``: T_0 — busy time of a zero-row run              (line 1)
    ``sample_rows`` / ``full_rows``: |D| and |Sigma|-scale factor
    ``staggering_rows_sample``: rows through A_j in the sample (defaults to
        sample_rows; differs when upstream filters drop rows).
    """
    names = list(activity_times.keys())
    if not names:
        # degenerate calibration (no activities measured): serial plan
        return PipelinePlan(n=0, t0=0.0, c=0.0, lam=0.0, N=0,
                            staggering="", T_s=0.0, m_star=1.0)
    times = np.array([activity_times[k] for k in names], dtype=np.float64)
    n = len(names)
    T_s = float(times.sum())
    t0 = misc_total / max(n, 1)                                   # line 3
    j = int(times.argmax())                                       # line 3
    staggering = names[j]
    scale = full_rows / max(sample_rows, 1)
    c_sample = max(T_s - misc_total, 1e-12)
    c = c_sample * scale                                          # line 3
    N_s = staggering_rows_sample or sample_rows
    N = int(round(N_s * scale))
    # line 4: lambda from the staggering activity's per-split time
    t_j_split = times[j] / max(m_prime, 1)
    lam = max(t_j_split - t0, 1e-12) * max(m_prime, 1) / max(N_s, 1)
    m_star = theorem1_m_star(c, lam, N, t0, m_max=full_rows)      # line 5
    return PipelinePlan(n=n, t0=t0, c=c, lam=lam, N=N, staggering=staggering,
                        activity_times=dict(activity_times), T_s=T_s,
                        m_star=m_star)


def choose_degree(plan: PipelinePlan, cores: Optional[int] = None,
                  cap: int = 64, split_bytes: Optional[int] = None,
                  memory_budget_bytes: Optional[int] = None) -> int:
    """Practical degree: Theorem-1 optimum, bounded by a configured cap and
    (when known) by available cores — the paper observed the decline past the
    core count (Fig 12/13).  When cache-size metadata is available
    (``split_bytes``), the degree is additionally capped so m' in-flight
    shared caches fit the memory budget."""
    if not math.isfinite(plan.m_star):
        return 1                    # degenerate plan: explicit serial fallback
    m = int(round(plan.m_star))
    if cores is not None:
        m = min(m, max(1, cores))
    if split_bytes and memory_budget_bytes:
        m = min(m, max(1, memory_budget_bytes // max(split_bytes, 1)))
    return int(min(max(m, 1), cap))


# ---------------------------------------------------------------------------
#  Runtime plan — shared pool width + per-edge channel depths (executor.py)
# ---------------------------------------------------------------------------
#: default memory budget for in-flight cross-tree copies, per edge
DEFAULT_CHANNEL_BUDGET_BYTES = 256 * 1024 * 1024


@dataclass
class RuntimePlan:
    """Sizing decisions for one engine run of the streaming executor."""
    pool_width: int
    # (src_tree_id, dst_tree_id) -> bounded queue depth (splits in flight)
    channel_depth: Dict[Tuple[int, int], int] = field(default_factory=dict)
    # (src_tree_id, dst_tree_id) -> estimated bytes crossing the edge
    edge_bytes: Dict[Tuple[int, int], int] = field(default_factory=dict)
    # source chunk rows aligned to the backend's preferred batch size; None
    # when no backend preference was planned (executor falls back to
    # total/num_splits)
    chunk_rows: Optional[int] = None

    def spec(self) -> dict:
        """Metadata-store representation (cache-size planning info)."""
        return {
            "pool_width": self.pool_width,
            "chunk_rows": self.chunk_rows,
            "channels": [{"edge": list(k), "depth": d,
                          "est_bytes": self.edge_bytes.get(k, 0)}
                         for k, d in sorted(self.channel_depth.items())],
        }


def estimate_edge_bytes(flow: Dataflow,
                        g_tau: ExecutionTreeGraph) -> Dict[Tuple[int, int], int]:
    """Cache-size metadata per inter-tree edge: estimated bytes of the split
    stream crossing each tree->tree transition.  Source trees report their
    source's total bytes (``Component.est_output_bytes``); downstream trees
    inherit the sum of their inputs (a conservative no-attenuation bound —
    filters only shrink it).  Every out-edge carries the FULL replicated
    stream (the pipeline copies the output to each cross-tree successor),
    so fan-out does not divide the estimate."""
    tree_bytes: Dict[int, int] = {}
    for tid in g_tau.topo_tree_order():
        tree = g_tau.tree(tid)
        root = flow.component(tree.root)
        est = root.est_output_bytes()
        if est is None:
            ups = g_tau.upstream_trees(tid)
            est = sum(tree_bytes.get(u, 0) for u in ups)
        tree_bytes[tid] = int(est)
    return {(a, b): tree_bytes.get(a, 0) for (a, b) in g_tau.edges}


def choose_channel_depth(edge_nbytes: int, num_splits: int, m_prime: int,
                         memory_budget_bytes: int = DEFAULT_CHANNEL_BUDGET_BYTES
                         ) -> int:
    """Per-edge queue depth m'': deep enough to decouple producer bursts
    (>= 2), never deeper than m' (upstream admission already bounds in-flight
    splits), and shallow enough that the buffered cross-tree COPIES stay
    within the memory budget."""
    depth = max(1, int(m_prime))
    split_bytes = edge_nbytes // max(1, int(num_splits))
    if split_bytes > 0:
        by_mem = memory_budget_bytes // split_bytes
        depth = min(depth, max(2, int(by_mem)))
    return max(1, depth)


def choose_pool_width(num_trees: int, m_prime: int,
                      mt_threads: Optional[Dict[str, int]] = None,
                      wave_width: int = 1,
                      cores: Optional[int] = None, cap: int = 64) -> int:
    """Width of the single shared worker pool: enough runnable workers for
    m' in-flight splits per concurrently-active tree plus the widest §4.3
    row-range fan-out, capped (and capped at cores when known — the paper's
    Fig 12/13 decline past the core count).  ``wave_width`` is the number
    of trees active at once — the widest schedule wave, plus any streamed
    trees that overlap their upstream wave — and never exceeds
    ``num_trees``."""
    mt_max = max([1] + list((mt_threads or {}).values()))
    concurrency = max(1, min(wave_width, max(num_trees, 1)))
    want = max(2, m_prime * concurrency, mt_max)
    if cores is not None:
        want = min(want, max(1, cores))
    return int(min(want, cap))


def backend_chunk_rows(flow: Dataflow, num_splits: int, backend) -> Optional[int]:
    """Source chunk size honouring the backend's preferred batch alignment:
    total/num_splits rounded UP to a multiple of ``backend.batch_align`` so
    jitted device kernels see few distinct shapes (and the segment-sum Pallas
    grid has no ragged final tile in the common case)."""
    align = max(1, int(getattr(backend, "batch_align", 1)))
    if align == 1:
        return None          # no preference: keep per-source even splits
    total = 0
    from .component import SourceComponent   # local import (module cycle)
    for sname in flow.sources():
        comp = flow.component(sname)
        if isinstance(comp, SourceComponent):
            total = max(total, comp.total_rows())
    if total <= 0:
        return None
    base = -(-total // max(1, int(num_splits)))
    return int(-(-base // align) * align)


def plan_runtime(flow: Dataflow, g_tau: ExecutionTreeGraph, *,
                 num_splits: int, m_prime: int,
                 mt_threads: Optional[Dict[str, int]] = None,
                 cores: Optional[int] = None,
                 pool_width: Optional[int] = None,
                 channel_capacity: Optional[int] = None,
                 memory_budget_bytes: int = DEFAULT_CHANNEL_BUDGET_BYTES,
                 streaming: bool = False,
                 backend=None,
                 edge_bytes_override: Optional[Dict[Tuple[int, int], int]]
                 = None) -> RuntimePlan:
    """Build the executor sizing plan for one run.  Explicit ``pool_width`` /
    ``channel_capacity`` overrides win; otherwise widths come from the
    schedule's widest wave (plus streamed-boundary overlap when
    ``streaming``) and depths from cache-size metadata.  When an operator
    ``backend`` is given, source splits are batched to its preferred size
    (``RuntimePlan.chunk_rows``) and edge-byte estimates already reflect its
    dtype widths via ``Component.est_output_bytes``.

    ``edge_bytes_override`` replaces the static ``est_output_bytes`` guesses
    with MEASURED per-edge bytes (``optimizer.measured_edge_bytes``) — the
    adaptive path where channel depths reflect observed attenuation instead
    of the conservative no-attenuation bound."""
    from .partitioner import streamable_tree_ids
    from .scheduler import plan_schedule     # local import (module cycle)
    wave_width = max((len(w) for w in plan_schedule(g_tau)), default=1)
    if streaming:
        # a streamed stage-boundary tree runs concurrently with its
        # upstream wave rather than after it
        wave_width += len(streamable_tree_ids(flow, g_tau))
    width = pool_width if pool_width is not None else choose_pool_width(
        len(g_tau.trees), m_prime, mt_threads, wave_width, cores=cores)
    edge_bytes = (dict(edge_bytes_override) if edge_bytes_override is not None
                  else estimate_edge_bytes(flow, g_tau))
    depths: Dict[Tuple[int, int], int] = {}
    for edge, nbytes in edge_bytes.items():
        depths[edge] = (channel_capacity if channel_capacity is not None
                        else choose_channel_depth(nbytes, num_splits, m_prime,
                                                  memory_budget_bytes))
    chunk = (backend_chunk_rows(flow, num_splits, backend)
             if backend is not None else None)
    return RuntimePlan(pool_width=max(1, int(width)),
                       channel_depth=depths, edge_bytes=edge_bytes,
                       chunk_rows=chunk)
