"""Declarative column-expression DSL — introspectable predicates and
derived-column expressions whose provenance is DERIVED, not declared.

The legacy component API takes opaque Python lambdas plus a hand-declared
``reads=`` list; one forgotten column silently disables filter-commute,
segment fusion and the minimal device upload set.  This module replaces the
lambdas with a small expression AST:

    from repro import col, lit, where

    pred = col("lo_discount").between(1, 3) & (col("d_year") == 1993)
    rev  = col("lo_extendedprice") * col("lo_discount")
    big  = where(col("profit") > 0, col("profit"), lit(0)).cast(np.int32)

Every node knows its exact read column set (``Expr.columns()``), so the
cost-based optimizer's commute/fusion rules and ``FusedSegment``'s kernel
upload set get exact provenance for free.

An ``Expr`` is *callable with the legacy signature* ``expr(cache, rows)``,
so it drops into every place a ``fn(cache, rows)`` lambda was accepted —
and because evaluation dispatches through the operands' own operators, the
same AST compiles three ways:

  1. **eager numpy** — ``cache.col`` returns host ndarrays, the ops run
     vectorized on host (the reference semantics);
  2. **jitted jax** — the jax backend compiles an expression once into a
     single XLA computation over exactly ``columns()`` device arrays
     (``JaxBackend`` recognises ``Expr`` in ``filter_mask`` /
     ``eval_expression``), so predicates run as ONE fused device kernel
     instead of a host lambda round-trip or per-op dispatch;
  3. **fused segment bodies** — inside ``Backend.compile_segment`` the
     segment runner hands the expression a tracer-backed ``SegmentEnv``
     view and the whole predicate traces straight into the segment's
     jitted kernel.

Only ``where`` and dtype casts need explicit namespace dispatch (numpy vs
``jax.numpy``); everything else is plain operator protocol.
"""
from __future__ import annotations

import operator
from typing import Dict, FrozenSet, Iterable, List, Optional

import numpy as np


def _array_namespace(*values):
    """numpy, unless any operand is a jax array / tracer (module rooted at
    ``jax`` or ``jaxlib``) — then ``jax.numpy``, imported lazily so the DSL
    never forces a jax import on the host path."""
    for v in values:
        root = type(v).__module__.partition(".")[0]
        if root in ("jax", "jaxlib"):
            import jax.numpy as jnp
            return jnp
    return np


class ColumnsView:
    """Minimal cache-like evaluation target over a plain dict of columns —
    what ``Expr.eval_columns`` and the jitted jax expression runner hand to
    ``evaluate`` (same ``col``/``names`` surface as ``SharedCache``)."""

    __slots__ = ("_cols",)

    def __init__(self, cols: Dict[str, object]):
        self._cols = cols

    @property
    def names(self) -> List[str]:
        return list(self._cols)

    def col(self, name: str):
        try:
            return self._cols[name]
        except KeyError:
            raise KeyError(f"expression reads unknown column {name!r}; "
                           f"available: {sorted(self._cols)}") from None


# ---------------------------------------------------------------------------
#  AST nodes
# ---------------------------------------------------------------------------
class Expr:
    """Base expression node.  Build with ``col``/``lit``/``where`` and the
    overloaded operators; evaluate with ``expr(cache, rows)`` (the legacy
    component-callable signature) or ``expr.eval_columns({...})``."""

    # --------------------------------------------------------- construction
    @staticmethod
    def wrap(value) -> "Expr":
        """Lift a scalar to ``Lit``; pass ``Expr`` nodes through."""
        return value if isinstance(value, Expr) else Lit(value)

    # arithmetic -----------------------------------------------------------
    def __add__(self, o):
        return BinOp("add", self, Expr.wrap(o))

    def __radd__(self, o):
        return BinOp("add", Expr.wrap(o), self)

    def __sub__(self, o):
        return BinOp("sub", self, Expr.wrap(o))

    def __rsub__(self, o):
        return BinOp("sub", Expr.wrap(o), self)

    def __mul__(self, o):
        return BinOp("mul", self, Expr.wrap(o))

    def __rmul__(self, o):
        return BinOp("mul", Expr.wrap(o), self)

    def __truediv__(self, o):
        return BinOp("truediv", self, Expr.wrap(o))

    def __rtruediv__(self, o):
        return BinOp("truediv", Expr.wrap(o), self)

    def __floordiv__(self, o):
        return BinOp("floordiv", self, Expr.wrap(o))

    def __rfloordiv__(self, o):
        return BinOp("floordiv", Expr.wrap(o), self)

    def __mod__(self, o):
        return BinOp("mod", self, Expr.wrap(o))

    def __rmod__(self, o):
        return BinOp("mod", Expr.wrap(o), self)

    def __neg__(self):
        return UnOp("neg", self)

    def __abs__(self):
        return UnOp("abs", self)

    # comparisons ----------------------------------------------------------
    def __eq__(self, o):                                    # type: ignore[override]
        return BinOp("eq", self, Expr.wrap(o))

    def __ne__(self, o):                                    # type: ignore[override]
        return BinOp("ne", self, Expr.wrap(o))

    def __lt__(self, o):
        return BinOp("lt", self, Expr.wrap(o))

    def __le__(self, o):
        return BinOp("le", self, Expr.wrap(o))

    def __gt__(self, o):
        return BinOp("gt", self, Expr.wrap(o))

    def __ge__(self, o):
        return BinOp("ge", self, Expr.wrap(o))

    # __eq__ is overloaded to BUILD nodes, so restore identity hashing —
    # expressions are compared structurally via repr, never via ==
    __hash__ = object.__hash__

    # boolean --------------------------------------------------------------
    def __and__(self, o):
        return BinOp("and", self, Expr.wrap(o))

    def __rand__(self, o):
        return BinOp("and", Expr.wrap(o), self)

    def __or__(self, o):
        return BinOp("or", self, Expr.wrap(o))

    def __ror__(self, o):
        return BinOp("or", Expr.wrap(o), self)

    def __xor__(self, o):
        return BinOp("xor", self, Expr.wrap(o))

    def __rxor__(self, o):
        return BinOp("xor", Expr.wrap(o), self)

    def __invert__(self):
        return UnOp("invert", self)

    def __bool__(self):
        raise TypeError(
            "an Expr has no truth value — use & | ~ for boolean logic "
            "(`and`/`or`/`not` cannot be overloaded) and == for equality "
            "nodes")

    # sugar ----------------------------------------------------------------
    def between(self, lo, hi) -> "Expr":
        """Inclusive band predicate: ``lo <= self <= hi``."""
        return (self >= lo) & (self <= hi)

    def isin(self, values: Iterable) -> "Expr":
        """Membership predicate: OR-fold of equality against each value."""
        vals = list(values)
        if not vals:
            raise ValueError("isin() needs at least one value")
        out: Expr = self == vals[0]
        for v in vals[1:]:
            out = out | (self == v)
        return out

    def cast(self, dtype) -> "Expr":
        """Dtype-aware cast (``astype`` alias).  Device backends apply their
        canonical dtype (jax with x64 off maps 64-bit to 32-bit)."""
        return Cast(self, np.dtype(dtype))

    astype = cast

    # --------------------------------------------------------- introspection
    def columns(self) -> FrozenSet[str]:
        """The EXACT set of column names this expression reads — derived
        from the AST, cached, and consumed as provenance by the optimizer's
        commute/fusion rules and the fused-kernel upload sets."""
        got = self.__dict__.get("_columns_cache")
        if got is None:
            got = self.__dict__["_columns_cache"] = self._columns()
        return got

    def _columns(self) -> FrozenSet[str]:  # pragma: no cover — abstract
        raise NotImplementedError

    # ------------------------------------------------------------ evaluation
    def evaluate(self, cache, rows):  # pragma: no cover — abstract
        """Evaluate against any cache-like view (``col(name)`` ->  array).
        ``rows`` slices each leaf column, matching the legacy lambda
        convention ``c.col(name)[rows]``."""
        raise NotImplementedError

    def __call__(self, cache, rows):
        """Legacy component-callable signature ``fn(cache, rows)`` — an
        ``Expr`` drops in wherever a predicate/expression lambda was
        accepted."""
        return self.evaluate(cache, rows)

    def eval_columns(self, cols: Dict[str, object]):
        """Convenience: evaluate over a plain ``{name: array}`` dict."""
        return self.evaluate(ColumnsView(cols), slice(None))


class Col(Expr):
    """A named column reference — the AST leaf."""

    def __init__(self, name: str):
        if not isinstance(name, str) or not name:
            raise TypeError(f"column name must be a non-empty str, "
                            f"got {name!r}")
        self.name = name

    def _columns(self) -> FrozenSet[str]:
        return frozenset({self.name})

    def evaluate(self, cache, rows):
        return cache.col(self.name)[rows]

    def __repr__(self) -> str:
        return f"col({self.name!r})"


class Lit(Expr):
    """A scalar literal.  Arrays are rejected: a per-row constant column
    would silently desynchronize under filtering — derive it from a real
    column instead."""

    def __init__(self, value):
        if isinstance(value, Expr):
            raise TypeError("lit() of an Expr — pass the expression itself")
        if isinstance(value, np.ndarray) and value.ndim != 0:
            raise TypeError(
                "lit() takes scalars only; a per-row array literal cannot "
                "stay row-synchronized under filtering — add it as a source "
                "column or derive() it")
        self.value = value.item() if isinstance(value, np.ndarray) else value

    def _columns(self) -> FrozenSet[str]:
        return frozenset()

    def evaluate(self, cache, rows):
        return self.value

    def __repr__(self) -> str:
        return f"lit({self.value!r})"


_BIN_FNS = {
    "add": operator.add, "sub": operator.sub, "mul": operator.mul,
    "truediv": operator.truediv, "floordiv": operator.floordiv,
    "mod": operator.mod,
    "eq": operator.eq, "ne": operator.ne, "lt": operator.lt,
    "le": operator.le, "gt": operator.gt, "ge": operator.ge,
    "and": operator.and_, "or": operator.or_, "xor": operator.xor,
}
_BIN_SYMBOLS = {
    "add": "+", "sub": "-", "mul": "*", "truediv": "/", "floordiv": "//",
    "mod": "%", "eq": "==", "ne": "!=", "lt": "<", "le": "<=", "gt": ">",
    "ge": ">=", "and": "&", "or": "|", "xor": "^",
}


class BinOp(Expr):
    """A binary operation — evaluation dispatches through the operands' own
    operator protocol, so host ndarrays, device arrays and jit tracers all
    work without branching."""

    def __init__(self, op: str, left: Expr, right: Expr):
        if op not in _BIN_FNS:
            raise ValueError(f"unknown binary op {op!r}")
        self.op = op
        self.left = left
        self.right = right

    def _columns(self) -> FrozenSet[str]:
        return self.left.columns() | self.right.columns()

    def evaluate(self, cache, rows):
        return _BIN_FNS[self.op](self.left.evaluate(cache, rows),
                                 self.right.evaluate(cache, rows))

    def __repr__(self) -> str:
        return f"({self.left!r} {_BIN_SYMBOLS[self.op]} {self.right!r})"


_UN_FNS = {"neg": operator.neg, "invert": operator.invert, "abs": abs}
_UN_SYMBOLS = {"neg": "-", "invert": "~", "abs": "abs"}


class UnOp(Expr):
    def __init__(self, op: str, operand: Expr):
        if op not in _UN_FNS:
            raise ValueError(f"unknown unary op {op!r}")
        self.op = op
        self.operand = operand

    def _columns(self) -> FrozenSet[str]:
        return self.operand.columns()

    def evaluate(self, cache, rows):
        return _UN_FNS[self.op](self.operand.evaluate(cache, rows))

    def __repr__(self) -> str:
        if self.op == "abs":
            return f"abs({self.operand!r})"
        return f"({_UN_SYMBOLS[self.op]}{self.operand!r})"


class Cast(Expr):
    """Dtype cast.  The target dtype is the HOST dtype; device backends
    apply their canonicalization (jax x64-off: 64-bit -> 32-bit), exactly
    as an eager ``astype`` on a device column would."""

    def __init__(self, operand: Expr, dtype):
        self.operand = operand
        self.dtype = np.dtype(dtype)

    def _columns(self) -> FrozenSet[str]:
        return self.operand.columns()

    def evaluate(self, cache, rows):
        v = self.operand.evaluate(cache, rows)
        if not hasattr(v, "astype"):       # python scalar literal
            v = np.asarray(v)
        return v.astype(self.dtype)

    def __repr__(self) -> str:
        return f"{self.operand!r}.cast({self.dtype.name!r})"


class Where(Expr):
    """Elementwise conditional select — the only node needing an explicit
    numpy-vs-jax.numpy dispatch (there is no operator for ``where``)."""

    def __init__(self, cond: Expr, if_true: Expr, if_false: Expr):
        self.cond = cond
        self.if_true = if_true
        self.if_false = if_false

    def _columns(self) -> FrozenSet[str]:
        return (self.cond.columns() | self.if_true.columns()
                | self.if_false.columns())

    def evaluate(self, cache, rows):
        c = self.cond.evaluate(cache, rows)
        t = self.if_true.evaluate(cache, rows)
        f = self.if_false.evaluate(cache, rows)
        return _array_namespace(c, t, f).where(c, t, f)

    def __repr__(self) -> str:
        return f"where({self.cond!r}, {self.if_true!r}, {self.if_false!r})"


# ---------------------------------------------------------------------------
#  Public constructors
# ---------------------------------------------------------------------------
def col(name: str) -> Col:
    """Reference a column by name: ``col("lo_discount")``."""
    return Col(name)


def lit(value) -> Lit:
    """Lift a scalar to an expression literal (usually implicit — bare
    scalars on either side of an operator are wrapped automatically)."""
    return Lit(value)


def where(cond, if_true, if_false) -> Where:
    """Elementwise select: ``where(col("p") > 0, col("p"), lit(0))``."""
    return Where(Expr.wrap(cond), Expr.wrap(if_true), Expr.wrap(if_false))


def expr_reads(fn) -> Optional[FrozenSet[str]]:
    """Exact read set of a component callable: derived for ``Expr`` nodes,
    ``None`` (unknown) for opaque legacy callables."""
    return fn.columns() if isinstance(fn, Expr) else None
