# The paper's primary contribution: component classification (§3),
# execution-tree partitioning (Algorithm 1), shared caching scheme (§3),
# pipeline parallelization (Algorithm 2 + Theorem 1), inside-component
# multithreading (§4.3), and the dataflow task planner (§2) — extended with
# a streaming inter-tree executor on one shared worker pool (executor.py).
from . import config
from .backend import (Backend, available_backends, get_backend,
                      get_default_backend, register_backend, resolve_backend,
                      set_default_backend)
from .component import (BlockComponent, Component, ComponentType, FnComponent,
                        SemiBlockComponent, SinkComponent, SourceComponent,
                        StageBoundary)
from .engine import (EngineRun, OptimizedEngine, OptimizeOptions,
                     OrdinaryEngine, ServingEngine, StreamingEngine)
from .executor import (ChannelGroup, ExecutionAborted, RunAbort,
                       SharedWorkerPool, StreamingExecutor, TaskFuture)
from .expr import Col, ColumnsView, Expr, Lit, col, expr_reads, lit, where
from .faults import (Degradation, FaultError, FaultPlan, PermanentFault,
                     PoisonFault, TransientFault, fault_recorder, fault_scope,
                     retry_call, with_retries)
from .graph import Dataflow
from .metadata import MetadataStore
from .optimizer import (ComponentStats, CostBasedOptimizer, FlowStatistics,
                        Refusal, Rewrite, fuse_segments_flow,
                        measured_edge_bytes, run_calibration,
                        suggest_pipeline_degree)
from .partitioner import ExecutionTree, ExecutionTreeGraph, partition
from .pipeline import TreePipeline
from .planner import (PipelinePlan, RuntimePlan, backend_chunk_rows,
                      build_plan, choose_channel_depth, choose_degree,
                      choose_pool_width, discover_segments,
                      estimate_edge_bytes, infer_schema, plan_runtime,
                      theorem1_m_star)
from .scheduler import plan_schedule, run_tree_graph
from .shard import (ShardContext, ShardPlan, ShardResult, ShardRunner,
                    choose_shards, plan_shards)
from .shared_cache import (GLOBAL_ARENA, GLOBAL_CACHE_STATS, CacheArena,
                           CacheStats, SharedCache, cache_stats_scope,
                           concat_caches)
from .simulate import (SimResult, cpu_usage_curve, multithreading_curve,
                       simulate_tree, speedup_curve)

__all__ = [
    "config",
    "Backend", "available_backends", "get_backend", "get_default_backend",
    "register_backend", "resolve_backend", "set_default_backend",
    "BlockComponent", "Component", "ComponentType", "FnComponent",
    "SemiBlockComponent", "SinkComponent", "SourceComponent", "StageBoundary",
    "EngineRun", "OptimizedEngine", "OptimizeOptions", "OrdinaryEngine",
    "ServingEngine", "StreamingEngine",
    "ChannelGroup", "ExecutionAborted", "RunAbort", "SharedWorkerPool",
    "StreamingExecutor", "TaskFuture",
    "Col", "ColumnsView", "Expr", "Lit", "col", "expr_reads", "lit", "where",
    "Degradation", "FaultError", "FaultPlan", "PermanentFault", "PoisonFault",
    "TransientFault", "fault_recorder", "fault_scope", "retry_call",
    "with_retries",
    "Dataflow", "MetadataStore",
    "ComponentStats", "CostBasedOptimizer", "FlowStatistics", "Refusal",
    "Rewrite", "fuse_segments_flow", "measured_edge_bytes", "run_calibration",
    "suggest_pipeline_degree",
    "ExecutionTree", "ExecutionTreeGraph", "partition",
    "TreePipeline",
    "PipelinePlan", "RuntimePlan", "backend_chunk_rows", "build_plan",
    "choose_channel_depth", "choose_degree", "choose_pool_width",
    "discover_segments", "estimate_edge_bytes", "infer_schema",
    "plan_runtime", "theorem1_m_star",
    "plan_schedule", "run_tree_graph",
    "ShardContext", "ShardPlan", "ShardResult", "ShardRunner",
    "choose_shards", "plan_shards",
    "GLOBAL_ARENA", "GLOBAL_CACHE_STATS", "CacheArena", "CacheStats",
    "SharedCache", "cache_stats_scope", "concat_caches",
    "SimResult", "cpu_usage_curve", "multithreading_curve", "simulate_tree",
    "speedup_curve",
]
