# The paper's primary contribution: component classification (§3),
# execution-tree partitioning (Algorithm 1), shared caching scheme (§3),
# pipeline parallelization (Algorithm 2 + Theorem 1), inside-component
# multithreading (§4.3), and the dataflow task planner (§2).
from .component import (BlockComponent, Component, ComponentType, FnComponent,
                        SemiBlockComponent, SinkComponent, SourceComponent)
from .engine import (EngineRun, OptimizedEngine, OptimizeOptions,
                     OrdinaryEngine)
from .graph import Dataflow
from .metadata import MetadataStore
from .partitioner import ExecutionTree, ExecutionTreeGraph, partition
from .pipeline import TreePipeline
from .planner import (PipelinePlan, build_plan, choose_degree,
                      theorem1_m_star)
from .scheduler import plan_schedule, run_tree_graph
from .shared_cache import (GLOBAL_CACHE_STATS, CacheStats, SharedCache,
                           concat_caches)
from .simulate import (SimResult, cpu_usage_curve, multithreading_curve,
                       simulate_tree, speedup_curve)

__all__ = [
    "BlockComponent", "Component", "ComponentType", "FnComponent",
    "SemiBlockComponent", "SinkComponent", "SourceComponent",
    "EngineRun", "OptimizedEngine", "OptimizeOptions", "OrdinaryEngine",
    "Dataflow", "MetadataStore",
    "ExecutionTree", "ExecutionTreeGraph", "partition",
    "TreePipeline",
    "PipelinePlan", "build_plan", "choose_degree", "theorem1_m_star",
    "plan_schedule", "run_tree_graph",
    "GLOBAL_CACHE_STATS", "CacheStats", "SharedCache", "concat_caches",
    "SimResult", "cpu_usage_curve", "multithreading_curve", "simulate_tree",
    "speedup_curve",
]
