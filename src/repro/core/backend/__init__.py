"""Pluggable operator backends for the ETL component library.

``numpy`` is the always-available reference; ``jax`` runs the same operator
kernels jitted on device (groupby through the ``kernels/segment_sum`` Pallas
op).  See base.py for the interface and selection order."""
from .base import (AGG_OPS, BACKEND_ENV_VAR, SEGMENT_KEEP_MASK, Backend,
                   available_backends, get_backend, get_default_backend,
                   register_backend, resolve_backend, set_default_backend)
from .numpy_backend import NumpyBackend

register_backend("numpy", NumpyBackend)


def _make_jax_backend() -> Backend:
    from .jax_backend import JaxBackend     # deferred: only imports jax on use
    return JaxBackend()


register_backend("jax", _make_jax_backend)

__all__ = [
    "AGG_OPS", "BACKEND_ENV_VAR", "SEGMENT_KEEP_MASK", "Backend",
    "NumpyBackend",
    "available_backends", "get_backend", "get_default_backend",
    "register_backend", "resolve_backend", "set_default_backend",
]
