"""Reference operator backend: plain numpy, bit-identical to the component
code it replaced (the inlined Filter/Lookup/Expression/Aggregate/Sort
bodies).  Every accelerated backend is property-tested against this one.

Segment fusion (``compile_segment``) uses the base class's composed host
runner unchanged: one vectorized pass over the fused op list with filter
masks applied eagerly, so a ``FusedSegment`` on this backend is the
loop-free reference the jitted jax segment kernel is checked against."""
from __future__ import annotations

from typing import Callable, Dict, List, Mapping, Sequence, Tuple

import numpy as np

from .base import AGG_OPS, Backend


class NumpyBackend(Backend):
    name = "numpy"
    batch_align = 1
    oracle_rtol = 1e-9
    #: host compaction is a free boolean index — nothing to defer, so the
    #: composed host runner keeps applying the keep-mask eagerly per chunk
    #: even when the optimizer marked the segment for mask deferral (output
    #: is byte-identical either way; only transfer counts differ on device
    #: backends)
    supports_segment_defer = False

    # ------------------------------------------------------------ array ops
    def asarray(self, x) -> np.ndarray:
        return np.asarray(x)

    def to_host(self, x) -> np.ndarray:
        return np.asarray(x)

    def concat(self, parts: Sequence) -> np.ndarray:
        return np.concatenate([np.asarray(p) for p in parts])

    # ------------------------------------------------------- operator kernels
    def filter_mask(self, predicate: Callable, cache, rows: slice) -> np.ndarray:
        return np.asarray(predicate(cache, rows), dtype=bool)

    def eval_expression(self, fn: Callable, cache, rows: slice) -> np.ndarray:
        return np.asarray(fn(cache, rows))

    def searchsorted_probe(self, dim, vals) -> Tuple[np.ndarray, np.ndarray]:
        return dim.probe(np.asarray(vals))

    def lookup_gather(self, dim, dim_col: str, idx, matched, default):
        got = dim.payload[dim_col][np.asarray(idx)]
        return np.where(np.asarray(matched), got, np.asarray(default, got.dtype))

    def groupby_reduce(self, keys: Sequence, values: Mapping[str, Tuple[object, str]],
                       n_rows: int) -> Tuple[List[np.ndarray], Dict[str, np.ndarray]]:
        for out, (col, op) in values.items():
            if op not in AGG_OPS:
                raise ValueError(f"unknown agg op {op!r} for {out!r}")
        n = int(n_rows)
        if not keys:
            # global aggregation: one group over all rows
            aggs: Dict[str, np.ndarray] = {}
            for out, (col, op) in values.items():
                vals = np.asarray(col)
                if op == "count":
                    aggs[out] = np.array([n], dtype=np.int64)
                elif op == "sum":
                    aggs[out] = np.array([vals.astype(np.float64).sum()])
                elif op == "avg":
                    aggs[out] = np.array([vals.astype(np.float64).mean()])
                elif op == "min":
                    aggs[out] = np.array([vals.min()])
                elif op == "max":
                    aggs[out] = np.array([vals.max()])
            return [], aggs
        keys = [np.asarray(k) for k in keys]
        order = np.lexsort(keys[::-1])
        sk = [k[order] for k in keys]
        boundary = np.zeros(n, dtype=bool)
        boundary[0] = True
        for k in sk:
            boundary[1:] |= k[1:] != k[:-1]
        starts = np.flatnonzero(boundary)
        counts = np.diff(np.append(starts, n))
        group_cols = [k[starts] for k in sk]
        aggs = {}
        for out, (col, op) in values.items():
            if op == "count":
                aggs[out] = counts.astype(np.int64)
                continue
            vals = np.asarray(col)[order]
            if op in ("sum", "avg"):
                acc = np.add.reduceat(vals.astype(np.float64), starts)
                aggs[out] = acc / counts if op == "avg" else acc
            elif op == "min":
                aggs[out] = np.minimum.reduceat(vals, starts)
            elif op == "max":
                aggs[out] = np.maximum.reduceat(vals, starts)
        return group_cols, aggs

    def sort_rows(self, keys: Sequence, ascending: bool = True) -> np.ndarray:
        order = np.lexsort([np.asarray(k) for k in keys][::-1])
        return order if ascending else order[::-1]
