"""Operator-backend interface + registry.

A ``Backend`` supplies the heavy per-operator kernels the ETL component
library dispatches through (`etl/components.py`):

    filter_mask          row predicate -> boolean keep-mask
    searchsorted_probe   dimension-table probe (sorted keys + searchsorted)
    lookup_gather        payload gather with unmatched-default substitution
    eval_expression      derived-column computation
    groupby_reduce       group-by aggregation (sum/avg/min/max/count)
    sort_rows            stable multi-key row ordering (lexsort)

plus the array plumbing the shared-cache layer needs (``asarray`` /
``to_host`` / ``concat``) and the sizing metadata the runtime planner uses
(``dtype_width`` / ``batch_align``).

Two implementations ship: the ``numpy`` reference backend (bit-identical to
the historical inlined component code) and the ``jax`` accelerated backend
(jitted kernels, device-resident columns, ``groupby_reduce`` routed through
the ``kernels/segment_sum`` Pallas op).  Selection order:

    OptimizeOptions(backend=...)  >  REPRO_BACKEND env var  >  "numpy"

Backends are process-wide singletons created lazily, so importing this
module never imports jax.
"""
from __future__ import annotations

import os
import threading
from typing import (Callable, Dict, List, Mapping, Optional, Sequence, Tuple,
                    TYPE_CHECKING)

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from ..shared_cache import SharedCache

#: aggregation ops every backend must implement in groupby_reduce
AGG_OPS = ("sum", "avg", "min", "max", "count")

#: environment variable naming the default backend for the process
BACKEND_ENV_VAR = "REPRO_BACKEND"

DEFAULT_BACKEND = "numpy"


class Backend:
    """Abstract operator backend.  Subclasses implement the kernel set; the
    base class carries the sizing/precision metadata with safe defaults."""

    #: registry key ("numpy", "jax", ...)
    name: str = "abstract"
    #: planner hint: round source chunk sizes up to a multiple of this (the
    #: jax backend aligns to its segment-sum row tile so jitted kernels see
    #: few distinct shapes; 1 means no preference)
    batch_align: int = 1
    #: expected relative error of float aggregation vs a float64 oracle —
    #: engine-vs-oracle equality checks use this per-backend tolerance
    #: (float32 device accumulation cannot hit float64 exactness)
    oracle_rtol: float = 1e-9

    # ------------------------------------------------------------ array ops
    def asarray(self, x) -> object:
        """Convert to this backend's native array type (may record a
        host->device transfer in CacheStats)."""
        raise NotImplementedError

    def to_host(self, x) -> np.ndarray:
        """Convert a backend array to numpy (may record device->host)."""
        raise NotImplementedError

    def concat(self, parts: Sequence) -> object:
        """Concatenate row-range outputs (the row-order synchronizer's merge
        step) into one backend-native column."""
        raise NotImplementedError

    # --------------------------------------------------------------- sizing
    def dtype_width(self, dtype) -> int:
        """Bytes per element this backend stores for ``dtype`` (device
        backends may canonicalize, e.g. 64-bit -> 32-bit)."""
        return int(np.dtype(dtype).itemsize)

    def est_nbytes(self, columns: Mapping[str, np.ndarray]) -> int:
        """Estimated bytes of a columnar table under this backend's dtype
        widths — feeds ``Component.est_output_bytes`` so ``plan_runtime``
        channel sizing stays correct when columns are device arrays.
        ``v.size`` (total elements) keeps multi-dimensional columns (e.g. a
        [n, doc_len] token table) counted in full."""
        return int(sum(self.dtype_width(v.dtype) * v.size
                       for v in columns.values()))

    # ------------------------------------------------------- operator kernels
    def filter_mask(self, predicate: Callable, cache: "SharedCache",
                    rows: slice):
        """Evaluate ``predicate(cache_view, rows)`` to a boolean keep-mask."""
        raise NotImplementedError

    def eval_expression(self, fn: Callable, cache: "SharedCache",
                        rows: slice):
        """Evaluate ``fn(cache_view, rows)`` to a derived column."""
        raise NotImplementedError

    def searchsorted_probe(self, dim, vals) -> Tuple[object, object]:
        """Probe a ``DimTable``: returns (row_idx, matched_mask)."""
        raise NotImplementedError

    def lookup_gather(self, dim, dim_col: str, idx, matched, default):
        """Gather a payload column at ``idx``; unmatched rows get
        ``default``."""
        raise NotImplementedError

    def groupby_reduce(self, keys: Sequence, values: Mapping[str, Tuple[object, str]],
                       n_rows: int) -> Tuple[List[object], Dict[str, object]]:
        """Group-by aggregation.  ``keys`` are the group-by columns (empty =>
        one global group over ``n_rows`` rows); ``values`` maps output name
        -> (value column, op) with op in AGG_OPS.  Returns (group key
        columns in lexicographic ascending group order, aggregate columns in
        the same group order)."""
        raise NotImplementedError

    def sort_rows(self, keys: Sequence, ascending: bool = True):
        """Stable multi-key row order (last key major — lexsort semantics on
        ``keys[::-1]``); returns the permutation index array."""
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
#  Registry
# ---------------------------------------------------------------------------
_lock = threading.Lock()
_factories: Dict[str, Callable[[], Backend]] = {}
_instances: Dict[str, Backend] = {}
_default_override: Optional[str] = None


def register_backend(name: str, factory: Callable[[], Backend]) -> None:
    """Register a backend factory (instantiated lazily, cached)."""
    with _lock:
        _factories[name] = factory
        _instances.pop(name, None)


def available_backends() -> List[str]:
    with _lock:
        return sorted(_factories)


def get_backend(name: str) -> Backend:
    """Resolve a backend by name (lazy singleton)."""
    with _lock:
        if name not in _factories:
            raise ValueError(
                f"unknown backend {name!r}; available: {sorted(_factories)}")
        inst = _instances.get(name)
        if inst is None:
            inst = _instances[name] = _factories[name]()
        return inst


def set_default_backend(name: Optional[str]) -> None:
    """Process-wide default override (None restores env/builtin order)."""
    global _default_override
    if name is not None:
        get_backend(name)                      # validate eagerly
    _default_override = name


def resolve_backend(name: Optional[str] = None) -> Backend:
    """Selection order: explicit ``name`` > set_default_backend override >
    ``REPRO_BACKEND`` env var > "numpy"."""
    if name is None:
        name = (_default_override
                or os.environ.get(BACKEND_ENV_VAR, "").strip()
                or DEFAULT_BACKEND)
    return get_backend(name)


def get_default_backend() -> Backend:
    return resolve_backend(None)
