"""Operator-backend interface + registry.

A ``Backend`` supplies the heavy per-operator kernels the ETL component
library dispatches through (`etl/components.py`):

    filter_mask          row predicate -> boolean keep-mask
    searchsorted_probe   dimension-table probe (sorted keys + searchsorted)
    lookup_gather        payload gather with unmatched-default substitution
    eval_expression      derived-column computation
    groupby_reduce       group-by aggregation (sum/avg/min/max/count)
    sort_rows            stable multi-key row ordering (lexsort)

plus the array plumbing the shared-cache layer needs (``asarray`` /
``to_host`` / ``concat``) and the sizing metadata the runtime planner uses
(``dtype_width`` / ``batch_align``).

Two implementations ship: the ``numpy`` reference backend (bit-identical to
the historical inlined component code) and the ``jax`` accelerated backend
(jitted kernels, device-resident columns, ``groupby_reduce`` routed through
the ``kernels/segment_sum`` Pallas op).  Selection order:

    OptimizeOptions(backend=...)  >  REPRO_BACKEND env var  >  "numpy"

Backends are process-wide singletons created lazily, so importing this
module never imports jax.
"""
from __future__ import annotations

import threading
from typing import (Callable, Dict, List, Mapping, Optional, Sequence, Tuple,
                    TYPE_CHECKING)

import numpy as np

from .. import config

if TYPE_CHECKING:  # pragma: no cover
    from ..shared_cache import SharedCache

#: aggregation ops every backend must implement in groupby_reduce
AGG_OPS = ("sum", "avg", "min", "max", "count")

#: sentinel column a deferring fused segment leaves in its output cache: the
#: segment's combined keep-mask, NOT applied per chunk (that would force a
#: device->host sync every chunk).  The terminal ``Aggregate`` pops it after
#: the device-side concat and compacts the merged cache ONCE.  The name is
#: illegal as a user column (spaces), so it can never shadow real data.
SEGMENT_KEEP_MASK = "__segment keep mask__"

#: environment variable naming the default backend for the process
#: (typed accessor: ``core.config.backend_name``)
BACKEND_ENV_VAR = config.ENV_BACKEND

DEFAULT_BACKEND = "numpy"


class Backend:
    """Abstract operator backend.  Subclasses implement the kernel set; the
    base class carries the sizing/precision metadata with safe defaults."""

    #: registry key ("numpy", "jax", ...)
    name: str = "abstract"
    #: planner hint: round source chunk sizes up to a multiple of this (the
    #: jax backend aligns to its segment-sum row tile so jitted kernels see
    #: few distinct shapes; 1 means no preference)
    batch_align: int = 1
    #: expected relative error of float aggregation vs a float64 oracle —
    #: engine-vs-oracle equality checks use this per-backend tolerance
    #: (float32 device accumulation cannot hit float64 exactness)
    oracle_rtol: float = 1e-9
    #: whether this backend's ``compile_segment`` runner honors
    #: ``FusedSegment.defer_cols`` — leaving the chunk uncompacted with a
    #: ``SEGMENT_KEEP_MASK`` column for the terminal Aggregate to apply once.
    #: Only meaningful for backends where an eager compact costs a
    #: device->host sync; host backends compact for free and ignore deferral.
    supports_segment_defer: bool = False

    # ------------------------------------------------------------ array ops
    def asarray(self, x) -> object:
        """Convert to this backend's native array type (may record a
        host->device transfer in CacheStats)."""
        raise NotImplementedError

    def to_host(self, x) -> np.ndarray:
        """Convert a backend array to numpy (may record device->host)."""
        raise NotImplementedError

    def concat(self, parts: Sequence) -> object:
        """Concatenate row-range outputs (the row-order synchronizer's merge
        step) into one backend-native column."""
        raise NotImplementedError

    # --------------------------------------------------------------- sizing
    def dtype_width(self, dtype) -> int:
        """Bytes per element this backend stores for ``dtype`` (device
        backends may canonicalize, e.g. 64-bit -> 32-bit)."""
        return int(np.dtype(dtype).itemsize)

    def est_nbytes(self, columns: Mapping[str, np.ndarray]) -> int:
        """Estimated bytes of a columnar table under this backend's dtype
        widths — feeds ``Component.est_output_bytes`` so ``plan_runtime``
        channel sizing stays correct when columns are device arrays.
        ``v.size`` (total elements) keeps multi-dimensional columns (e.g. a
        [n, doc_len] token table) counted in full."""
        return int(sum(self.dtype_width(v.dtype) * v.size
                       for v in columns.values()))

    # ------------------------------------------------------- operator kernels
    def filter_mask(self, predicate: Callable, cache: "SharedCache",
                    rows: slice):
        """Evaluate ``predicate(cache_view, rows)`` to a boolean keep-mask."""
        raise NotImplementedError

    def eval_expression(self, fn: Callable, cache: "SharedCache",
                        rows: slice):
        """Evaluate ``fn(cache_view, rows)`` to a derived column."""
        raise NotImplementedError

    def searchsorted_probe(self, dim, vals) -> Tuple[object, object]:
        """Probe a ``DimTable``: returns (row_idx, matched_mask)."""
        raise NotImplementedError

    def lookup_gather(self, dim, dim_col: str, idx, matched, default):
        """Gather a payload column at ``idx``; unmatched rows get
        ``default``."""
        raise NotImplementedError

    def groupby_reduce(self, keys: Sequence, values: Mapping[str, Tuple[object, str]],
                       n_rows: int) -> Tuple[List[object], Dict[str, object]]:
        """Group-by aggregation.  ``keys`` are the group-by columns (empty =>
        one global group over ``n_rows`` rows); ``values`` maps output name
        -> (value column, op) with op in AGG_OPS.  Returns (group key
        columns in lexicographic ascending group order, aggregate columns in
        the same group order)."""
        raise NotImplementedError

    def sort_rows(self, keys: Sequence, ascending: bool = True):
        """Stable multi-key row order (last key major — lexsort semantics on
        ``keys[::-1]``); returns the permutation index array."""
        raise NotImplementedError

    # ------------------------------------------------------- segment fusion
    def compile_segment(self, segment) -> Callable:
        """Compile a ``FusedSegment`` (a maximal row-synchronized chain of
        Filter/Expression/Lookup/Project/Converter activities) into ONE
        callable ``run(cache) -> None`` that mutates the shared cache in
        place exactly like running the chain component by component — but as
        a single backend dispatch per chunk.

        The base implementation is the loop-free composed host reference:
        each op is evaluated vectorized over the current row set with filter
        masks applied eagerly, so results are bit-identical to the unfused
        chain.  Accelerated backends override this with a genuinely compiled
        kernel (the jax backend jits the whole segment: one h2d in, one d2h
        out per chunk).  The returned runner is cached on the segment by the
        component, so compilation happens once per (segment, backend)."""
        from ..shared_cache import record_segment_compile   # cycle-free
        ops = list(segment.ops)
        backend = self
        record_segment_compile()

        def run(cache) -> None:
            _run_segment_host(backend, ops, cache)
        return run

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
#  Composed host reference for fused segments
# ---------------------------------------------------------------------------
def segment_written_columns(ops) -> List[str]:
    """Columns a fused segment produces/overwrites, in last-write order —
    static analysis over the op list (no data needed)."""
    written: List[str] = []

    def note(name: str) -> None:
        if name in written:
            written.remove(name)
        written.append(name)

    for op in ops:
        kind = op[0]
        if kind == "expr":
            note(op[1])
        elif kind == "lookup":
            for out_name in op[3]:
                note(out_name)
            if op[5]:
                note(op[5])
        elif kind == "convert":
            for col in op[1]:
                note(col)
    return written


def segment_final_live(ops, initial_names) -> set:
    """The column set left visible after the segment runs over a cache that
    started with ``initial_names`` (Projects prune, everything else adds)."""
    live = set(initial_names)
    for op in ops:
        kind = op[0]
        if kind == "expr":
            live.add(op[1])
        elif kind == "lookup":
            live.update(op[3])
            if op[5]:
                live.add(op[5])
        elif kind == "convert":
            live.update(op[1])
        elif kind == "project":
            live &= set(op[1])
    return live
class SegmentEnv:
    """The cache-like view fused predicates/expressions evaluate against:
    ``col(name)`` returns the column's CURRENT value at this point of the
    segment (input column, or the output of an earlier fused op)."""

    __slots__ = ("_get", "_live", "n")

    def __init__(self, get: Callable[[str], object], live, n: int):
        self._get = get
        self._live = live
        self.n = n

    @property
    def names(self) -> List[str]:
        return list(self._live)

    def col(self, name: str):
        if name not in self._live:
            raise KeyError(
                f"column {name!r} is not visible at this point of the fused "
                f"segment (dropped by an earlier Project, or an undeclared "
                f"read — declare it via the component's reads=)")
        return self._get(name)


def _run_segment_host(bk: Backend, ops, cache) -> None:
    """Reference execution of a fused segment: one pass over the op list with
    vectorized numpy kernels, filter masks applied eagerly (so every op sees
    exactly the rows the unfused chain would), and a single write-back to the
    shared cache (one compact + the produced columns)."""
    n0 = cache.n
    env: Dict[str, np.ndarray] = {}          # materialized current values
    live = set(cache.names)                  # columns visible right now
    written: List[str] = []                  # produced/overwritten, in order
    sel: Optional[np.ndarray] = None         # surviving original-row indices
    n_cur = n0

    def get(name: str) -> np.ndarray:
        if name not in live:
            # same visibility rule the unfused chain enforces: a column
            # dropped by an earlier Project (or never present) must not be
            # silently resurrected from the underlying cache
            raise KeyError(
                f"column {name!r} is not visible at this point of the fused "
                f"segment (dropped by an earlier Project, or missing)")
        got = env.get(name)
        if got is None:
            got = bk.to_host(cache.col(name))
            if sel is not None:
                got = got[sel]
            env[name] = got
        return got

    def note_written(name: str) -> None:
        live.add(name)
        if name in written:
            written.remove(name)
        written.append(name)

    for op in ops:
        kind = op[0]
        view = SegmentEnv(get, live, n_cur)
        rows = slice(0, n_cur)
        if kind == "filter":
            mask = np.asarray(op[1](view, rows), dtype=bool)
            sel_new = np.flatnonzero(mask) if sel is None else sel[mask]
            for k in list(env):
                env[k] = env[k][mask]
            sel = sel_new
            n_cur = int(len(sel))
        elif kind == "expr":
            _, out_col, fn = op[0], op[1], op[2]
            env[out_col] = np.asarray(fn(view, rows))
            note_written(out_col)
        elif kind == "lookup":
            _, dim, key_col, return_cols, default, matched_flag = op
            idx, matched = bk.searchsorted_probe(dim, get(key_col))
            idx, matched = bk.to_host(idx), bk.to_host(matched)
            for out_name, dim_col in return_cols.items():
                env[out_name] = bk.to_host(
                    bk.lookup_gather(dim, dim_col, idx, matched, default))
                note_written(out_name)
            if matched_flag:
                env[matched_flag] = np.asarray(matched, dtype=bool)
                note_written(matched_flag)
        elif kind == "project":
            live = live & set(op[1])
            for k in list(env):
                if k not in live:
                    del env[k]
        elif kind == "convert":
            for col, dt in op[1].items():
                env[col] = get(col).astype(dt)
                note_written(col)
        else:  # pragma: no cover — op kinds are produced by segment_ops()
            raise ValueError(f"unknown segment op kind {kind!r}")

    # single write-back: one compact, then the produced columns, then the
    # final column set (Project) — same end state as the unfused chain
    if sel is not None:
        final_mask = np.zeros(n0, dtype=bool)
        final_mask[sel] = True
        cache.compact(final_mask)
    for name in written:
        if name in live:
            cache.add_column(name, env[name])
    if live != set(cache.names):
        cache.keep_columns([k for k in cache.names if k in live])


# ---------------------------------------------------------------------------
#  Registry
# ---------------------------------------------------------------------------
_lock = threading.Lock()
_factories: Dict[str, Callable[[], Backend]] = {}
_instances: Dict[str, Backend] = {}
_default_override: Optional[str] = None


def register_backend(name: str, factory: Callable[[], Backend]) -> None:
    """Register a backend factory (instantiated lazily, cached)."""
    with _lock:
        _factories[name] = factory
        _instances.pop(name, None)


def available_backends() -> List[str]:
    with _lock:
        return sorted(_factories)


def get_backend(name: str) -> Backend:
    """Resolve a backend by name (lazy singleton)."""
    with _lock:
        if name not in _factories:
            raise ValueError(
                f"unknown backend {name!r}; available: {sorted(_factories)}")
        inst = _instances.get(name)
        if inst is None:
            inst = _instances[name] = _factories[name]()
        return inst


def set_default_backend(name: Optional[str]) -> None:
    """Process-wide default override (None restores env/builtin order)."""
    global _default_override
    if name is not None:
        get_backend(name)                      # validate eagerly
    _default_override = name


def resolve_backend(name: Optional[str] = None) -> Backend:
    """Selection order: explicit ``name`` > set_default_backend override >
    ``REPRO_BACKEND`` env var > "numpy"."""
    if name is None:
        name = (_default_override
                or config.backend_name()
                or DEFAULT_BACKEND)
    return get_backend(name)


def get_default_backend() -> Backend:
    return resolve_backend(None)
