"""Accelerated operator backend: jitted JAX kernels + device-resident columns.

Kernels:
  - ``searchsorted_probe`` / ``lookup_gather`` — probe over a device-cached
    dimension table (keys/qualifies/payload are device_put once per table and
    reused across every chunk).  Default route is the ``kernels/hash_join``
    open-addressing table (host-built once per DimTable, probes handle
    arbitrary key order and multi-column keys); ``REPRO_JOIN_IMPL=
    searchsorted`` selects the legacy jitted binary search over the sorted
    keys.  Both return the same (index, matched) pair bit-for-bit: the hash
    build keeps the FIRST occurrence of a duplicate key, which over the
    DimTable's sorted keys is exactly ``searchsorted``'s leftmost index.
  - ``groupby_reduce`` — dense integer key spaces route through
    ``kernels/radix_groupby`` (radix-partitioned one-hot matmul, no sort);
    sparse/non-integer/huge key spaces fall back to the legacy lexsort +
    ``kernels/segment_sum`` route (``REPRO_GROUPBY_IMPL=sort`` forces it;
    ``REPRO_SEGSUM_IMPL=interpret`` exercises the Pallas segment-sum body on
    CPU).  Sums accumulate in float32 — the MXU-native width — so
    engine-vs-oracle checks use ``oracle_rtol`` instead of float64 exactness.
  - ``filter_mask`` / ``eval_expression`` — user lambdas evaluated over a
    device view of the shared cache, so `c.col(...)` hands back jax arrays
    and the whole expression runs on device.
  - ``sort_rows`` — stable ``jnp.lexsort``.

Every host->device / device->host crossing is recorded in
``CacheStats`` (scoped ``record_transfer``) — the copy-cost
analogue of the paper's §3 scheme for the device tier.

Note: x64 stays disabled (jax default), so 64-bit host columns are
canonicalized to 32-bit on device; ``dtype_width`` reports the canonical
width so planner channel sizing matches what actually crosses an edge.
"""
from __future__ import annotations

import threading
import time
import weakref
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ...obs import trace as obs_trace
from .. import config, faults
from ..expr import ColumnsView, Expr
from ..shared_cache import (GLOBAL_ARENA, is_host_column, record_dim_upload,
                            record_segment_compile, record_transfer)
from .base import AGG_OPS, Backend, SegmentEnv


class _DeviceCacheView:
    """Read-only view of a SharedCache whose ``col`` returns device arrays
    (converted+cached on first touch), so user predicates/expressions written
    against the cache API compute on device.  One view is shared across a
    component's §4.3 row-range calls (see ``JaxBackend._view``), so each
    column is uploaded once per cache version, not once per range."""

    __slots__ = ("_backend", "_cache", "_cols", "_lock")

    def __init__(self, backend: "JaxBackend", cache):
        self._backend = backend
        self._cache = cache
        self._cols: Dict[str, object] = {}
        self._lock = threading.Lock()

    @property
    def n(self) -> int:
        return self._cache.n

    @property
    def names(self):
        return self._cache.names

    def col(self, name: str):
        got = self._cols.get(name)
        if got is None:
            with self._lock:       # concurrent row ranges: upload once
                got = self._cols.get(name)
                if got is None:
                    got = self._cols[name] = self._backend.asarray(
                        self._cache.col(name))
        return got

    def __getattr__(self, name):
        # API parity with SharedCache: anything beyond col/n/names
        # (split_index, columns, to_dict, ...) falls back to the underlying
        # cache — host compute, but the numpy-backend contract still holds
        return getattr(self._cache, name)


class JaxBackend(Backend):
    name = "jax"
    #: align chunks to the segment-sum row tile so jitted kernels see few
    #: distinct shapes (bounds retracing) and the Pallas grid has no ragged
    #: final tile in the common case
    batch_align = 512
    #: float32 accumulation (MXU width) vs the float64 oracles
    oracle_rtol = 1e-3
    #: fused row-sync chains may defer their combined keep-mask through a
    #: terminal Aggregate (the per-chunk d2h sync disappears; Aggregate.finish
    #: applies the mask once after the device-side concat)
    supports_segment_defer = True
    #: dense-groupby guards: past either, fall back to the sort route
    #: (float32 counts are exact below 2^24; the dense cell count bounds the
    #: group-id space the radix kernel partitions)
    _DENSE_MAX_ROWS = 1 << 24
    _DENSE_MAX_CELLS = 1 << 20
    #: kernel degradation ladders (left = fastest, right = safest): on a
    #: non-transient kernel failure the route walks ONE rung right and stays
    #: there for this backend instance's lifetime.  Every rung is
    #: bit-identical to its neighbours by the kernels' own equivalence tests.
    _JOIN_LADDER = ("pallas", "interpret", "reference", "searchsorted")
    _GROUPBY_LADDER = ("pallas", "interpret", "reference", "sort")

    def __init__(self) -> None:
        import jax                       # deferred: registry creates lazily
        import jax.numpy as jnp
        from ...kernels.hash_join import hash_build, hash_probe, hash_probe_ref
        from ...kernels.radix_groupby import radix_groupby
        from ...kernels.segment_sum import segment_sum
        self._jax = jax
        self._jnp = jnp
        self._segment_sum = segment_sum
        self._hash_build = hash_build
        self._hash_probe = hash_probe
        self._hash_probe_ref = hash_probe_ref
        self._radix_groupby = radix_groupby
        self._segsum_impl = config.segsum_impl()

        def _probe(keys, qualifies, vals):
            idx = jnp.searchsorted(keys, vals)
            idx = jnp.clip(idx, 0, keys.shape[0] - 1)
            matched = (keys[idx] == vals) & qualifies[idx]
            return idx, matched

        def _gather(payload, idx, matched, default):
            return jnp.where(matched, payload[idx],
                             jnp.asarray(default, payload.dtype))

        self._probe_jit = jax.jit(_probe)
        self._gather_jit = jax.jit(_gather)
        # device views keyed by cache, invalidated by cache.version — a
        # stale view (pre-compact/add_column) is never reused
        self._views: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
        self._views_lock = threading.Lock()
        self._dims_lock = threading.Lock()
        # sticky degradation-ladder routes; None => follow the env config
        self._join_route: Optional[str] = None
        self._groupby_route: Optional[str] = None

    def _degraded_impl(self, kind: str, impl: str, exc: BaseException):
        """Next rung of the ``kind`` kernel ladder after ``impl`` failed with
        ``exc``, or ``None`` when the failure must propagate instead:
        transient faults escalate so chunk-level replay retries the SAME
        route; explicitly injected permanent/poison faults abort promptly;
        ``REPRO_DEGRADE=0`` disables ladders; the ladder floor has no next
        rung.  A chosen rung is recorded as a ``Degradation`` and sticks on
        this backend instance — later chunks skip the broken kernel."""
        if (faults.classify(exc) == "transient"
                or isinstance(exc, (faults.PermanentFault, faults.PoisonFault))
                or not config.degrade_enabled()):
            return None
        ladder = self._JOIN_LADDER if kind == "join" else self._GROUPBY_LADDER
        i = ladder.index(impl) if impl in ladder else 0   # "auto" => rung 0
        if i + 1 >= len(ladder):
            return None
        nxt = ladder[i + 1]
        faults.record_degradation("kernel", src=f"{kind}[{impl}]", dst=nxt,
                                  component=kind, error=repr(exc))
        if kind == "join":
            self._join_route = nxt
        else:
            self._groupby_route = nxt
        return nxt

    def _view(self, cache) -> _DeviceCacheView:
        with self._views_lock:
            got = self._views.get(cache)
            if got is not None and got[0] == cache.version:
                return got[1]
            view = _DeviceCacheView(self, cache)
            self._views[cache] = (cache.version, view)
            return view

    # ------------------------------------------------------------ array ops
    def asarray(self, x):
        if isinstance(x, np.ndarray):
            # copy=True: jax on CPU zero-copies numpy arrays onto the
            # "device", aliasing the host memory — with CacheArena recycling
            # host buffers, an aliased device column would silently observe
            # the next borrower's bytes.  Forcing the copy restores the
            # ownership boundary the h2d accounting already models (real
            # accelerators copy on transfer regardless).
            t0 = time.perf_counter() if obs_trace.ACTIVE.get() else 0.0
            out = self._jnp.array(x, copy=True)
            record_transfer("h2d", x.nbytes,
                            seconds=(time.perf_counter() - t0) if t0 else 0.0)
            return out
        if isinstance(x, self._jax.Array):
            return x
        return self._jnp.asarray(x)

    def to_host(self, x) -> np.ndarray:
        if isinstance(x, np.ndarray):
            return x
        t0 = time.perf_counter() if obs_trace.ACTIVE.get() else 0.0
        out = np.asarray(x)
        record_transfer("d2h", out.nbytes,
                        seconds=(time.perf_counter() - t0) if t0 else 0.0)
        return out

    def concat(self, parts: Sequence):
        parts = list(parts)
        if len(parts) == 1:
            return self.asarray(parts[0])
        return self._jnp.concatenate([self.asarray(p) for p in parts])

    # --------------------------------------------------------------- sizing
    def dtype_width(self, dtype) -> int:
        # x64 disabled => int64/float64 host columns live as 4-byte device
        return int(np.dtype(self._jax.dtypes.canonicalize_dtype(dtype)).itemsize)

    def bucket_rows(self, n: int) -> int:
        """Pad target for a data-dependent row count: ``batch_align`` times
        the next power of two of the needed alignment units.  Keeps the
        number of DISTINCT jit shapes logarithmic in the row-count range —
        linear multiple-of-align bucketing retraces once per distinct chunk
        size, which under a resident serving session with varying tick sizes
        means unbounded warm-tick recompiles."""
        align = max(1, self.batch_align)
        units = max(1, -(-int(n) // align))
        return align * (1 << (units - 1).bit_length())

    # ------------------------------------------------------- dim-table cache
    def _dim_device(self, dim) -> Dict[str, object]:
        """Device-resident mirror of a DimTable, device_put once per table
        (payload columns lazily) and cached on the table itself.  Locked:
        concurrent §4.3 probes of one table must not duplicate uploads (or
        double-count h2d bytes)."""
        dev = dim.__dict__.get("_jax_device_cache")
        if dev is None:
            with self._dims_lock:
                dev = dim.__dict__.get("_jax_device_cache")
                if dev is None:
                    record_dim_upload(dim.keys.nbytes)
                    record_dim_upload(dim.qualifies.nbytes)
                    dev = dim.__dict__["_jax_device_cache"] = {
                        "keys": self.asarray(dim.keys),
                        "qualifies": self.asarray(dim.qualifies),
                        "payload": {},
                    }
        return dev

    def _dim_payload(self, dim, col: str):
        dev = self._dim_device(dim)
        got = dev["payload"].get(col)
        if got is None:
            with self._dims_lock:
                got = dev["payload"].get(col)
                if got is None:
                    record_dim_upload(dim.payload[col].nbytes)
                    got = dev["payload"][col] = self.asarray(dim.payload[col])
        return got

    def _dim_hash(self, dim) -> Dict[str, object]:
        """Open-addressing hash table over the DimTable's keys: built once on
        host (``kernels/hash_join.hash_build``), slot arrays device_put once,
        cached on the table itself like ``_dim_device``.  ``max_probes`` (the
        static probe-loop bound) stays a Python int — it must never become a
        tracer."""
        ht = dim.__dict__.get("_jax_hash_cache")
        if ht is None:
            with self._dims_lock:
                ht = dim.__dict__.get("_jax_hash_cache")
                if ht is None:
                    built = self._hash_build((np.asarray(dim.keys),))
                    for k in built["slot_keys"]:
                        record_dim_upload(np.asarray(k).nbytes)
                    record_dim_upload(np.asarray(built["slot_idx"]).nbytes)
                    ht = dim.__dict__["_jax_hash_cache"] = {
                        "slot_keys": tuple(self.asarray(k)
                                           for k in built["slot_keys"]),
                        "slot_idx": self.asarray(built["slot_idx"]),
                        "max_probes": int(built["max_probes"]),
                    }
        return ht

    # ---------------------------------------------------- DSL expression jit
    def _expr_runner(self, expr: Expr):
        """One jitted XLA computation per DSL expression: the whole AST
        traces into a single compiled kernel over exactly ``expr.columns()``
        device arrays — no host lambda round-trip, no per-op dispatch.  The
        compiled runner is cached on the expression node itself (expressions
        are long-lived component attributes), and jit's trace cache bounds
        retraces per argument shape."""
        got = expr.__dict__.get("_jax_compiled")
        if got is None:
            names = sorted(expr.columns())

            def run(*arrays):
                return expr.evaluate(ColumnsView(dict(zip(names, arrays))),
                                     slice(None))
            got = expr.__dict__["_jax_compiled"] = (names, self._jax.jit(run))
        return got

    def _eval_expr(self, expr: Expr, cache, rows: slice):
        """Run the jitted expression over the requested row range, padded to
        the backend's batch alignment so jit sees bucketed shapes — without
        this, every post-filter chunk (data-dependent length) would force a
        fresh XLA compile.  Safe because DSL ops are row-local: the zeroed
        pad rows are sliced off before anyone observes them."""
        jnp = self._jnp
        names, fn = self._expr_runner(expr)
        view = self._view(cache)
        cols = [view.col(name)[rows] for name in names]
        n = cols[0].shape[0]
        pad = self.bucket_rows(n) - n
        if pad:
            cols = [jnp.concatenate(
                [c, jnp.zeros((pad,) + c.shape[1:], c.dtype)]) for c in cols]
        out = fn(*cols)
        return out[:n] if pad else out

    # ------------------------------------------------------- operator kernels
    def filter_mask(self, predicate: Callable, cache, rows: slice):
        if isinstance(predicate, Expr) and predicate.columns():
            return self._eval_expr(predicate, cache, rows).astype(bool)
        mask = predicate(self._view(cache), rows)
        if isinstance(mask, np.ndarray):
            return mask.astype(bool)       # host-computed mask stays host
        # device array, or any sequence the numpy reference would accept
        return self._jnp.asarray(mask, dtype=bool)

    def eval_expression(self, fn: Callable, cache, rows: slice):
        if isinstance(fn, Expr) and fn.columns():
            return self._eval_expr(fn, cache, rows)
        out = fn(self._view(cache), rows)
        return out if isinstance(out, np.ndarray) else self._jnp.asarray(out)

    def searchsorted_probe(self, dim, vals):
        if len(dim.keys) == 0:
            n = len(vals)
            return (np.zeros(n, dtype=np.int64),
                    np.zeros(n, dtype=bool))
        dev = self._dim_device(dim)
        v = self.asarray(vals)
        n = v.shape[0]
        pad = self.bucket_rows(n) - n          # bound jit retraces per shape
        if pad:
            v = self._jnp.concatenate([v, self._jnp.full((pad,), dim.keys[0],
                                                         dtype=v.dtype)])
        impl = self._join_route or config.join_impl()
        while True:
            try:
                if faults.active():
                    faults.inject("kernel", component=f"join[{impl}]")
                if impl == "searchsorted":
                    idx, matched = self._probe_jit(dev["keys"],
                                                   dev["qualifies"], v)
                else:
                    ht = self._dim_hash(dim)
                    idx, found = self._hash_probe(
                        ht["slot_keys"], ht["slot_idx"], (v,),
                        ht["max_probes"], impl=impl)
                    matched = found & dev["qualifies"][idx]
                break
            except BaseException as e:
                nxt = self._degraded_impl("join", impl, e)
                if nxt is None:
                    raise
                impl = nxt
        return idx[:n], matched[:n]

    def lookup_gather(self, dim, dim_col: str, idx, matched, default):
        payload = self._dim_payload(dim, dim_col)
        return self._gather_jit(payload, idx, matched, default)

    def groupby_reduce(self, keys: Sequence, values: Mapping[str, Tuple[object, str]],
                       n_rows: int) -> Tuple[List[object], Dict[str, object]]:
        for out, (col, op) in values.items():
            if op not in AGG_OPS:
                raise ValueError(f"unknown agg op {op!r} for {out!r}")
        jnp = self._jnp
        n = int(n_rows)
        if not keys:
            aggs: Dict[str, object] = {}
            zeros = jnp.zeros((n,), dtype=jnp.int32)
            for out, (col, op) in values.items():
                if op == "count":
                    aggs[out] = np.array([n], dtype=np.int64)
                    continue
                vals = self.asarray(col)
                if op in ("sum", "avg"):
                    s = self._segment_sum(zeros,
                                          vals.astype(jnp.float32)[:, None],
                                          1, impl=self._segsum_impl)[:, 0]
                    aggs[out] = s / n if op == "avg" else s
                elif op == "min":
                    aggs[out] = jnp.min(vals)[None]
                elif op == "max":
                    aggs[out] = jnp.max(vals)[None]
            return [], aggs
        keys_d = [self.asarray(k) for k in keys]
        impl = self._groupby_route or config.groupby_impl()
        while impl != "sort":
            try:
                if faults.active():
                    faults.inject("kernel", component=f"groupby[{impl}]")
                dense = self._groupby_dense(keys_d, values, n, impl)
            except BaseException as e:
                nxt = self._degraded_impl("groupby", impl, e)
                if nxt is None:
                    raise
                impl = nxt
                continue
            if dense is not None:
                return dense
            break          # key space disqualified: legacy sort route
        order = jnp.lexsort(tuple(keys_d[::-1]))
        sk = [k[order] for k in keys_d]
        boundary = jnp.zeros((n,), dtype=bool).at[0].set(True)
        for k in sk:
            boundary = boundary.at[1:].set(boundary[1:] | (k[1:] != k[:-1]))
        seg = (jnp.cumsum(boundary) - 1).astype(jnp.int32)
        starts_h = np.flatnonzero(self.to_host(boundary))
        n_groups = len(starts_h)
        counts_h = np.diff(np.append(starts_h, n))
        starts = jnp.asarray(starts_h)
        group_cols = [k[starts] for k in sk]
        counts_d = jnp.asarray(counts_h)
        aggs = {}
        for out, (col, op) in values.items():
            if op == "count":
                aggs[out] = counts_h.astype(np.int64)
                continue
            vals = self.asarray(col)[order]
            if op in ("sum", "avg"):
                # the repo's Pallas segment-sum op: one-hot matmul per row
                # tile on TPU, jnp segment_sum reference on CPU
                s = self._segment_sum(seg, vals.astype(jnp.float32)[:, None],
                                      n_groups, impl=self._segsum_impl)[:, 0]
                aggs[out] = s / counts_d if op == "avg" else s
            elif op == "min":
                aggs[out] = self._jax.ops.segment_min(vals, seg,
                                                      num_segments=n_groups)
            elif op == "max":
                aggs[out] = self._jax.ops.segment_max(vals, seg,
                                                      num_segments=n_groups)
        return group_cols, aggs

    def _groupby_dense(self, keys_d: List, values: Mapping[str, Tuple[object, str]],
                       n: int, impl: str):
        """Radix-partitioned groupby over a dense composite key id — no sort.

        Each key column is offset to zero and the tuple is flattened into one
        dense int32 id (FIRST key column most significant, so ascending id
        order IS the lexicographic group order the sort route emits).  All
        sum/avg inputs stack into one [N, C] matrix and reduce in a single
        ``kernels/radix_groupby`` pass that also yields per-group counts;
        occupied cells are recovered from the counts (the only extra d2h) and
        group key columns are reconstructed arithmetically from the cell ids —
        the row data is never sorted and never leaves the device.

        Returns ``None`` when the key space doesn't qualify (empty input,
        non-integer keys, cell count past the VMEM-scaled bound, row count
        past float32-count exactness) — the caller falls back to the sort
        route.
        """
        jnp = self._jnp
        if n == 0 or n >= self._DENSE_MAX_ROWS:
            return None
        for k in keys_d:
            if not jnp.issubdtype(k.dtype, jnp.integer):
                return None
        # one d2h for every column's min/max (stacked into a single transfer)
        lo_hi = self.to_host(jnp.stack(
            [jnp.stack([jnp.min(k), jnp.max(k)]) for k in keys_d]))
        mins = [int(v) for v in lo_hi[:, 0]]
        ranges = [int(hi) - int(lo) + 1 for lo, hi in lo_hi]
        cells = 1
        for r in ranges:
            cells *= r
            if cells > self._DENSE_MAX_CELLS:
                return None
        strides = [1] * len(keys_d)
        for i in range(len(keys_d) - 2, -1, -1):
            strides[i] = strides[i + 1] * ranges[i + 1]
        ids = jnp.zeros((n,), jnp.int32)
        for k, mn, st in zip(keys_d, mins, strides):
            ids = ids + (k.astype(jnp.int32) - mn) * st

        sum_outs = [out for out, (_, op) in values.items()
                    if op in ("sum", "avg")]
        mat = [self.asarray(values[out][0]).astype(jnp.float32)
               for out in sum_outs]
        vmat = (jnp.stack(mat, axis=1) if mat
                else jnp.zeros((n, 0), jnp.float32))
        sums, counts = self._radix_groupby(ids, vmat, cells, impl=impl)
        counts_h = np.rint(self.to_host(counts)).astype(np.int64)  # one d2h
        occ = np.flatnonzero(counts_h)
        occ_d = jnp.asarray(occ.astype(np.int32))
        group_cols = [((occ_d // st) % rg + mn).astype(k.dtype)
                      for k, mn, st, rg in zip(keys_d, mins, strides, ranges)]
        counts_d = jnp.asarray(counts_h[occ])
        aggs: Dict[str, object] = {}
        for out, (col, op) in values.items():
            if op == "count":
                aggs[out] = counts_h[occ]
            elif op in ("sum", "avg"):
                s = sums[occ_d, sum_outs.index(out)]
                aggs[out] = s / counts_d if op == "avg" else s
            else:  # min / max: one segment reduce over the dense ids
                fn = (self._jax.ops.segment_min if op == "min"
                      else self._jax.ops.segment_max)
                aggs[out] = fn(self.asarray(col), ids,
                               num_segments=cells)[occ_d]
        return group_cols, aggs

    def sort_rows(self, keys: Sequence, ascending: bool = True):
        order = self._jnp.lexsort(tuple(self.asarray(k) for k in keys)[::-1])
        return order if ascending else order[::-1]

    # ------------------------------------------------------- segment fusion
    def compile_segment(self, segment) -> Callable:
        """One jitted kernel for the whole row-synchronized segment: the
        needed host input columns are packed into a single staging buffer
        (ONE h2d per chunk), every fused op runs on device inside one XLA
        computation with the filter masks deferred to a single combined
        keep-mask (the only d2h per chunk), and the produced columns stay
        device-resident for downstream consumers.  Tracing is bounded by a
        compile cache keyed on the packed layout (column names x canonical
        dtypes x padded chunk-size bucket) — jit's own trace cache keys on
        exactly that layout, so steady-state chunks replay a compiled
        executable with zero retracing."""
        return _JaxSegmentRunner(self, segment)


class _JaxSegmentRunner:
    """Compiled executor for one FusedSegment on the jax backend.

    Deferred-mask semantics: row-synchronized ops are row-local by the
    paper's §3 classification (each output row depends only on its own input
    row), so filters are evaluated as masks over the full padded chunk, ANDed
    into one keep-mask, and applied once at write-back — values of surviving
    rows are identical to the eagerly-compacted unfused chain."""

    def __init__(self, backend: "JaxBackend", segment):
        from .base import segment_final_live, segment_written_columns
        self._bk = backend
        self._jnp = backend._jnp
        self._jax = backend._jax
        self.ops = list(segment.ops)
        #: external columns the kernel needs uploaded; None => every cache
        #: column (some op has an undeclared read set)
        self.inputs = segment.kernel_input_columns()
        self._written = segment_written_columns(self.ops)
        self._final_live = segment_final_live
        #: mask deferral: when the optimizer fused this chain through its
        #: terminal Aggregate, skip the per-chunk compact (the chunk's only
        #: d2h) and hand the keep-mask downstream as a sentinel column
        self.defer_mask = bool(getattr(segment, "defer_cols", None))
        #: Lookup route inside the fused kernel: hash-probe (traced inline
        #: via hash_probe_ref — it fuses into the one XLA computation) unless
        #: pinned back to the legacy binary search
        self._join_impl = config.join_impl()
        self._max_probes: List[int] = []   # python-side: static loop bounds
        self._jit = backend._jax.jit(self._kernel, static_argnums=(0,))
        self._layouts: set = set()
        self._dims = None            # built once: stable per (segment, backend)
        self.kernel_calls = 0

    # ----------------------------------------------------------- the kernel
    def _kernel(self, layout, packed, dev_cols, dims):
        jnp = self._jnp
        bucket, entries = layout
        env: Dict[str, object] = {}
        for (name, dtype_str, off) in entries:
            dt = np.dtype(dtype_str)
            nb = bucket * dt.itemsize
            raw = packed[off:off + nb]
            if dt == np.bool_:
                env[name] = raw != 0
            elif dt.itemsize == 1:
                env[name] = self._jax.lax.bitcast_convert_type(raw, dt)
            else:
                env[name] = self._jax.lax.bitcast_convert_type(
                    raw.reshape(bucket, dt.itemsize), dt)
        env.update(dev_cols)

        masks = []
        dim_i = 0
        rows = slice(None)
        for op in self.ops:
            view = SegmentEnv(env.__getitem__, set(env), bucket)
            kind = op[0]
            if kind == "filter":
                masks.append(jnp.asarray(op[1](view, rows), dtype=bool))
            elif kind == "expr":
                env[op[1]] = jnp.asarray(op[2](view, rows))
            elif kind == "lookup":
                _, dim, key_col, return_cols, default, matched_flag = op
                d = dims[dim_i]
                max_probes = self._max_probes[dim_i]  # static (never traced)
                dim_i += 1
                vals = env[key_col]
                keys = d["keys"]
                if keys.shape[0] == 0:        # static: degenerate dim table
                    matched = jnp.zeros(vals.shape[0], dtype=bool)
                    for out_name, dim_col in return_cols.items():
                        env[out_name] = jnp.full(
                            vals.shape[0], default,
                            d["payload"][dim_col].dtype)
                else:
                    if max_probes:
                        # hash-probe route, traced inline so the open-
                        # addressing loop fuses into this one XLA computation
                        idx, found = self._bk._hash_probe_ref(
                            d["slot_keys"], d["slot_idx"], (vals,),
                            max_probes)
                        matched = found & d["qualifies"][idx]
                    else:
                        idx = jnp.clip(jnp.searchsorted(keys, vals),
                                       0, keys.shape[0] - 1)
                        matched = (keys[idx] == vals) & d["qualifies"][idx]
                    for out_name, dim_col in return_cols.items():
                        payload = d["payload"][dim_col]
                        env[out_name] = jnp.where(
                            matched, payload[idx],
                            jnp.asarray(default, payload.dtype))
                if matched_flag:
                    env[matched_flag] = matched
            elif kind == "project":
                keep = set(op[1])
                for k in list(env):
                    if k not in keep:
                        del env[k]
            elif kind == "convert":
                for col, dt in op[1].items():
                    env[col] = env[col].astype(dt)
            else:  # pragma: no cover
                raise ValueError(f"unknown segment op kind {kind!r}")

        keep_mask = None
        for m in masks:
            keep_mask = m if keep_mask is None else (keep_mask & m)
        out = {name: env[name] for name in self._written if name in env}
        return out, keep_mask

    # ------------------------------------------------------------ execution
    def __call__(self, cache) -> None:
        bk = self._bk
        jnp = self._jnp
        n = cache.n
        bucket = bk.bucket_rows(n)

        names = (sorted(self.inputs) if self.inputs is not None
                 else sorted(cache.names))
        packable = []              # 1-D host columns -> one staging buffer
        dev_cols: Dict[str, object] = {}
        for name in names:
            v = cache.col(name)
            if is_host_column(v) and v.ndim == 1:
                packable.append((name, v))
            else:
                # device-resident (or multi-dim host) input: pad to the
                # bucket on device so the kernel sees one shape per layout
                dev = bk.asarray(np.ascontiguousarray(v)
                                 if is_host_column(v) else v)
                pad = bucket - n
                if pad:
                    dev = jnp.concatenate(
                        [dev, jnp.zeros((pad,) + dev.shape[1:], dev.dtype)])
                dev_cols[name] = dev

        # pack every 1-D host input into ONE staging buffer (canonical
        # device dtypes, zeroed pad tail) and upload it with a single h2d
        entries = []
        off = 0
        for name, v in packable:
            cd = np.dtype(self._jax.dtypes.canonicalize_dtype(v.dtype))
            entries.append((name, cd.str, off))
            off += bucket * cd.itemsize
        total = off
        if total:
            staging, root = GLOBAL_ARENA.acquire(np.uint8, (total,))
            for (name, v), (_, dtype_str, off) in zip(packable, entries):
                cd = np.dtype(dtype_str)
                dst = staging[off:off + bucket * cd.itemsize].view(cd)
                np.copyto(dst[:n], v, casting="same_kind")
                dst[n:] = 0
            # copy=True + block: the device buffer must not alias the
            # staging memory, which goes straight back to the arena
            t0 = time.perf_counter() if obs_trace.ACTIVE.get() else 0.0
            packed = jnp.array(staging, copy=True)
            packed.block_until_ready()
            record_transfer("h2d", total,
                            seconds=(time.perf_counter() - t0) if t0 else 0.0)
            GLOBAL_ARENA.release(root)
        else:
            packed = jnp.zeros((0,), np.uint8)

        if self._dims is None:
            # device mirrors of every looked-up DimTable — uploaded once per
            # table (cached on the table), structurally identical per call,
            # so building the pytree once keeps per-chunk Python cost flat
            dims = []
            max_probes = []
            for op in self.ops:
                if op[0] == "lookup":
                    _, dim, _, return_cols, _, _ = op
                    dev = bk._dim_device(dim)
                    entry = {
                        "keys": dev["keys"],
                        "qualifies": dev["qualifies"],
                        "payload": {dcol: bk._dim_payload(dim, dcol)
                                    for dcol in return_cols.values()},
                    }
                    if (self._join_impl != "searchsorted"
                            and len(dim.keys) > 0):
                        ht = bk._dim_hash(dim)
                        entry["slot_keys"] = ht["slot_keys"]
                        entry["slot_idx"] = ht["slot_idx"]
                        max_probes.append(ht["max_probes"])
                    else:
                        max_probes.append(0)   # 0 => legacy searchsorted
                    dims.append(entry)
            self._max_probes = max_probes
            self._dims = dims

        layout = (bucket, tuple(entries))
        if layout not in self._layouts:
            # a layout never seen by this runner => the jit call below traces
            # and compiles a fresh executable for it
            self._layouts.add(layout)
            record_segment_compile()
        out_cols, keep_mask = self._jit(layout, packed, dev_cols, self._dims)
        self.kernel_calls += 1

        final_live = self._final_live(self.ops, cache.names)
        for name in self._written:
            if name in out_cols and name in final_live:
                cache.add_column(name, out_cols[name][:n])
        if self.defer_mask:
            # fused-through-Aggregate: the per-chunk compact (this chunk's
            # ONLY d2h) is deferred — the keep-mask rides along as a device
            # sentinel column and Aggregate.finish applies it once to the
            # merged cache
            from .base import SEGMENT_KEEP_MASK
            if keep_mask is not None:
                cache.add_column(SEGMENT_KEEP_MASK, keep_mask[:n])
                final_live = final_live | {SEGMENT_KEEP_MASK}
            if final_live != set(cache.names):
                cache.keep_columns(
                    [k for k in cache.names if k in final_live])
            return
        if keep_mask is not None:
            cache.compact(keep_mask[:n])
        if final_live != set(cache.names):
            cache.keep_columns([k for k in cache.names if k in final_live])

    def stats(self) -> Dict[str, int]:
        return {"kernel_calls": self.kernel_calls,
                "layouts": len(self._layouts)}
