"""Accelerated operator backend: jitted JAX kernels + device-resident columns.

Kernels:
  - ``searchsorted_probe`` / ``lookup_gather`` — jitted probe over a
    device-cached dimension table (keys/qualifies/payload are device_put once
    per table and reused across every chunk).
  - ``groupby_reduce`` — routed through the repo's ``kernels/segment_sum``
    Pallas op (MXU one-hot matmul on TPU, jnp reference elsewhere; set
    ``REPRO_SEGSUM_IMPL=interpret`` to exercise the Pallas kernel body on
    CPU).  Sums accumulate in float32 — the MXU-native width — so
    engine-vs-oracle checks use ``oracle_rtol`` instead of float64 exactness.
  - ``filter_mask`` / ``eval_expression`` — user lambdas evaluated over a
    device view of the shared cache, so `c.col(...)` hands back jax arrays
    and the whole expression runs on device.
  - ``sort_rows`` — stable ``jnp.lexsort``.

Every host->device / device->host crossing is recorded in
``CacheStats`` (``GLOBAL_CACHE_STATS.record_transfer``) — the copy-cost
analogue of the paper's §3 scheme for the device tier.

Note: x64 stays disabled (jax default), so 64-bit host columns are
canonicalized to 32-bit on device; ``dtype_width`` reports the canonical
width so planner channel sizing matches what actually crosses an edge.
"""
from __future__ import annotations

import os
import threading
import weakref
from typing import Callable, Dict, List, Mapping, Sequence, Tuple

import numpy as np

from ..shared_cache import GLOBAL_CACHE_STATS
from .base import AGG_OPS, Backend


class _DeviceCacheView:
    """Read-only view of a SharedCache whose ``col`` returns device arrays
    (converted+cached on first touch), so user predicates/expressions written
    against the cache API compute on device.  One view is shared across a
    component's §4.3 row-range calls (see ``JaxBackend._view``), so each
    column is uploaded once per cache version, not once per range."""

    __slots__ = ("_backend", "_cache", "_cols", "_lock")

    def __init__(self, backend: "JaxBackend", cache):
        self._backend = backend
        self._cache = cache
        self._cols: Dict[str, object] = {}
        self._lock = threading.Lock()

    @property
    def n(self) -> int:
        return self._cache.n

    @property
    def names(self):
        return self._cache.names

    def col(self, name: str):
        got = self._cols.get(name)
        if got is None:
            with self._lock:       # concurrent row ranges: upload once
                got = self._cols.get(name)
                if got is None:
                    got = self._cols[name] = self._backend.asarray(
                        self._cache.col(name))
        return got

    def __getattr__(self, name):
        # API parity with SharedCache: anything beyond col/n/names
        # (split_index, columns, to_dict, ...) falls back to the underlying
        # cache — host compute, but the numpy-backend contract still holds
        return getattr(self._cache, name)


class JaxBackend(Backend):
    name = "jax"
    #: align chunks to the segment-sum row tile so jitted kernels see few
    #: distinct shapes (bounds retracing) and the Pallas grid has no ragged
    #: final tile in the common case
    batch_align = 512
    #: float32 accumulation (MXU width) vs the float64 oracles
    oracle_rtol = 1e-3

    def __init__(self) -> None:
        import jax                       # deferred: registry creates lazily
        import jax.numpy as jnp
        from ...kernels.segment_sum import segment_sum
        self._jax = jax
        self._jnp = jnp
        self._segment_sum = segment_sum
        self._segsum_impl = os.environ.get("REPRO_SEGSUM_IMPL", "auto")

        def _probe(keys, qualifies, vals):
            idx = jnp.searchsorted(keys, vals)
            idx = jnp.clip(idx, 0, keys.shape[0] - 1)
            matched = (keys[idx] == vals) & qualifies[idx]
            return idx, matched

        def _gather(payload, idx, matched, default):
            return jnp.where(matched, payload[idx],
                             jnp.asarray(default, payload.dtype))

        self._probe_jit = jax.jit(_probe)
        self._gather_jit = jax.jit(_gather)
        # device views keyed by cache, invalidated by cache.version — a
        # stale view (pre-compact/add_column) is never reused
        self._views: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
        self._views_lock = threading.Lock()
        self._dims_lock = threading.Lock()

    def _view(self, cache) -> _DeviceCacheView:
        with self._views_lock:
            got = self._views.get(cache)
            if got is not None and got[0] == cache.version:
                return got[1]
            view = _DeviceCacheView(self, cache)
            self._views[cache] = (cache.version, view)
            return view

    # ------------------------------------------------------------ array ops
    def asarray(self, x):
        if isinstance(x, np.ndarray):
            out = self._jnp.asarray(x)
            GLOBAL_CACHE_STATS.record_transfer("h2d", x.nbytes)
            return out
        if isinstance(x, self._jax.Array):
            return x
        return self._jnp.asarray(x)

    def to_host(self, x) -> np.ndarray:
        if isinstance(x, np.ndarray):
            return x
        out = np.asarray(x)
        GLOBAL_CACHE_STATS.record_transfer("d2h", out.nbytes)
        return out

    def concat(self, parts: Sequence):
        parts = list(parts)
        if len(parts) == 1:
            return self.asarray(parts[0])
        return self._jnp.concatenate([self.asarray(p) for p in parts])

    # --------------------------------------------------------------- sizing
    def dtype_width(self, dtype) -> int:
        # x64 disabled => int64/float64 host columns live as 4-byte device
        return int(np.dtype(self._jax.dtypes.canonicalize_dtype(dtype)).itemsize)

    # ------------------------------------------------------- dim-table cache
    def _dim_device(self, dim) -> Dict[str, object]:
        """Device-resident mirror of a DimTable, device_put once per table
        (payload columns lazily) and cached on the table itself.  Locked:
        concurrent §4.3 probes of one table must not duplicate uploads (or
        double-count h2d bytes)."""
        dev = dim.__dict__.get("_jax_device_cache")
        if dev is None:
            with self._dims_lock:
                dev = dim.__dict__.get("_jax_device_cache")
                if dev is None:
                    dev = dim.__dict__["_jax_device_cache"] = {
                        "keys": self.asarray(dim.keys),
                        "qualifies": self.asarray(dim.qualifies),
                        "payload": {},
                    }
        return dev

    def _dim_payload(self, dim, col: str):
        dev = self._dim_device(dim)
        got = dev["payload"].get(col)
        if got is None:
            with self._dims_lock:
                got = dev["payload"].get(col)
                if got is None:
                    got = dev["payload"][col] = self.asarray(dim.payload[col])
        return got

    # ------------------------------------------------------- operator kernels
    def filter_mask(self, predicate: Callable, cache, rows: slice):
        mask = predicate(self._view(cache), rows)
        if isinstance(mask, np.ndarray):
            return mask.astype(bool)       # host-computed mask stays host
        # device array, or any sequence the numpy reference would accept
        return self._jnp.asarray(mask, dtype=bool)

    def eval_expression(self, fn: Callable, cache, rows: slice):
        out = fn(self._view(cache), rows)
        return out if isinstance(out, np.ndarray) else self._jnp.asarray(out)

    def searchsorted_probe(self, dim, vals):
        if len(dim.keys) == 0:
            n = len(vals)
            return (np.zeros(n, dtype=np.int64),
                    np.zeros(n, dtype=bool))
        dev = self._dim_device(dim)
        v = self.asarray(vals)
        n = v.shape[0]
        pad = (-n) % self.batch_align          # bound jit retraces per shape
        if pad:
            v = self._jnp.concatenate([v, self._jnp.full((pad,), dim.keys[0],
                                                         dtype=v.dtype)])
        idx, matched = self._probe_jit(dev["keys"], dev["qualifies"], v)
        return idx[:n], matched[:n]

    def lookup_gather(self, dim, dim_col: str, idx, matched, default):
        payload = self._dim_payload(dim, dim_col)
        return self._gather_jit(payload, idx, matched, default)

    def groupby_reduce(self, keys: Sequence, values: Mapping[str, Tuple[object, str]],
                       n_rows: int) -> Tuple[List[object], Dict[str, object]]:
        for out, (col, op) in values.items():
            if op not in AGG_OPS:
                raise ValueError(f"unknown agg op {op!r} for {out!r}")
        jnp = self._jnp
        n = int(n_rows)
        if not keys:
            aggs: Dict[str, object] = {}
            zeros = jnp.zeros((n,), dtype=jnp.int32)
            for out, (col, op) in values.items():
                if op == "count":
                    aggs[out] = np.array([n], dtype=np.int64)
                    continue
                vals = self.asarray(col)
                if op in ("sum", "avg"):
                    s = self._segment_sum(zeros,
                                          vals.astype(jnp.float32)[:, None],
                                          1, impl=self._segsum_impl)[:, 0]
                    aggs[out] = s / n if op == "avg" else s
                elif op == "min":
                    aggs[out] = jnp.min(vals)[None]
                elif op == "max":
                    aggs[out] = jnp.max(vals)[None]
            return [], aggs
        keys_d = [self.asarray(k) for k in keys]
        order = jnp.lexsort(tuple(keys_d[::-1]))
        sk = [k[order] for k in keys_d]
        boundary = jnp.zeros((n,), dtype=bool).at[0].set(True)
        for k in sk:
            boundary = boundary.at[1:].set(boundary[1:] | (k[1:] != k[:-1]))
        seg = (jnp.cumsum(boundary) - 1).astype(jnp.int32)
        starts_h = np.flatnonzero(self.to_host(boundary))
        n_groups = len(starts_h)
        counts_h = np.diff(np.append(starts_h, n))
        starts = jnp.asarray(starts_h)
        group_cols = [k[starts] for k in sk]
        counts_d = jnp.asarray(counts_h)
        aggs = {}
        for out, (col, op) in values.items():
            if op == "count":
                aggs[out] = counts_h.astype(np.int64)
                continue
            vals = self.asarray(col)[order]
            if op in ("sum", "avg"):
                # the repo's Pallas segment-sum op: one-hot matmul per row
                # tile on TPU, jnp segment_sum reference on CPU
                s = self._segment_sum(seg, vals.astype(jnp.float32)[:, None],
                                      n_groups, impl=self._segsum_impl)[:, 0]
                aggs[out] = s / counts_d if op == "avg" else s
            elif op == "min":
                aggs[out] = self._jax.ops.segment_min(vals, seg,
                                                      num_segments=n_groups)
            elif op == "max":
                aggs[out] = self._jax.ops.segment_max(vals, seg,
                                                      num_segments=n_groups)
        return group_cols, aggs

    def sort_rows(self, keys: Sequence, ascending: bool = True):
        order = self._jnp.lexsort(tuple(self.asarray(k) for k in keys)[::-1])
        return order if ascending else order[::-1]
