"""Typed runtime configuration — every ``REPRO_*`` environment variable in
one place.

Historically each subsystem parsed its own environment variable at the point
of use (backend registry, engine fusion switch, cache arena, debug guard,
test harness).  This module is the single source of truth: one constant per
variable, one typed accessor per setting, and a ``snapshot()`` the metadata
store and benchmark JSON can record so a run's configuration is
reconstructable.

Accessors read the environment on every call (they are cheap), so tests can
``monkeypatch.setenv`` without cache invalidation, and a long-lived process
picks up changes the same way the historical inline ``os.environ`` reads
did.

Every setting also has a first-class API equivalent (see the README table):

    REPRO_BACKEND        OptimizeOptions(backend=...) / Session(backend=...)
    REPRO_FUSION         OptimizeOptions(fuse_segments=...)
    REPRO_ARENA          CacheArena(enabled=...)
    REPRO_ARENA_MAX_MB   CacheArena(max_bytes=...)
    REPRO_CACHE_GUARD    debug only (split-overlap checks + buffer poisoning)
    REPRO_SEGSUM_IMPL    kernels.segment_sum(impl=...)
    REPRO_JOIN_IMPL      kernels.hash_join probe route in JaxBackend lookups
    REPRO_GROUPBY_IMPL   kernels.radix_groupby route in JaxBackend groupbys
    REPRO_OPTEQ_EXAMPLES test harness scale (property-based equivalence)
    REPRO_FLOW_STYLE     etl.queries builders' use_dsl= argument
    REPRO_TRACE          repro.obs.trace.trace_scope() (explicit scoping)
    REPRO_TRACE_PATH     repro.obs.trace.export_run() target path
    REPRO_FAULTS         core.faults.fault_scope(FaultPlan.parse(...))
    REPRO_RETRY_MAX      core.faults.retry_call(max_retries=...)
    REPRO_RETRY_BACKOFF  core.faults.retry_call(backoff=...)
    REPRO_DEGRADE        debug only (disables the degradation ladders)
    REPRO_SHARDS         OptimizeOptions(shards=...) / Session.run(shards=...)
    REPRO_SHARD_IMPL     OptimizeOptions(shard_impl=...)
"""
from __future__ import annotations

import os
from typing import Dict, Optional

#: operator backend for the heavy component kernels ("numpy" / "jax")
ENV_BACKEND = "REPRO_BACKEND"
#: "1" turns segment fusion on when OptimizeOptions.fuse_segments is unset
ENV_FUSION = "REPRO_FUSION"
#: "0" disables the CacheArena buffer pool
ENV_ARENA = "REPRO_ARENA"
#: cap on pooled arena bytes, in MB
ENV_ARENA_MAX_MB = "REPRO_ARENA_MAX_MB"
#: "1" enables split-overlap checks + 0xAB buffer poisoning (debug mode)
ENV_CACHE_GUARD = "REPRO_CACHE_GUARD"
#: example count for the property-based flow-equivalence harness
ENV_OPTEQ_EXAMPLES = "REPRO_OPTEQ_EXAMPLES"
#: segment-sum kernel implementation selector ("auto" / "pallas" /
#: "interpret" / "reference")
ENV_SEGSUM_IMPL = "REPRO_SEGSUM_IMPL"
#: Lookup probe route on the jax backend: hash-join kernel impls ("auto" /
#: "pallas" / "interpret" / "reference") or "searchsorted" (legacy
#: binary-search probe over the sorted DimTable)
ENV_JOIN_IMPL = "REPRO_JOIN_IMPL"
#: groupby route on the jax backend: radix-groupby kernel impls ("auto" /
#: "pallas" / "interpret" / "reference") or "sort" (legacy lexsort +
#: segment-sum route; also the automatic fallback for sparse/non-integer
#: key spaces)
ENV_GROUPBY_IMPL = "REPRO_GROUPBY_IMPL"
#: how the SSB query builders construct predicates/expressions:
#: "dsl" (column-expression AST, exact provenance) or "lambda" (the legacy
#: callable path, kept for A/B benchmarking)
ENV_FLOW_STYLE = "REPRO_FLOW_STYLE"
#: "1" enables per-run structured tracing (repro.obs): engines open a
#: tracer scope, record spans/metrics, and export a Perfetto-loadable
#: Chrome-trace JSON file
ENV_TRACE = "REPRO_TRACE"
#: path of the exported trace file (default "repro_trace.json"); one file
#: accumulates every traced run of the process as its own Perfetto process
ENV_TRACE_PATH = "REPRO_TRACE_PATH"
#: cap on buffered trace events — per tracer AND across the runs the trace
#: file retains; oldest events/runs rotate out so a resident serving session
#: stays bounded (0 disables the cap)
ENV_TRACE_MAX_EVENTS = "REPRO_TRACE_MAX_EVENTS"
#: "0" relaxes the serving watermark contract from strict (a regressing
#: watermark raises) to clamping (a regressing watermark is lifted to the
#: session high-water mark)
ENV_SERVE_STRICT_WATERMARK = "REPRO_SERVE_STRICT_WATERMARK"
#: number of recent per-tick wall times a ServeSession retains for its
#: closing p50/p99 summary
ENV_SERVE_HISTORY = "REPRO_SERVE_HISTORY"
#: deterministic fault-injection plan for the whole process, in the
#: ``core.faults`` rule grammar (e.g. "seed=7;chunk:count=2;kernel:count=1");
#: unset => no injection
ENV_FAULTS = "REPRO_FAULTS"
#: max retries for a transient failure (chunk replay, run re-execution,
#: serve-tick retry) before it escalates; 0 disables retrying
ENV_RETRY_MAX = "REPRO_RETRY_MAX"
#: initial retry backoff in seconds (doubles per attempt, capped at
#: ``core.faults.RETRY_BACKOFF_CAP_S``)
ENV_RETRY_BACKOFF = "REPRO_RETRY_BACKOFF"
#: "0" disables the graceful-degradation ladders (failing kernels/segments
#: then abort instead of falling back to slower routes)
ENV_DEGRADE = "REPRO_DEGRADE"
#: shard count for the OptimizedEngine/StreamingEngine sharded-execution
#: route when ``OptimizeOptions.shards`` is unset: 1 (default) runs the
#: serial path, N>1 hash/range-partitions sources across N shards, 0 lets
#: the ShardPlanner choose from calibration stats
ENV_SHARDS = "REPRO_SHARDS"
#: sharded-execution implementation route: "auto" (mesh when the backend is
#: jax, else inline), "process" (spawned worker processes running pickled
#: per-shard flows), "mesh" (jax ``shard_map`` partial merge over a
#: data-axis host mesh), or "inline" (sequential in-process shard passes —
#: the always-available correctness route)
ENV_SHARD_IMPL = "REPRO_SHARD_IMPL"

DEFAULT_TRACE_PATH = "repro_trace.json"
DEFAULT_TRACE_MAX_EVENTS = 200_000
DEFAULT_SERVE_HISTORY = 4096
DEFAULT_RETRY_MAX = 3
DEFAULT_RETRY_BACKOFF_S = 0.05
#: bound on a ServeSession's dead-letter buffer (oldest entries drop)
DEAD_LETTER_MAX = 256

DEFAULT_ARENA_MAX_MB = 256
DEFAULT_OPTEQ_EXAMPLES = 100
FLOW_STYLES = ("dsl", "lambda")
JOIN_IMPLS = ("auto", "pallas", "interpret", "reference", "searchsorted")
GROUPBY_IMPLS = ("auto", "pallas", "interpret", "reference", "sort")
SHARD_IMPLS = ("auto", "process", "mesh", "inline")


def _raw(name: str) -> Optional[str]:
    v = os.environ.get(name)
    if v is None:
        return None
    v = v.strip()
    return v or None


# ---------------------------------------------------------------------------
#  Typed accessors
# ---------------------------------------------------------------------------
def backend_name() -> Optional[str]:
    """Process-default operator backend name, or ``None`` when unset (the
    registry then falls back to its builtin default)."""
    return _raw(ENV_BACKEND)


def fusion_default() -> bool:
    """Segment-fusion default when ``OptimizeOptions.fuse_segments`` is left
    unset (``REPRO_FUSION=1`` => on)."""
    return _raw(ENV_FUSION) == "1"


def arena_enabled() -> bool:
    """CacheArena pooling switch (``REPRO_ARENA=0`` => off)."""
    return _raw(ENV_ARENA) != "0"


def arena_max_bytes() -> int:
    """Cap on pooled arena bytes (``REPRO_ARENA_MAX_MB``, default 256 MB)."""
    v = _raw(ENV_ARENA_MAX_MB)
    mb = int(v) if v is not None else DEFAULT_ARENA_MAX_MB
    return mb << 20


def cache_guard_enabled() -> bool:
    """Debug mode: split-overlap checks + poisoned arena releases
    (``REPRO_CACHE_GUARD=1``)."""
    return _raw(ENV_CACHE_GUARD) == "1"


def opteq_examples(default: int = DEFAULT_OPTEQ_EXAMPLES) -> int:
    """Example count per property in the flow-equivalence harness."""
    v = _raw(ENV_OPTEQ_EXAMPLES)
    return int(v) if v is not None else int(default)


def segsum_impl() -> str:
    """Implementation selector for the segment-sum kernel."""
    return _raw(ENV_SEGSUM_IMPL) or "auto"


def join_impl() -> str:
    """Lookup probe route on the jax backend: a hash-join kernel impl or
    "searchsorted" for the legacy binary-search probe."""
    v = _raw(ENV_JOIN_IMPL) or "auto"
    if v not in JOIN_IMPLS:
        raise ValueError(
            f"{ENV_JOIN_IMPL}={v!r} is not a valid join impl; "
            f"expected one of {JOIN_IMPLS}")
    return v


def groupby_impl() -> str:
    """Groupby route on the jax backend: a radix-groupby kernel impl or
    "sort" for the legacy lexsort + segment-sum route."""
    v = _raw(ENV_GROUPBY_IMPL) or "auto"
    if v not in GROUPBY_IMPLS:
        raise ValueError(
            f"{ENV_GROUPBY_IMPL}={v!r} is not a valid groupby impl; "
            f"expected one of {GROUPBY_IMPLS}")
    return v


def flow_style() -> str:
    """How the SSB query builders construct predicates/expressions when the
    caller does not pass ``use_dsl=`` explicitly: "dsl" (default) or
    "lambda"."""
    v = _raw(ENV_FLOW_STYLE) or "dsl"
    if v not in FLOW_STYLES:
        raise ValueError(
            f"{ENV_FLOW_STYLE}={v!r} is not a valid flow style; "
            f"expected one of {FLOW_STYLES}")
    return v


def trace_enabled() -> bool:
    """Per-run structured tracing + trace-file export (``REPRO_TRACE=1``).
    An explicitly opened ``repro.obs.trace.trace_scope`` records regardless;
    this switch additionally makes every engine run open its own scope and
    write ``trace_path()``."""
    return _raw(ENV_TRACE) == "1"


def trace_path() -> str:
    """Export path for the Chrome-trace/Perfetto JSON file
    (``REPRO_TRACE_PATH``, default ``repro_trace.json``)."""
    return _raw(ENV_TRACE_PATH) or DEFAULT_TRACE_PATH


def trace_max_events() -> int:
    """Trace-event retention cap (``REPRO_TRACE_MAX_EVENTS``, default
    200000; 0 disables rotation).  Applies per tracer and to the total the
    process trace file keeps across runs."""
    v = _raw(ENV_TRACE_MAX_EVENTS)
    n = int(v) if v is not None else DEFAULT_TRACE_MAX_EVENTS
    return max(0, n)


def serve_strict_watermark() -> bool:
    """Serving watermark contract: strict (default — a tick whose watermark
    regresses below the session high-water mark raises) or clamping
    (``REPRO_SERVE_STRICT_WATERMARK=0`` — regressions are lifted to the
    high-water mark)."""
    return _raw(ENV_SERVE_STRICT_WATERMARK) != "0"


def serve_history() -> int:
    """Per-tick wall-time samples a ServeSession retains for its closing
    p50/p99 summary (``REPRO_SERVE_HISTORY``, default 4096)."""
    v = _raw(ENV_SERVE_HISTORY)
    n = int(v) if v is not None else DEFAULT_SERVE_HISTORY
    return max(1, n)


def faults_spec() -> Optional[str]:
    """The process-wide fault-injection plan spec (``REPRO_FAULTS``), or
    ``None`` when no injection is configured."""
    return _raw(ENV_FAULTS)


def retry_max() -> int:
    """Max transient-failure retries per recovery site
    (``REPRO_RETRY_MAX``, default 3; 0 disables retrying)."""
    v = _raw(ENV_RETRY_MAX)
    n = int(v) if v is not None else DEFAULT_RETRY_MAX
    return max(0, n)


def retry_backoff() -> float:
    """Initial retry backoff seconds (``REPRO_RETRY_BACKOFF``, default
    0.05; doubles per attempt up to the cap)."""
    v = _raw(ENV_RETRY_BACKOFF)
    s = float(v) if v is not None else DEFAULT_RETRY_BACKOFF_S
    return max(0.0, s)


def degrade_enabled() -> bool:
    """Graceful-degradation ladders switch (``REPRO_DEGRADE=0`` => off:
    failing kernel routes abort instead of falling back)."""
    return _raw(ENV_DEGRADE) != "0"


def shards() -> int:
    """Shard count when ``OptimizeOptions.shards`` is unset
    (``REPRO_SHARDS``, default 1 = serial; 0 = planner-chosen)."""
    v = _raw(ENV_SHARDS)
    n = int(v) if v is not None else 1
    if n < 0:
        raise ValueError(f"{ENV_SHARDS}={v!r} must be >= 0")
    return n


def shard_impl() -> str:
    """Sharded-execution route when ``OptimizeOptions.shard_impl`` is unset
    (``REPRO_SHARD_IMPL``, default "auto")."""
    v = _raw(ENV_SHARD_IMPL) or "auto"
    if v not in SHARD_IMPLS:
        raise ValueError(
            f"{ENV_SHARD_IMPL}={v!r} is not a valid shard impl; "
            f"expected one of {SHARD_IMPLS}")
    return v


def snapshot() -> Dict[str, object]:
    """Every setting's effective value — recorded in benchmark JSON so a
    run's configuration is reconstructable."""
    return {
        "backend": backend_name(),
        "fusion": fusion_default(),
        "arena": arena_enabled(),
        "arena_max_bytes": arena_max_bytes(),
        "cache_guard": cache_guard_enabled(),
        "opteq_examples": opteq_examples(),
        "segsum_impl": segsum_impl(),
        "join_impl": join_impl(),
        "groupby_impl": groupby_impl(),
        "flow_style": flow_style(),
        "trace": trace_enabled(),
        "trace_path": trace_path(),
        "trace_max_events": trace_max_events(),
        "serve_strict_watermark": serve_strict_watermark(),
        "serve_history": serve_history(),
        "faults": faults_spec(),
        "retry_max": retry_max(),
        "retry_backoff": retry_backoff(),
        "degrade": degrade_enabled(),
        "shards": shards(),
        "shard_impl": shard_impl(),
    }
